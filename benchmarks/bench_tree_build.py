"""Paper Figure 7: average tree-building time, SecureBoost vs SecureBoost+.

Legacy = no packing, no histogram subtraction, no compression, no GOSS
(FATE-1.5 SecureBoost).  Plus = all cipher optimizations + GOSS + sparse.
Reported per dataset and cipher: per-tree seconds, HE-op counts, the
headline derived metric -- % tree-time reduction (paper: 37.5-82.4%
IterativeAffine, 84.9-95.5% Paillier) -- and the layer-batching counters:
histogram kernel launches and guest<->host split_infos round-trips per
tree (O(depth) under the layer-batched grower, vs O(#nodes) per-node).
"""

from __future__ import annotations

import dataclasses

from .common import DATASETS, auc, emit, load, timed

from repro.core import SBTParams, VerticalBoosting


def _per_tree(stats, field: str, n_trees: int) -> float:
    return getattr(stats, field) / max(n_trees, 1)


def run_pair(name: str, cipher: str, key_bits: int, n_trees: int = 4,
             precision: int = 28):
    Xg, Xh, y, _ = load(name)
    base = SBTParams(n_trees=n_trees, max_depth=5, n_bins=32, cipher=cipher,
                     key_bits=key_bits, precision=precision, seed=1)
    legacy_p = dataclasses.replace(base, packing=False,
                                   histogram_subtraction=False,
                                   compression=False)
    # paper's default SBT+ setting; GOSS rates softened for the short
    # tree budgets CPU wall-time allows (paper runs 25 trees)
    plus_p = dataclasses.replace(base, goss=True, top_rate=0.3,
                                 other_rate=0.2, sparse=False)

    legacy = VerticalBoosting(legacy_p)
    _, t_leg = timed(lambda: legacy.fit(Xg, y, [Xh]))
    plus = VerticalBoosting(plus_p)
    _, t_plus = timed(lambda: plus.fit(Xg, y, [Xh]))

    red = 100.0 * (1 - t_plus / t_leg)
    return {
        "legacy_s_per_tree": t_leg / n_trees,
        "plus_s_per_tree": t_plus / n_trees,
        "reduction_pct": red,
        "legacy_ops": legacy.stats.as_dict(),
        "plus_ops": plus.stats.as_dict(),
        "legacy_launches_per_tree": _per_tree(legacy.stats,
                                              "n_hist_launches", n_trees),
        "plus_launches_per_tree": _per_tree(plus.stats,
                                            "n_hist_launches", n_trees),
        "legacy_roundtrips_per_tree": _per_tree(legacy.stats,
                                                "n_split_roundtrips", n_trees),
        "plus_roundtrips_per_tree": _per_tree(plus.stats,
                                              "n_split_roundtrips", n_trees),
        "auc_legacy": auc(legacy.predict_proba(Xg, [Xh]), y),
        "auc_plus": auc(plus.predict_proba(Xg, [Xh]), y),
    }


def main(quick: bool = False):
    rows = []
    datasets = ["give_credit", "epsilon"] if quick else list(DATASETS)
    for cipher, bits in [("affine", 1024)]:
        for name in datasets:
            r = run_pair(name, cipher, bits)
            rows.append((f"fig7/{name}/{cipher}/legacy",
                         r["legacy_s_per_tree"] * 1e6,
                         f"auc={r['auc_legacy']:.3f}"
                         f";launches/tree={r['legacy_launches_per_tree']:.1f}"
                         f";roundtrips/tree="
                         f"{r['legacy_roundtrips_per_tree']:.1f}"))
            rows.append((f"fig7/{name}/{cipher}/plus",
                         r["plus_s_per_tree"] * 1e6,
                         f"reduction={r['reduction_pct']:.1f}%"
                         f";auc={r['auc_plus']:.3f}"
                         f";launches/tree={r['plus_launches_per_tree']:.1f}"
                         f";roundtrips/tree="
                         f"{r['plus_roundtrips_per_tree']:.1f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced dataset list (CI smoke test)")
    main(quick=ap.parse_args().quick)
