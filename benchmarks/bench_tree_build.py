"""Paper Figure 7: average tree-building time, SecureBoost vs SecureBoost+.

Legacy = no packing, no histogram subtraction, no compression, no GOSS
(FATE-1.5 SecureBoost).  Plus = all cipher optimizations + GOSS + sparse.
Reported per dataset and cipher: per-tree seconds, HE-op counts, the
headline derived metric -- % tree-time reduction (paper: 37.5-82.4%
IterativeAffine, 84.9-95.5% Paillier) -- and the layer-batching counters:
histogram kernel launches and guest<->host split_infos round-trips per
tree (O(depth) under the layer-batched grower, vs O(#nodes) per-node).

The ``scale`` section measures the mesh-sharded frontier engine
(DESIGN.md §7): the same federated training on the largest quick-bench
shape, single device vs an (data, model) mesh over every visible device.
Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get the
multi-device rows on CPU; they report per-tree speedup, bit-identity of
predictions, and intra-party collective bytes (psum + node all-gather) from
the ``Stats``/``Channel`` ledgers.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .common import DATASETS, auc, emit, load, timed

from repro.core import SBTParams, VerticalBoosting
from repro.data import synthetic_tabular

# largest quick-bench shape: instance-heavy so histogram accumulation (the
# sharded stage) dominates the per-tree wall time; 3 trees amortize the
# per-frontier-shape jit compilations into the steady state
SCALE = dict(n=65536, d=16, n_trees=3, max_depth=4, n_bins=32)


def _per_tree(stats, field: str, n_trees: int) -> float:
    return getattr(stats, field) / max(n_trees, 1)


def run_pair(name: str, cipher: str, key_bits: int, n_trees: int = 4,
             precision: int = 28):
    Xg, Xh, y, _ = load(name)
    base = SBTParams(n_trees=n_trees, max_depth=5, n_bins=32, cipher=cipher,
                     key_bits=key_bits, precision=precision, seed=1)
    legacy_p = dataclasses.replace(base, packing=False,
                                   histogram_subtraction=False,
                                   compression=False)
    # paper's default SBT+ setting; GOSS rates softened for the short
    # tree budgets CPU wall-time allows (paper runs 25 trees)
    plus_p = dataclasses.replace(base, goss=True, top_rate=0.3,
                                 other_rate=0.2, sparse=False)

    legacy = VerticalBoosting(legacy_p)
    _, t_leg = timed(lambda: legacy.fit(Xg, y, [Xh]))
    plus = VerticalBoosting(plus_p)
    _, t_plus = timed(lambda: plus.fit(Xg, y, [Xh]))

    red = 100.0 * (1 - t_plus / t_leg)
    return {
        "legacy_s_per_tree": t_leg / n_trees,
        "plus_s_per_tree": t_plus / n_trees,
        "reduction_pct": red,
        "legacy_ops": legacy.stats.as_dict(),
        "plus_ops": plus.stats.as_dict(),
        "legacy_launches_per_tree": _per_tree(legacy.stats,
                                              "n_hist_launches", n_trees),
        "plus_launches_per_tree": _per_tree(plus.stats,
                                            "n_hist_launches", n_trees),
        "legacy_roundtrips_per_tree": _per_tree(legacy.stats,
                                                "n_split_roundtrips", n_trees),
        "plus_roundtrips_per_tree": _per_tree(plus.stats,
                                              "n_split_roundtrips", n_trees),
        "plus_encrypt_s_per_tree": _per_tree(plus.stats, "encrypt_seconds",
                                             n_trees),
        "plus_overlap_frac": plus.stats.overlap_fraction,
        "auc_legacy": auc(legacy.predict_proba(Xg, [Xh]), y),
        "auc_plus": auc(plus.predict_proba(Xg, [Xh]), y),
    }


def run_scale():
    """Mesh-sharded frontier engine + crypto endpoints vs single device.

    Two ciphers on the same shape: ``plain`` isolates the sharded histogram
    dispatch and the guest/host overlap; ``affine`` additionally exercises
    the sharded encrypt/decrypt Toeplitz matmuls (DESIGN.md §8), which is
    where the paper's ciphertext-cost argument lives."""
    from repro.launch.mesh import make_gbdt_mesh

    s = SCALE
    X, y = synthetic_tabular(s["n"], s["d"], seed=0, task="binary")
    # host-heavy vertical split (paper's setting: the passive party holds
    # most features) -- the ciphertext histogram path is what shards
    n_guest = max(2, s["d"] // 8)
    Xg, Xh = X[:, :n_guest], X[:, n_guest:]
    mesh = make_gbdt_mesh()

    rows = []
    configs = [("plain", {"cipher": "plain"}, s["n_trees"]),
               ("affine", {"cipher": "affine", "key_bits": 512,
                           "precision": 24}, 2)]
    for cname, kw, n_trees in configs:
        base = SBTParams(n_trees=n_trees, max_depth=s["max_depth"],
                         n_bins=s["n_bins"], seed=1, **kw)
        single = VerticalBoosting(base)
        _, t1 = timed(lambda: single.fit(Xg, y, [Xh]))
        st1 = single.stats
        rows.append((
            f"scale/{s['n']}x{s['d']}/{cname}/1dev",
            t1 / n_trees * 1e6,
            f"launches/tree={st1.n_hist_launches / n_trees:.1f};devices=1"
            f";encrypt_s_per_tree={st1.encrypt_seconds / n_trees:.3f}"
            f";overlap_frac={st1.overlap_fraction:.3f}"))

        if cname == "affine":
            # pipelined boosting (DESIGN.md §12): encrypt+ship overlapped
            # with compute.  Bit-identical to the sequential run by
            # construction — the row asserts it — so the s/tree delta is
            # pure overlap, not a different model.
            pipe = VerticalBoosting(dataclasses.replace(base,
                                                        pipeline=True))
            _, tp = timed(lambda: pipe.fit(Xg, y, [Xh]))
            identp = bool(np.array_equal(pipe.predict_proba(Xg, [Xh]),
                                         single.predict_proba(Xg, [Xh])))
            stp = pipe.stats
            rows.append((
                f"scale/{s['n']}x{s['d']}/{cname}/pipelined",
                tp / n_trees * 1e6,
                f"speedup_vs_seq={t1 / tp:.2f}x;bit_identical={identp}"
                f";encrypt_s_per_tree={stp.encrypt_seconds / n_trees:.3f}"
                f";wire_overlap_frac={stp.wire_overlap_frac:.3f}"))

            # round-forests (forest_size=k): k bagged member trees per
            # round share ONE enc_gh round-trip, so encrypt seconds
            # amortize across the round's members
            fk = 4
            forest = VerticalBoosting(dataclasses.replace(
                base, forest_size=fk, pipeline=True))
            _, tf = timed(lambda: forest.fit(Xg, y, [Xh]))
            n_member = n_trees * fk
            stf = forest.stats
            rows.append((
                f"scale/{s['n']}x{s['d']}/{cname}/forest{fk}",
                tf / n_member * 1e6,
                f"members={n_member}"
                f";auc={auc(forest.predict_proba(Xg, [Xh]), y):.3f}"
                f";encrypt_s_per_tree={stf.encrypt_seconds / n_member:.3f}"
                f";wire_overlap_frac={stf.wire_overlap_frac:.3f}"))

        if mesh is None:
            rows.append((f"scale/{s['n']}x{s['d']}/{cname}/sharded", 0.0,
                         "SKIP:single-device (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)"))
            continue

        sharded = VerticalBoosting(dataclasses.replace(base, mesh=mesh))
        _, t2 = timed(lambda: sharded.fit(Xg, y, [Xh]))
        ident = bool(np.array_equal(sharded.predict_proba(Xg, [Xh]),
                                    single.predict_proba(Xg, [Xh])))
        coll = sharded.channel.collective_summary()
        st2 = sharded.stats
        rows.append((
            f"scale/{s['n']}x{s['d']}/{cname}/{mesh.devices.size}dev",
            t2 / n_trees * 1e6,
            f"speedup={t1 / t2:.2f}x;bit_identical={ident}"
            f";encrypt_s_per_tree={st2.encrypt_seconds / n_trees:.3f}"
            f";overlap_frac={st2.overlap_fraction:.3f}"
            f";cts_placements={st2.n_cts_placements}"
            f";coll_mb={st2.coll_bytes / 1e6:.1f}"
            f";psum_mb={coll.get('hist_psum', {}).get('bytes', 0) / 1e6:.1f}"
            f";allgather_mb="
            f"{coll.get('hist_allgather', {}).get('bytes', 0) / 1e6:.1f}"
            f";n_collectives={st2.n_collectives}"
            f";mesh={'x'.join(map(str, mesh.devices.shape))}"))
    return rows


def run_outofcore(quick: bool = False):
    """Out-of-core data path (DESIGN.md §13): train a row count that never
    materializes X — streaming sketch binning, block-wise frontier
    accumulation, chunked encrypt->ship — and report the peak gauges that
    certify O(block) residency.  The full shape is the paper-scale
    tens-of-millions row claim (10M x 64, ~10 minutes on CPU); ``--quick``
    runs the same path at 200k x 16.  Budget: the full run must stay under
    ~6 GB peak RSS end-to-end (the gauges in the derived string are the
    asserted device-side footprint; ``peak_rss_mb`` is the whole-process
    ceiling CI enforces at the 1M smoke tier)."""
    import resource

    from repro.data import synthetic_tabular_stream

    if quick:
        n, d, block = 200_000, 16, 32_768
    else:
        n, d, block = 10_000_000, 64, 65_536
    n_guest = max(2, d // 8)
    blocks, y = synthetic_tabular_stream(n, d, block=block, seed=0)
    # key_bits=256 keeps the plain-cipher limb width at its floor (Ln=32):
    # the 10M shape's assembled ciphertext store is n * Ln uint8 bytes
    p = SBTParams(n_trees=1, max_depth=3, n_bins=16, cipher="plain",
                  key_bits=256, seed=1, row_block=block)
    model = VerticalBoosting(p)
    _, t = timed(lambda: model.fit(blocks.select_columns(0, n_guest), y,
                                   [blocks.select_columns(n_guest, d)]))
    st = model.stats
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    return [(
        f"outofcore/{n}x{d}/plain/block{block}",
        t / p.n_trees * 1e6,
        f"rows={n};block={block}"
        f";peak_cts_bytes={st.peak_cts_bytes}"
        f";peak_block_bytes={st.peak_block_bytes}"
        f";peak_rss_mb={rss_mb:.0f}"
        f";enc_gh_msgs={model.channel.summary()['enc_gh']['msgs']}"
        f";train_s={t:.1f}")]


def main(quick: bool = False):
    rows = []
    datasets = ["give_credit", "epsilon"] if quick else list(DATASETS)
    for cipher, bits in [("affine", 1024)]:
        for name in datasets:
            r = run_pair(name, cipher, bits)
            rows.append((f"fig7/{name}/{cipher}/legacy",
                         r["legacy_s_per_tree"] * 1e6,
                         f"auc={r['auc_legacy']:.3f}"
                         f";launches/tree={r['legacy_launches_per_tree']:.1f}"
                         f";roundtrips/tree="
                         f"{r['legacy_roundtrips_per_tree']:.1f}"))
            rows.append((f"fig7/{name}/{cipher}/plus",
                         r["plus_s_per_tree"] * 1e6,
                         f"reduction={r['reduction_pct']:.1f}%"
                         f";auc={r['auc_plus']:.3f}"
                         f";launches/tree={r['plus_launches_per_tree']:.1f}"
                         f";roundtrips/tree="
                         f"{r['plus_roundtrips_per_tree']:.1f}"
                         f";encrypt_s_per_tree="
                         f"{r['plus_encrypt_s_per_tree']:.3f}"
                         f";overlap_frac={r['plus_overlap_frac']:.3f}"))
    rows += run_scale()
    rows += run_outofcore(quick=quick)
    emit(rows)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced dataset list (CI smoke test)")
    main(quick=ap.parse_args().quick)
