"""Paper Figures 9/10 + Table 5: SecureBoost-MO vs per-class trees.

Derived metrics: trees built to matched accuracy (paper: 275->38 etc.) and
total tree-building time reduction (paper: 57-81%)."""

from __future__ import annotations

import dataclasses

import numpy as np

from .common import MULTI_DATASETS, emit, load, timed

from repro.core import SBTParams, VerticalBoosting


def main(quick: bool = False):
    rows = []
    datasets = ["sensorless"] if quick else list(MULTI_DATASETS)
    for name in datasets:
        Xg, Xh, y, spec = load(name)
        k = spec["n_classes"]
        base = SBTParams(n_trees=2, max_depth=4, n_bins=32,
                         cipher="affine", key_bits=1024, precision=24,
                         n_classes=k, seed=7)
        percls = VerticalBoosting(dataclasses.replace(
            base, objective="multiclass"))        # 2*k trees
        _, t_pc = timed(lambda: percls.fit(Xg, y, [Xh]))
        acc_pc = float((percls.predict_proba(Xg, [Xh]).argmax(1) == y).mean())

        # MO gets more rounds (paper matches accuracy, not rounds) but still
        # far fewer trees than per-class
        mo = VerticalBoosting(dataclasses.replace(base, objective="mo",
                                                  n_trees=6))
        _, t_mo = timed(lambda: mo.fit(Xg, y, [Xh]))
        acc_mo = float((mo.predict_proba(Xg, [Xh]).argmax(1) == y).mean())

        red = 100 * (1 - t_mo / t_pc)
        rows.append((f"fig9/{name}/per_class_trees",
                     t_pc * 1e6, f"trees={len(percls.trees)};acc={acc_pc:.3f}"))
        rows.append((f"fig9/{name}/mo_trees", t_mo * 1e6,
                     f"trees={len(mo.trees)};acc={acc_mo:.3f}"
                     f";time_reduction={red:.1f}%"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
