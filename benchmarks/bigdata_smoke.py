"""CI bigdata smoke: 1M-row out-of-core training under a hard RSS bound.

Exercises the full §13 streaming path — sketch binning over a generated
``RowBlocks`` source, chunked encrypt->ship, block-wise frontier
accumulation — at a row count where the monolithic int32 bin matrix plus
width-padded device ciphertexts would already cost ~1 GB, and FAILS (exit
1) if the process peak RSS exceeds ``--max-rss-mb``.  The bound is the
regression tripwire: an accidental O(rows) materialization anywhere in
the streamed path (a full-width cts upload, an un-chunked encode, an
int32 bin copy) blows straight through it.

    PYTHONPATH=src python -m benchmarks.bigdata_smoke [--rows 1000000]
                                                      [--max-rss-mb 2048]
"""

from __future__ import annotations

import argparse
import resource
import sys

sys.path.insert(0, "src")


def main(rows: int, max_rss_mb: float) -> int:
    import numpy as np

    from repro.core import SBTParams, VerticalBoosting
    from repro.data import synthetic_tabular_stream

    d, block = 16, 65_536
    n_guest = max(2, d // 8)
    blocks, y = synthetic_tabular_stream(rows, d, block=block, seed=0)
    p = SBTParams(n_trees=1, max_depth=3, n_bins=16, cipher="plain",
                  key_bits=256, seed=1, row_block=block)
    model = VerticalBoosting(p)
    model.fit(blocks.select_columns(0, n_guest), y,
              [blocks.select_columns(n_guest, d)])
    st = model.stats
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024

    n_blocks = -(-rows // block)
    enc_msgs = model.channel.summary()["enc_gh"]["msgs"]
    assert enc_msgs == n_blocks, (enc_msgs, n_blocks)
    assert st.peak_block_bytes > 0 and st.peak_cts_bytes > 0
    # device-side residency must be O(block), nowhere near O(rows)
    width = model.cipher.hist_width
    assert st.peak_cts_bytes <= 2 * block * width * 4, st.peak_cts_bytes
    assert np.isfinite(model.train_score_).all()

    print(f"bigdata_smoke: rows={rows} block={block} "
          f"peak_rss_mb={rss_mb:.0f} (bound {max_rss_mb:.0f}) "
          f"peak_cts_bytes={st.peak_cts_bytes} "
          f"peak_block_bytes={st.peak_block_bytes} enc_gh_msgs={enc_msgs}")
    if rss_mb > max_rss_mb:
        print(f"FAIL: peak RSS {rss_mb:.0f} MB exceeds the "
              f"{max_rss_mb:.0f} MB budget", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1_000_000)
    ap.add_argument("--max-rss-mb", type=float, default=2048)
    a = ap.parse_args()
    sys.exit(main(a.rows, a.max_rss_mb))
