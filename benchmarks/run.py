"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` runs reduced dataset
lists (CI); default runs the full set (minutes on CPU).  ``--json out.json``
additionally writes machine-readable results (name, us_per_call, the parsed
derived counters, and environment info) so per-PR perf trajectories can be
recorded and CI can upload the file as an artifact.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7,...]
                                            [--json out.json]
                                            [--trace trace.json]

``--trace`` installs a process-default tracer (``repro.obs``): every
bench's guest-side spans and wire instants are recorded, each bench's
results gain a ``trace`` summary (event count, top-3 spans by self
time), and one merged Perfetto ``trace.json`` (one pid per bench) is
written at the given path — open it at https://ui.perfetto.dev.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
import traceback

sys.path.insert(0, "src")

_KEY_RE = re.compile(r"^[A-Za-z_][\w./-]*$")

BENCHES = {
    "fig7_tree_build": "benchmarks.bench_tree_build",
    "table3_lossless": "benchmarks.bench_lossless",
    "fig8_modes": "benchmarks.bench_modes",
    "fig9_mo": "benchmarks.bench_mo",
    "cost_model": "benchmarks.bench_cost_model",
    "kernels": "benchmarks.bench_kernels",
    "serving": "benchmarks.bench_serving",
    "transport": "benchmarks.bench_transport",
}


def _parse_derived(derived: str) -> dict:
    """'a=1;b=2.5x;c=foo' -> {'a': 1.0, 'b': '2.5x', 'c': 'foo'} (floats
    where they parse, raw strings otherwise).  Fragments without an
    identifier-like key (e.g. 'SKIP:...' markers) land under 'notes'."""
    out = {}
    for part in str(derived).split(";"):
        k, _, v = part.partition("=")
        if _KEY_RE.match(k) and _ == "=":
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
        elif part:
            out.setdefault("notes", []).append(part)
    return out


def _env_info() -> dict:
    import os
    info = {"python": sys.version.split()[0],
            "xla_flags": os.environ.get("XLA_FLAGS", "")}
    try:
        import jax
        info["jax"] = jax.__version__
        info["backend"] = jax.default_backend()
        info["n_devices"] = len(jax.devices())
    except Exception:                # noqa: BLE001
        pass
    return info


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None,
                    help="write machine-readable results to this path")
    ap.add_argument("--trace", default=None,
                    help="record spans and write a merged Perfetto "
                         "trace.json to this path")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    tracer = None
    if args.trace:
        from repro.obs.trace import Tracer, set_default
        tracer = Tracer("bench", capacity=1 << 18)
        set_default(tracer)     # training code inherits it via begin_fit

    print("name,us_per_call,derived")
    failures = 0
    results = []
    trace_parties = []
    for pid, (key, mod_name) in enumerate(BENCHES.items()):
        if only and key not in only:
            continue
        print(f"# --- {key} ---", flush=True)
        if tracer is not None:
            tracer.clear()      # one clean buffer per bench
        try:
            mod = __import__(mod_name, fromlist=["main"])
            rows = mod.main(quick=args.quick) or []
            bench_results = [{"bench": key, "name": name,
                              "us_per_call": float(us),
                              "stats": _parse_derived(derived)}
                             for name, us, derived in rows]
        except Exception as e:        # noqa: BLE001
            failures += 1
            print(f"{key},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc()
            bench_results = [{"bench": key, "name": key, "us_per_call": 0.0,
                              "stats": {"error": f"{type(e).__name__}: {e}"}}]
        if tracer is not None and len(tracer):
            from repro.obs.export import merge_traces, trace_summary
            party = {"party": key, "pid": pid,
                     "events": tracer.export_events(), "offset_ns": 0}
            summ = trace_summary(merge_traces([party]),
                                 dropped=tracer.dropped)
            for r in bench_results:
                r["trace"] = summ
            trace_parties.append(party)
        results += bench_results
    if args.trace and trace_parties:
        from repro.obs.export import merge_traces, write_perfetto
        write_perfetto(args.trace, merge_traces(trace_parties),
                       trace_parties)
        print(f"# wrote trace to {args.trace}", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": 2, "env": _env_info(),
                       "quick": args.quick, "results": results}, f,
                      indent=1)
        print(f"# wrote {len(results)} results to {args.json}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
