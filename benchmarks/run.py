"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` runs reduced dataset
lists (CI); default runs the full set (minutes on CPU).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7,...]
"""

from __future__ import annotations

import argparse
import sys
import traceback

sys.path.insert(0, "src")

BENCHES = {
    "fig7_tree_build": "benchmarks.bench_tree_build",
    "table3_lossless": "benchmarks.bench_lossless",
    "fig8_modes": "benchmarks.bench_modes",
    "fig9_mo": "benchmarks.bench_mo",
    "cost_model": "benchmarks.bench_cost_model",
    "kernels": "benchmarks.bench_kernels",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for key, mod_name in BENCHES.items():
        if only and key not in only:
            continue
        print(f"# --- {key} ---", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main(quick=args.quick)
        except Exception as e:        # noqa: BLE001
            failures += 1
            print(f"{key},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
