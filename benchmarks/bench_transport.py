"""Multi-host transport benchmark (DESIGN.md §10): socket-vs-ledger bytes
and round-trip latency for one training round and one serving batch.

Runs the forced-2-process runtime (guest here, one host spawned over the
length-prefixed localhost socket) and reports, per phase:

* ``ledger_bytes``  — the analytic protocol-fidelity wire model the paper's
  cost equations (10/16) read,
* ``socket_bytes``  — framed bytes that actually crossed the transport
  (tx + rx, headers and the int32 in-memory limb layout included),
* ``overhead_x``    — socket / ledger (the serialization-fidelity gap),
* ``rt_ms``         — median control-frame round-trip latency,
* ``bit_identical`` — vs the in-process Channel oracle.

Falls back to the in-memory loopback transport (identical framing and
byte accounting, no sockets) where process spawning is unavailable; the
``mode`` field says which ran.
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from .common import emit, timed

from repro.core import SBTParams, VerticalBoosting
from repro.data import synthetic_tabular
from repro.runtime.transport import MultiHostRun

SHAPE = dict(n=4096, d=12, n_bins=16, max_depth=4)


def _phase_bytes(channel, tags) -> tuple:
    ledger = sum(channel.totals[t] for t in tags)
    sock = sum(channel.tx_bytes[t] + channel.rx_bytes[t] for t in tags)
    return ledger, sock


def main(quick: bool = False):
    s = SHAPE
    n = 1024 if quick else s["n"]
    X, y = synthetic_tabular(n, s["d"], seed=0, task="binary")
    Xg, Xh = X[:, :4], X[:, 4:]
    params = SBTParams(n_trees=1, max_depth=s["max_depth"],
                       n_bins=s["n_bins"], cipher="affine", key_bits=256,
                       precision=20, seed=1)

    ref = VerticalBoosting(params).fit(Xg, y, [Xh])

    rows = []
    run = None
    try:
        try:
            run = MultiHostRun(params, [Xh], transport="socket",
                               export_dir=tempfile.mkdtemp(), timeout=300.0)
            mode = "socket"
        except Exception:                        # noqa: BLE001
            run = MultiHostRun(params, [Xh], transport="loopback",
                               export_dir=tempfile.mkdtemp())
            mode = "loopback"

        # -- one training round (1 tree) over the transport -------------
        model, t_fit = timed(lambda: run.fit(Xg, y))
        train_tags = ("enc_gh", "assign_sync", "split_infos", "chosen_sid",
                      "assign_mask")
        ledger, sock = _phase_bytes(run.channel, train_tags)
        ident = bool(np.array_equal(model.train_score_, ref.train_score_))
        pings = sorted(run.ping() for _ in range(5))
        rt_ms = pings[len(pings) // 2] * 1e3
        rows.append((
            "transport/train_round",
            t_fit * 1e6,
            f"mode={mode};ledger_bytes={ledger};socket_bytes={sock};"
            f"overhead_x={sock / max(ledger, 1):.2f};rt_ms={rt_ms:.3f};"
            f"roundtrips={model.stats.n_split_roundtrips};"
            f"bit_identical={ident}"))

        # -- one serving batch from reloaded per-party exports -----------
        run.serve()
        ref.predict_score(Xg, [Xh])              # warm the oracle's jits
        base = dict(run.channel.totals)
        base_tx = dict(run.channel.tx_bytes)
        base_rx = dict(run.channel.rx_bytes)
        t0 = time.perf_counter()
        score = run.predict_score(Xg, staged=True)
        t_serve = time.perf_counter() - t0
        serve_tags = ("predict_req", "predict_bits")
        ledger = sum(run.channel.totals[t] - base.get(t, 0)
                     for t in serve_tags)
        sock = sum(run.channel.tx_bytes[t] - base_tx.get(t, 0)
                   + run.channel.rx_bytes[t] - base_rx.get(t, 0)
                   for t in serve_tags)
        s_ref = ref.predict_score(Xg, [Xh])
        rows.append((
            "transport/serve_batch",
            t_serve * 1e6,
            f"mode={mode};rows={n};ledger_bytes={ledger};"
            f"socket_bytes={sock};overhead_x={sock / max(ledger, 1):.2f};"
            f"batch_ms={t_serve * 1e3:.1f};"
            f"bit_identical={bool(np.array_equal(score, s_ref))}"))
    finally:
        if run is not None:
            run.close()

    rows += _bench_resilience(params, Xg, Xh, y, ref, quick)
    rows += _bench_trace_overhead(params, Xg, Xh, y, quick)
    emit(rows)
    return rows


def _bench_trace_overhead(params, Xg, Xh, y, quick: bool):
    """``transport/trace_overhead`` — the observability layer's cost when
    ENABLED: paired fits with ``trace=True`` vs ``trace=False`` (min of 3
    each), plus the merged event count.  The acceptance bound for the
    DISABLED path is bit-identity + ≤2% (tests/test_obs.py); this row
    tracks what turning tracing ON costs."""
    import dataclasses

    def one_fit(trace: bool) -> tuple:
        p = dataclasses.replace(params, trace=trace)
        run = MultiHostRun(p, [Xh], transport="loopback",
                           export_dir=tempfile.mkdtemp())
        try:
            t0 = time.perf_counter()
            run.fit(Xg, y)
            dt = time.perf_counter() - t0
            n_ev = len(run.trace()) if trace else 0
            return dt, n_ev
        finally:
            run.close()

    try:
        reps = 2 if quick else 3
        one_fit(False)                           # warm jits
        t_off = min(one_fit(False)[0] for _ in range(reps))
        pairs = [one_fit(True) for _ in range(reps)]
        t_on = min(dt for dt, _ in pairs)
        n_ev = pairs[0][1]
        return [(
            "transport/trace_overhead",
            t_on * 1e6,
            f"plain_us={t_off * 1e6:.0f};"
            f"overhead_pct={(t_on / t_off - 1) * 100:.1f};"
            f"events={n_ev}")]
    except Exception as e:                       # noqa: BLE001
        return [("transport/trace_overhead", 0.0,
                 f"skipped={type(e).__name__}")]


def _bench_resilience(params, Xg, Xh, y, ref, quick: bool):
    """Fault-tolerance rows (DESIGN.md §11):

    * ``transport/resilient_overhead`` — the seq/retry/snapshot layer's
      zero-fault cost: a resilient fit with NO faults injected, compared
      against the plain fit wall-clock from the same process (must stay
      within a few percent — the acceptance bound is 5%);
    * ``transport/crash_recovery`` — wall-clock for a fit that takes one
      deterministic mid-tree host kill, minus the fault-free fit: the
      price of detect + respawn + resume, with bit-identity checked.
    """
    import os

    from repro.runtime.chaos import RECV, FaultPlan, Kill

    rows = []

    def one_fit(fault: bool, resilient: bool):
        base = tempfile.mkdtemp()
        plans = {0: FaultPlan(rules=[Kill(tree=0, layer=1, direction=RECV)],
                              seed=5)} if fault else None
        run = MultiHostRun(params, [Xh], transport="socket",
                           export_dir=os.path.join(base, "export"),
                           state_dir=os.path.join(base, "state"),
                           fault_plans=plans, timeout=300.0)
        try:
            t0 = time.perf_counter()
            if resilient:
                model = run.fit(Xg, y, resilient=True,
                                ckpt_dir=os.path.join(base, "ckpt"),
                                max_retries=5)
            else:
                model = run.fit(Xg, y)
            dt = time.perf_counter() - t0
            ident = bool(np.array_equal(model.train_score_,
                                        ref.train_score_))
            return dt, ident, run.restarts
        finally:
            run.close()

    try:
        t_plain, _, _ = one_fit(fault=False, resilient=False)
        t_resil, ident, _ = one_fit(fault=False, resilient=True)
        rows.append((
            "transport/resilient_overhead",
            t_resil * 1e6,
            f"plain_us={t_plain * 1e6:.0f};"
            f"overhead_pct={(t_resil / t_plain - 1) * 100:.1f};"
            f"bit_identical={ident}"))

        t_crash, ident, restarts = one_fit(fault=True, resilient=True)
        rows.append((
            "transport/crash_recovery",
            t_crash * 1e6,
            f"faultfree_us={t_resil * 1e6:.0f};"
            f"recovery_cost_us={(t_crash - t_resil) * 1e6:.0f};"
            f"restarts={restarts};bit_identical={ident}"))
    except Exception as e:                       # noqa: BLE001
        # resilience rows need real process spawning; report instead of
        # failing the whole benchmark where sockets are unavailable
        rows.append(("transport/resilient_overhead", 0.0,
                     f"skipped={type(e).__name__}"))
    return rows


if __name__ == "__main__":
    main()
