"""Shared benchmark helpers: datasets shaped like the paper's (scaled to
CPU), AUC/accuracy metrics, timing."""

from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.data import synthetic_tabular  # noqa: E402

# paper datasets, scaled down for single-core CPU wall-time (aspect ratios
# preserved: susy/higgs instance-heavy, epsilon feature-heavy)
DATASETS = {
    "give_credit": dict(n=3000, d=10, task="binary"),
    "susy": dict(n=5000, d=18, task="binary"),
    "higgs": dict(n=6000, d=28, task="binary"),
    "epsilon": dict(n=1200, d=100, task="binary"),   # high-dimensional
}

MULTI_DATASETS = {
    "sensorless": dict(n=3000, d=48, task="multi", n_classes=11),
    "covtype": dict(n=4000, d=54, task="multi", n_classes=7),
    "svhn": dict(n=1200, d=128, task="multi", n_classes=10),
}


def load(name: str, seed: int = 0, sparsity: float = 0.0):
    spec = {**DATASETS, **MULTI_DATASETS}[name]
    X, y = synthetic_tabular(spec["n"], spec["d"], seed=seed,
                             task=spec["task"],
                             n_classes=spec.get("n_classes", 2),
                             sparsity=sparsity)
    half = spec["d"] // 2
    return X[:, :half], X[:, half:], y, spec


def auc(p: np.ndarray, y: np.ndarray) -> float:
    pos, neg = p[y == 1], p[y == 0]
    if len(pos) == 0 or len(neg) == 0:
        return 0.5
    return float((pos[:, None] > neg[None, :]).mean()
                 + 0.5 * (pos[:, None] == neg[None, :]).mean())


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def emit(rows):
    """CSV contract: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
