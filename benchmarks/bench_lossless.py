"""Paper Table 3: AUC parity -- local XGBoost-role vs SecureBoost vs
SecureBoost+ (losslessness of the cipher optimizations)."""

from __future__ import annotations

import dataclasses

from .common import DATASETS, auc, emit, load, timed

from repro.core import LocalGBDT, SBTParams, VerticalBoosting


def main(quick: bool = False):
    rows = []
    datasets = ["give_credit", "susy"] if quick else list(DATASETS)
    for name in datasets:
        Xg, Xh, y, _ = load(name)
        import numpy as np
        X = np.concatenate([Xg, Xh], axis=1)
        base = SBTParams(n_trees=10, max_depth=4, n_bins=32, seed=3)
        xgb = LocalGBDT(base).fit(X, y)
        sbt = VerticalBoosting(dataclasses.replace(
            base, packing=False, histogram_subtraction=False,
            compression=False)).fit(Xg, y, [Xh])
        sbtp = VerticalBoosting(dataclasses.replace(
            base, goss=True, top_rate=0.3, other_rate=0.2)).fit(
            Xg, y, [Xh])
        a1 = auc(xgb.predict_proba(X), y)
        a2 = auc(sbt.predict_proba(Xg, [Xh]), y)
        a3 = auc(sbtp.predict_proba(Xg, [Xh]), y)
        rows.append((f"table3/{name}/xgb", 0.0, f"auc={a1:.4f}"))
        rows.append((f"table3/{name}/secureboost", 0.0, f"auc={a2:.4f}"))
        rows.append((f"table3/{name}/secureboost+", 0.0,
                     f"auc={a3:.4f};delta_vs_local={a3 - a1:+.4f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
