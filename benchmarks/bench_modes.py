"""Paper Figure 8 + Table 4: mix / layered tree modes vs default
SecureBoost+ -- tree time reduction at matched model quality."""

from __future__ import annotations

import dataclasses

from .common import auc, emit, load, timed

from repro.core import SBTParams, VerticalBoosting


def main(quick: bool = False):
    rows = []
    datasets = ["give_credit", "epsilon"] if quick else [
        "give_credit", "susy", "higgs", "epsilon"]
    for name in datasets:
        Xg, Xh, y, _ = load(name)
        # paper's setting: depth 5, layered = host 3 + guest 2
        base = SBTParams(n_trees=6, max_depth=5, n_bins=32, cipher="affine",
                         key_bits=1024, precision=28, goss=True, seed=5)
        out = {}
        for mode in ["default", "mix", "layered"]:
            p = dataclasses.replace(base, tree_mode=mode, host_depth=3,
                                    guest_depth=2)
            m = VerticalBoosting(p)
            _, t = timed(lambda: m.fit(Xg, y, [Xh]))
            out[mode] = (t / base.n_trees, auc(m.predict_proba(Xg, [Xh]), y))
        t0 = out["default"][0]
        for mode in ["default", "mix", "layered"]:
            t, a = out[mode]
            red = 100 * (1 - t / t0)
            rows.append((f"fig8/{name}/{mode}", t * 1e6,
                         f"auc={a:.3f};reduction={red:.1f}%"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
