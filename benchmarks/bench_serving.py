"""Serving subsystem benchmark (DESIGN.md §9): packed engine vs the legacy
per-node predict loop on the 65536-row bench shape.

Reports, per configuration:

* ``rows_per_s`` — batch throughput of each path,
* ``speedup``    — packed vs legacy *from bins* (the routing engine vs the
  python node loop; both paths share the binning front-end, reported
  separately as the ``e2e`` rows),
* ``p50_batch_ms`` — median serve latency over repeated full batches,
* ``wire_bytes_per_instance`` / ``roundtrips_per_batch`` — from the
  ``predict_*`` ledger entries (1 bit per host internal node per instance
  plus the id request, ONE round-trip per host per batch),
* ``bit_identical`` — packed output vs the legacy loop,
* export → reload round-trip time and identity.

The ensemble uses the paper's 25-tree budget (10 under ``--quick``) at
depth 6: serving cost scales with total node count, which is where the
per-node loop loses.  A mesh row appears when multiple devices are
visible (forced CPU devices time-slice real cores, so its *throughput* is
not the headline — bit-identity under row sharding is).
"""

from __future__ import annotations

import time

import numpy as np

from .common import emit, timed

from repro.core import SBTParams, VerticalBoosting
from repro.core.binning import apply_binning
from repro.core.tree import predict_tree
from repro.data import synthetic_tabular
from repro.serving import (FederatedPredictor, PackedEnsemble, export_model,
                           load_ensemble)

SHAPE = dict(n=65536, d=16, n_bins=32, max_depth=6, n_train=4096)


def _median(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        _, t = timed(fn)
        ts.append(t)
    return sorted(ts)[len(ts) // 2]


def main(quick: bool = False):
    s = SHAPE
    n_trees = 10 if quick else 25
    reps = 5 if quick else 7
    X, y = synthetic_tabular(s["n"], s["d"], seed=0, task="binary")
    n_guest = max(2, s["d"] // 8)           # host-heavy vertical split
    Xg, Xh = X[:, :n_guest], X[:, n_guest:]

    model = VerticalBoosting(SBTParams(
        n_trees=n_trees, max_depth=s["max_depth"], n_bins=s["n_bins"],
        cipher="plain", seed=1)).fit(Xg[: s["n_train"]], y[: s["n_train"]],
                                     [Xh[: s["n_train"]]])
    n_nodes = sum(len(t.nodes) for t in model.trees)
    tag = f"serving/{s['n']}x{s['d']}/t{n_trees}"
    rows = []

    # --- from-bins: the routing engine vs the python node loop ----------
    gb = apply_binning(Xg, model.guest_data)
    hb = apply_binning(Xh, model.host_data[0])

    def legacy_bins():
        out = np.full(s["n"], model.init_score)
        for tree in model.trees:
            out += predict_tree(tree, gb, [hb])
        return out

    ens = PackedEnsemble.from_model(model)
    pred = FederatedPredictor(ens.guest, ens.hosts)   # own ledgers

    def packed_bins():
        return pred.predict_score_binned(gb, [hb])

    ref = legacy_bins()
    t_leg = _median(legacy_bins, max(3, reps - 2))
    packed_bins()                                     # compile
    t_pkd = _median(packed_bins, reps)
    ident = bool(np.array_equal(packed_bins(), ref))

    ch = pred.channel.summary()
    batches = pred.stats.n_predict_batches
    wire = (ch["predict_bits"]["bytes"] + ch["predict_req"]["bytes"]) \
        / batches / s["n"]
    rt = pred.stats.n_predict_roundtrips / batches

    rows.append((f"{tag}/legacy_loop", t_leg * 1e6,
                 f"rows_per_s={s['n'] / t_leg:.0f};n_nodes={n_nodes}"))
    rows.append((f"{tag}/packed", t_pkd * 1e6,
                 f"rows_per_s={s['n'] / t_pkd:.0f}"
                 f";speedup={t_leg / t_pkd:.1f}x"
                 f";p50_batch_ms={t_pkd * 1e3:.1f}"
                 f";wire_bytes_per_instance={wire:.1f}"
                 f";roundtrips_per_batch={rt:.0f}"
                 f";bit_identical={ident}"))

    # --- end to end (binning included on both sides) --------------------
    t_leg_e2e = _median(
        lambda: model.predict_score(Xg, [Xh], packed=False), 3)
    model.predict_score(Xg, [Xh])
    t_pkd_e2e = _median(lambda: model.predict_score(Xg, [Xh]), reps)
    rows.append((f"{tag}/legacy_e2e", t_leg_e2e * 1e6,
                 f"rows_per_s={s['n'] / t_leg_e2e:.0f}"))
    rows.append((f"{tag}/packed_e2e", t_pkd_e2e * 1e6,
                 f"rows_per_s={s['n'] / t_pkd_e2e:.0f}"
                 f";speedup={t_leg_e2e / t_pkd_e2e:.1f}x"))

    # --- export -> reload -> serve --------------------------------------
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        export_model(ens, d + "/model")
        ens2 = load_ensemble(d + "/model")
        t_io = time.perf_counter() - t0
    pred2 = FederatedPredictor(ens2.guest, ens2.hosts)
    ident2 = bool(np.array_equal(pred2.predict_score_binned(gb, [hb]), ref))
    rows.append((f"{tag}/export_reload", t_io * 1e6,
                 f"bit_identical={ident2}"))

    # --- mesh row (visible multi-device runtimes only) ------------------
    import jax
    if len(jax.devices()) > 1:
        from repro.launch.mesh import make_gbdt_mesh
        mpred = FederatedPredictor(ens.guest, ens.hosts,
                                   mesh=make_gbdt_mesh())
        mpred.predict_score_binned(gb, [hb])
        t_mesh = _median(lambda: mpred.predict_score_binned(gb, [hb]), 3)
        ident3 = bool(np.array_equal(
            mpred.predict_score_binned(gb, [hb]), ref))
        rows.append((f"{tag}/packed_{len(jax.devices())}dev", t_mesh * 1e6,
                     f"rows_per_s={s['n'] / t_mesh:.0f}"
                     f";bit_identical={ident3}"))
    else:
        rows.append((f"{tag}/packed_mesh", 0.0,
                     "SKIP:single-device (set XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8)"))

    emit(rows)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
