"""Kernel micro-benchmarks: ciphertext histogram / modmul / binning.

On this CPU container the Pallas kernels run in interpret mode (not
representative of TPU); we therefore time the jitted REFERENCE formulations
(the same math XLA would fuse on TPU) and report op-level throughput plus
the analytic MXU utilisation the kernel formulation achieves on the target
(one-hot matmul: 2*n_i*n_f*n_b*L FLOPs per histogram)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit

from repro.core.he import limbs, get_cipher
from repro.kernels.histogram import hist_ref
from repro.kernels.binning import bucketize_ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)

    n_i, n_f, n_b, L = (20000, 32, 32, 128) if not quick else (2000, 8, 32, 32)
    bins = jnp.asarray(rng.integers(0, n_b, (n_i, n_f)), jnp.int32)
    cts = jnp.asarray(rng.integers(0, 256, (n_i, L)), jnp.int32)
    f = jax.jit(lambda b, c: hist_ref(b, c, n_b))
    dt = _time(f, bins, cts)
    flops = 2 * n_i * n_f * n_b * L
    rows.append(("kernel/ciphertext_histogram", dt * 1e6,
                 f"n_i={n_i};n_f={n_f};L={L};"
                 f"target_flops_per_call={flops:.3g}"))

    aff = get_cipher("affine", key_bits=1024, seed=0)
    pts = jnp.asarray(limbs.from_pyints(
        [int(x) for x in rng.integers(1, 2 ** 62, 512)], aff.Ln))
    g = jax.jit(lambda x: aff.encrypt_limbs(x))
    dt = _time(g, pts)
    rows.append(("kernel/modmul_encrypt_batch512_1024b", dt * 1e6,
                 f"ciphers_per_s={512 / dt:.0f}"))

    v = jnp.asarray(rng.normal(0, 1, (n_i, n_f)), jnp.float32)
    thr = jnp.asarray(np.sort(rng.normal(0, 1, (n_f, n_b - 1)), axis=1),
                      jnp.float32)
    h = jax.jit(bucketize_ref)
    dt = _time(h, v, thr)
    rows.append(("kernel/bucketize", dt * 1e6,
                 f"elems_per_s={n_i * n_f / dt:.3g}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
