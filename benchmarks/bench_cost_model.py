"""Paper §4.1/§4.6 cost model: eqs (8)-(10) vs (14)-(16), analytic at the
paper's reference point AND measured HE-op counts from instrumented runs.

Paper's reference point: n_i = 1e6, n_f = 2000, h = 5 (n_n = 32 nodes),
n_b = 32, r = 53, Paillier-1024 (iota = 1023) -> eta_s = 6; claims:
compute -75%, enc/dec & comm -78%."""

from __future__ import annotations

import dataclasses
import math

from .common import emit, load

from repro.core import SBTParams, VerticalBoosting
from repro.core.encoding import plan_packing
import numpy as np


def analytic(n_i=10 ** 6, n_f=2000, h=5, n_b=32, r=53, iota=1023):
    n_n = 2 ** h
    # eqs 8-10 (legacy)
    comp = 2 * n_i * h * n_f + 2 * n_n * n_f * n_b
    ende = 2 * n_i + 2 * n_b * n_f * n_n
    comm = 2 * n_i + 2 * n_b * n_f * n_n
    # packing plan at this point gives b_gh and eta_s
    g = np.array([-1.0, 1.0]); hh = np.array([0.0, 1.0])
    plan = plan_packing(g, hh, n_i, iota, r)
    eta = plan.compress_capacity
    # eqs 14-16 (optimized)
    comp_o = 0.5 * n_i * h * n_f + n_n * n_f * n_b
    ende_o = n_i + n_b * n_f * n_n / eta
    comm_o = n_i + n_b * n_f * n_n / eta
    return {
        "eta_s": eta, "b_gh": plan.b_gh,
        "comp_reduction_pct": 100 * (1 - comp_o / comp),
        "ende_reduction_pct": 100 * (1 - ende_o / ende),
        "comm_reduction_pct": 100 * (1 - comm_o / comm),
    }


def measured(name="give_credit"):
    Xg, Xh, y, _ = load(name)
    base = SBTParams(n_trees=2, max_depth=4, n_bins=32, cipher="plain",
                     seed=2)
    leg = VerticalBoosting(dataclasses.replace(
        base, packing=False, histogram_subtraction=False,
        compression=False)).fit(Xg, y, [Xh])
    opt = VerticalBoosting(base).fit(Xg, y, [Xh])
    out = {}
    for key in ["n_encrypt", "n_decrypt", "n_hom_add"]:
        a = getattr(leg.stats, key)
        b = getattr(opt.stats, key)
        out[key] = 100 * (1 - b / a) if a else 0.0
    out["comm_bytes"] = 100 * (1 - (opt.channel.total_bytes
                                    / leg.channel.total_bytes))
    return out


def main(quick: bool = False):
    a = analytic()
    m = measured()
    rows = [
        ("cost_model/analytic/compute", 0.0,
         f"reduction={a['comp_reduction_pct']:.1f}%(paper:75%)"),
        ("cost_model/analytic/encdec", 0.0,
         f"reduction={a['ende_reduction_pct']:.1f}%(paper:78%)"
         f";eta_s={a['eta_s']};b_gh={a['b_gh']}"),
        ("cost_model/analytic/comm", 0.0,
         f"reduction={a['comm_reduction_pct']:.1f}%(paper:78%)"),
        ("cost_model/measured/encrypt", 0.0, f"reduction={m['n_encrypt']:.1f}%"),
        ("cost_model/measured/decrypt", 0.0, f"reduction={m['n_decrypt']:.1f}%"),
        ("cost_model/measured/hom_add", 0.0, f"reduction={m['n_hom_add']:.1f}%"),
        ("cost_model/measured/comm_bytes", 0.0,
         f"reduction={m['comm_bytes']:.1f}%"),
    ]
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
