"""Multi-host party runtime (DESIGN.md §10): payload codec, framing, and
process-per-party training/serving bit-identity against the in-process
Channel oracle — with identical per-tag wire-byte ledgers.

The loopback tests run the full message path (encode -> frame -> decode ->
handler) single-threaded in this process; the socket test spawns a REAL
second OS process for the host and drives the identical protocol over
localhost TCP.
"""

import os
import tempfile

import numpy as np
import pytest

from repro.core import SBTParams, VerticalBoosting
from repro.runtime.transport import (KIND_CTRL, KIND_PROTO, LoopbackEndpoint,
                                     MultiHostRun, TransportError,
                                     decode_frame, decode_payload,
                                     encode_frame, encode_payload)

PROTOCOL_TAGS = {"enc_gh", "assign_sync", "split_infos", "chosen_sid",
                 "assign_mask"}
SERVING_TAGS = {"predict_req", "predict_bits"}


def _data(n=300, d=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d)
    y = (X @ w + 0.3 * rng.normal(0, 1, n) > 0).astype(np.float64)
    return X, y


# ---------------------------------------------------------------------------
# payload codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("obj", [
    None, True, False, 0, -7, 2 ** 62, -(2 ** 100), 2 ** 2048 + 13,
    1.5, -0.0, "tag", b"\x00\xffraw",
    (1, "two", None), [1, [2, [3]]],
    {"a": 1, "b": {"c": (None, 2.5)}, 3: "int-key"},
])
def test_codec_scalars_and_containers(obj):
    assert decode_payload(encode_payload(obj)) == obj


@pytest.mark.parametrize("arr", [
    np.arange(12, dtype=np.int32).reshape(3, 4),
    np.arange(6, dtype=np.int64),
    np.zeros((0, 5), np.float32),
    np.random.default_rng(0).integers(0, 256, (4, 2, 7)).astype(np.uint8),
    np.asarray([[True, False], [False, True]]),
    np.float64(3.25) * np.ones((2, 1)),
    np.asarray(2.5),                    # 0-d
])
def test_codec_ndarrays_exact(arr):
    out = decode_payload(encode_payload(arr))
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_codec_limb_tensor_and_jax_array():
    import jax.numpy as jnp
    limbs = np.random.default_rng(1).integers(0, 256, (5, 2, 9)).astype(
        np.int32)
    out = decode_payload(encode_payload(jnp.asarray(limbs)))
    assert out.dtype == np.int32
    np.testing.assert_array_equal(out, limbs)


def test_codec_object_int_array():
    """Paillier ciphertexts: object arrays of python bigints."""
    rng = np.random.default_rng(2)
    vals = [int(v) ** 7 + 1 for v in rng.integers(2, 2 ** 40, 6)]
    arr = np.asarray(vals, dtype=object).reshape(2, 3)
    out = decode_payload(encode_payload(arr))
    assert out.dtype == object and out.shape == (2, 3)
    assert out.reshape(-1).tolist() == vals


def test_codec_rejects_unserializable():
    with pytest.raises(TransportError):
        encode_payload(object())
    with pytest.raises(TransportError):
        encode_payload(np.asarray([{"not": "an int"}], dtype=object))


def test_codec_rejects_trailing_garbage():
    with pytest.raises(TransportError):
        decode_payload(encode_payload(1) + b"x")


# ---------------------------------------------------------------------------
# framing + endpoints
# ---------------------------------------------------------------------------

def test_frame_roundtrip():
    payload = {"data": np.arange(8, dtype=np.int32), "m": 4}
    frame = encode_frame(KIND_PROTO, "host0", "guest", "split_infos", 1234,
                         payload, seq=42)
    kind, src, dst, tag, seq, nbytes, out = decode_frame(frame)
    assert (kind, src, dst, tag, seq, nbytes) == (
        KIND_PROTO, "host0", "guest", "split_infos", 42, 1234)
    np.testing.assert_array_equal(out["data"], payload["data"])
    ctrl = encode_frame(KIND_CTRL, "guest", "host0", "bye", 0, None)
    assert decode_frame(ctrl)[0] == KIND_CTRL


def test_loopback_endpoint_delivery_and_close():
    a, b = LoopbackEndpoint.pair()
    a.send_bytes(b"frame-1")
    a.send_bytes(b"frame-2")
    assert b.poll()
    assert b.recv_bytes() == b"frame-1"
    assert b.recv_bytes() == b"frame-2"
    assert not b.poll()
    with pytest.raises(TransportError):
        b.recv_bytes()          # empty inbox = protocol desync
    b.close()
    with pytest.raises(TransportError):
        a.send_bytes(b"after close")


# ---------------------------------------------------------------------------
# 2-process-equivalent training/serving vs the in-process oracle (loopback)
# ---------------------------------------------------------------------------

def _bit_identity_run(params, X, y, n_guest_cols, n_hosts=1,
                      transport="loopback"):
    """Train + serve both in-process and over the transport; return both
    runs for assertions."""
    cols = np.array_split(np.arange(X.shape[1] - n_guest_cols) + n_guest_cols,
                          n_hosts)
    Xg = X[:, :n_guest_cols]
    Xh = [X[:, c] for c in cols]
    ref = VerticalBoosting(params).fit(Xg, y, Xh)
    run = MultiHostRun(params, Xh, transport=transport,
                       export_dir=tempfile.mkdtemp())
    model = run.fit(Xg, y)
    return ref, run, model, Xg, Xh


def test_loopback_training_bit_identical_affine_goss_compress():
    """The flagship parity: affine limb ciphertexts, GOSS row selection,
    cipher compression — all protocol features crossing a serialized
    transport — must train bit-identically to the in-process oracle with
    the identical per-tag ledger."""
    X, y = _data(n=400)
    params = SBTParams(n_trees=3, max_depth=3, n_bins=16, cipher="affine",
                       key_bits=256, precision=20, goss=True, seed=3)
    ref, run, model, Xg, Xh = _bit_identity_run(params, X, y, 3)
    try:
        np.testing.assert_array_equal(model.train_score_, ref.train_score_)
        # identical per-tag wire ledger (bytes AND message counts)
        assert run.channel.summary() == ref.channel.summary()
        assert PROTOCOL_TAGS <= set(run.channel.summary())
        # unchanged round-trip shape: one split_infos per (layer, host)
        assert model.stats.n_split_roundtrips == ref.stats.n_split_roundtrips
        # host-side HE work, merged back, matches the shared-Stats oracle
        merged = run.merged_stats()
        for k in ("n_encrypt", "n_decrypt", "n_hom_add", "n_hom_scalar",
                  "n_split_infos", "n_packages", "n_hist_launches"):
            assert getattr(merged, k) == getattr(ref.stats, k), k
        # placement locality is per-process: the remote host re-places the
        # deserialized ciphertexts onto ITS device (one placement per
        # tree), where the in-process run adopts them born-sharded
        assert merged.n_cts_placements == params.n_trees
        # the host party's cipher holds NO private material: decrypting
        # the guest's gradients from inside the host process must be
        # impossible, not merely unexercised
        host_cipher = run.parties[0].cipher
        for attr in ("T_dec", "T_enc", "a_inv_int", "a_int"):
            assert not hasattr(host_cipher, attr), attr
        with pytest.raises(AttributeError):
            host_cipher.decrypt_limbs(run.parties[0].hr.cts[:1, 0])
    finally:
        run.close()


def test_loopback_serving_bit_identical_from_reloaded_exports():
    """Round-batched serving across the transport: each party serves from
    its RELOADED export half, one predict_bits round-trip per host per
    batch, bit-identical scores, identical predict-tag ledgers."""
    X, y = _data(n=350, seed=1)
    params = SBTParams(n_trees=3, max_depth=3, n_bins=16, cipher="affine",
                       key_bits=256, precision=20, seed=5)
    ref, run, model, Xg, Xh = _bit_identity_run(params, X, y, 3)
    try:
        run.serve()
        Xe, _ = _data(n=123, seed=9)
        s_remote = run.predict_score(Xe[:, :3], [Xe[:, 3:]])
        s_ref = ref.predict_score(Xe[:, :3], [Xe[:, 3:]])
        np.testing.assert_array_equal(s_remote, s_ref)
        assert run.channel.summary() == ref.channel.summary()
        assert SERVING_TAGS <= set(run.channel.summary())
        assert (model.stats.n_predict_roundtrips
                == ref.stats.n_predict_roundtrips == 1)
        # counted once, at the guest collect site: folding host stats in
        # must NOT double it
        assert run.merged_stats().n_predict_roundtrips == 1
        # the host process exported its own half; reload it here and check
        # it matches the oracle's in-process export byte for byte
        from repro.serving import PackedEnsemble, load_host
        h_remote = load_host(os.path.join(run.export_dir, "host0"))
        h_ref = PackedEnsemble.from_model(ref).hosts[0]
        np.testing.assert_array_equal(h_remote.table.fid, h_ref.table.fid)
        np.testing.assert_array_equal(h_remote.table.bid, h_ref.table.bid)
        np.testing.assert_array_equal(h_remote.thresholds, h_ref.thresholds)
    finally:
        run.close()


def test_loopback_two_hosts_and_multiclass():
    X, y4 = _data(n=300, d=8, seed=2)
    s = X @ np.ones(8)
    y = ((s > np.quantile(s, 0.33)).astype(float)
         + (s > np.quantile(s, 0.66)).astype(float))
    params = SBTParams(n_trees=2, max_depth=2, n_bins=8,
                       objective="multiclass", n_classes=3)
    ref, run, model, Xg, Xh = _bit_identity_run(params, X, y, 2, n_hosts=2)
    try:
        np.testing.assert_array_equal(model.train_score_, ref.train_score_)
        assert run.channel.summary() == ref.channel.summary()
        run.serve()
        np.testing.assert_array_equal(
            run.predict_score(X[:, :2], staged=True),
            ref.predict_score(Xg, Xh))
    finally:
        run.close()


def test_loopback_paillier_object_arrays_on_the_wire():
    """The python-int oracle cipher: ciphertexts travel as object arrays
    through the codec (real bigints, no limb tensors)."""
    X, y = _data(n=100, seed=4)
    params = SBTParams(n_trees=1, max_depth=2, n_bins=8, cipher="paillier",
                       key_bits=256, precision=16)
    ref, run, model, Xg, Xh = _bit_identity_run(params, X, y, 3)
    try:
        np.testing.assert_array_equal(model.train_score_, ref.train_score_)
        assert run.channel.summary() == ref.channel.summary()
        # the Paillier private key (_lam/_mu) never exists host-side
        host_cipher = run.parties[0].cipher
        assert not hasattr(host_cipher, "_lam")
        assert not hasattr(host_cipher, "_mu")
        with pytest.raises(AttributeError):
            host_cipher.decrypt_to_ints(run.parties[0].hr.cts[:1, 0])
    finally:
        run.close()


def test_unstaged_serving_batch_fails_loudly():
    """Serving eval rows the host never received must raise an actionable
    error, not silently pair eval guest features with training host
    rows."""
    X, y = _data(n=80, seed=7)
    params = SBTParams(n_trees=1, max_depth=2, n_bins=8)
    run = MultiHostRun(params, [X[:, 3:]], transport="loopback",
                       export_dir=tempfile.mkdtemp())
    try:
        run.fit(X[:, :3], y)
        run.serve()
        Xbig, _ = _data(n=200, seed=8)
        # harness guard: neither X_hosts nor staged=True -> refuse before
        # any wire traffic
        with pytest.raises(ValueError, match="not staged"):
            run.predict_score(Xbig[:, :3])
        # host-side guard: staged=True asserted falsely, batch larger
        # than the staged matrix -> the host rejects with an actionable
        # message instead of dying opaquely
        with pytest.raises(TransportError, match="stage"):
            run.predict_score(Xbig[:, :3], staged=True)
        # staged properly, the same batch serves fine
        s = run.predict_score(Xbig[:, :3], [Xbig[:, 3:]])
        assert s.shape == (200,)
    finally:
        run.close()


def test_refit_resets_per_fit_accounting():
    """A second fit() on the same long-lived run must report per-fit
    ledgers and merged stats, not the accumulation of both fits."""
    X, y = _data(n=150, seed=11)
    params = SBTParams(n_trees=1, max_depth=2, n_bins=8)
    ref = VerticalBoosting(params).fit(X[:, :3], y, [X[:, 3:]])
    run = MultiHostRun(params, [X[:, 3:]], transport="loopback")
    try:
        run.fit(X[:, :3], y)
        model2 = run.fit(X[:, :3], y)           # refit on the same run
        np.testing.assert_array_equal(model2.train_score_,
                                      ref.train_score_)
        assert run.channel.summary() == ref.channel.summary()
        merged = run.merged_stats()
        assert merged.n_hom_add == ref.stats.n_hom_add
        assert merged.n_hist_launches == ref.stats.n_hist_launches
    finally:
        run.close()


def test_binned_serving_refuses_remote_hosts():
    """predict_score_binned would silently ignore caller bins for a
    remote host (its process bins its own staged rows) — it must refuse."""
    X, y = _data(n=100, seed=12)
    params = SBTParams(n_trees=1, max_depth=2, n_bins=8)
    run = MultiHostRun(params, [X[:, 3:]], transport="loopback")
    try:
        run.fit(X[:, :3], y)
        pred = run.serve()
        with pytest.raises(ValueError, match="in-process halves"):
            pred.predict_score_binned(np.zeros((4, 3), np.int32),
                                      [np.zeros((4, 3), np.int32)])
    finally:
        run.close()


def test_remote_model_refuses_inprocess_packing():
    X, y = _data(n=120, seed=6)
    params = SBTParams(n_trees=1, max_depth=2, n_bins=8)
    run = MultiHostRun(params, [X[:, 3:]], transport="loopback")
    try:
        model = run.fit(X[:, :3], y)
        from repro.serving import PackedEnsemble
        with pytest.raises(ValueError, match="remote processes"):
            PackedEnsemble.from_model(model)
        # the legacy predict_tree oracle reads host tables the guest
        # process does not have: guided error, not a bare KeyError
        with pytest.raises(ValueError, match="remote processes"):
            model.predict_score(X[:, :3], [None], packed=False)
    finally:
        run.close()


# ---------------------------------------------------------------------------
# the real thing: one OS process per party over localhost TCP
# ---------------------------------------------------------------------------

def test_socket_two_process_training_and_serving_bit_identical():
    """Forced-2-process run (guest here, host spawned) over the
    length-prefixed socket transport: training AND packed serving are
    bit-identical to the in-process Channel run, with identical per-tag
    wire-byte ledgers and unchanged round-trip counts — and the socket
    moved at least as many framed bytes as the analytic ledger counts."""
    X, y = _data(n=250)
    params = SBTParams(n_trees=2, max_depth=3, n_bins=16, cipher="plain")
    Xg, Xh = X[:, :3], [X[:, 3:]]
    ref = VerticalBoosting(params).fit(Xg, y, Xh)
    run = MultiHostRun(params, Xh, transport="socket",
                       export_dir=tempfile.mkdtemp(), timeout=300.0)
    try:
        model = run.fit(Xg, y)
        np.testing.assert_array_equal(model.train_score_, ref.train_score_)
        assert run.channel.summary() == ref.channel.summary()
        assert model.stats.n_split_roundtrips == ref.stats.n_split_roundtrips

        run.serve()
        np.testing.assert_array_equal(run.predict_score(Xg, staged=True),
                                      ref.predict_score(Xg, Xh))
        assert (model.stats.n_predict_roundtrips
                == ref.stats.n_predict_roundtrips == 1)
        assert (PROTOCOL_TAGS | SERVING_TAGS) <= set(run.channel.summary())

        # framed socket traffic >= analytic guest->host ledger bytes (the
        # ledger counts protocol fidelity; frames add headers and the
        # in-memory limb layout)
        for tag in ("enc_gh", "assign_sync", "chosen_sid", "predict_req"):
            assert run.channel.tx_bytes[tag] > run.channel.totals[tag]
        assert run.ping() < 5.0
        merged = run.merged_stats()
        assert merged.n_hom_add == ref.stats.n_hom_add
        assert merged.n_hist_launches == ref.stats.n_hist_launches
    finally:
        run.close()
