"""Fuzzing the wire codec and framing layer (DESIGN.md §11).

Contract under fuzz: for ANY byte string — truncated, bit-flipped,
adversarial length prefixes, garbage type bytes — ``decode_payload`` and
``decode_frame`` either return a value or raise :class:`TransportError`.
Never a different exception type, never a hang, never an allocation
proportional to a forged length field rather than to the actual buffer.

Runs in two modes: seeded-random fuzz loops always run (no external
dependency); property-based tests additionally run wherever `hypothesis`
is installed (the CI chaos job), and are skipped cleanly where it is not.
"""

import struct
import time

import numpy as np
import pytest

from repro.analysis import schema as wire_schema
from repro.runtime.transport import (KIND_CTRL, KIND_PROTO, LoopbackEndpoint,
                                     TransportChannel, TransportError,
                                     conformance_check, decode_frame,
                                     decode_payload, encode_frame,
                                     encode_payload)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_U32 = struct.Struct("!I")

SAMPLE_PAYLOADS = [
    None, True, -7, 2 ** 100, 1.5, "tag", b"\x00\xffraw",
    (1, "two", None), [1, [2, [3]]], {"a": 1, "b": (None, 2.5)},
    np.arange(12, dtype=np.int32).reshape(3, 4),
    np.asarray([10 ** 40, -3], dtype=object),
    {"ids": np.arange(5), "k": 2, "blob": b"\x01" * 33},
]


def _contract(fn, buf):
    """Decode must return or raise TransportError — nothing else."""
    try:
        fn(buf)
    except TransportError:
        pass
    except Exception as e:          # noqa: BLE001
        pytest.fail(f"{fn.__name__} raised {type(e).__name__} ({e!r}) on "
                    f"{buf[:40]!r}... — fuzz contract is TransportError only")


# ---------------------------------------------------------------------------
# always-on seeded fuzz (no external deps)
# ---------------------------------------------------------------------------

def test_random_bytes_decode_contract():
    rng = np.random.default_rng(0xC0DEC)
    for _ in range(400):
        n = int(rng.integers(0, 200))
        buf = rng.integers(0, 256, n).astype(np.uint8).tobytes()
        _contract(decode_payload, buf)
        _contract(decode_frame, buf)


def test_every_truncation_raises_not_crashes():
    """A strict prefix of a valid encoding can never decode cleanly: the
    parse is deterministic and consumes the exact encoding, so every cut
    lands mid-value and must surface as TransportError."""
    for obj in SAMPLE_PAYLOADS:
        buf = encode_payload(obj)
        cuts = range(len(buf)) if len(buf) < 64 else \
            sorted({0, 1, len(buf) // 2, len(buf) - 1}
                   | set(int(i) for i in
                         np.random.default_rng(7).integers(0, len(buf), 16)))
        for cut in cuts:
            with pytest.raises(TransportError):
                decode_payload(buf[:cut])


def test_truncated_frames_raise():
    for obj in SAMPLE_PAYLOADS:
        frame = encode_frame(KIND_PROTO, "guest", "host0", "enc_gh", 64,
                             obj, seq=3)
        for cut in (0, 1, 5, len(frame) // 2, len(frame) - 1):
            with pytest.raises(TransportError):
                decode_frame(frame[:cut])


def test_byte_flip_fuzz_frames():
    """Flipped bits anywhere in a frame either still decode (a flip in
    payload VALUE bytes yields a different value, which is the ledger /
    dedup layer's problem) or raise TransportError — never an internal
    numpy/struct/unicode error, never a hang."""
    rng = np.random.default_rng(0xF11B)
    frames = [encode_frame(KIND_PROTO, "guest", "host0", "assign_sync",
                           128, obj, seq=9) for obj in SAMPLE_PAYLOADS]
    t0 = time.monotonic()
    for _ in range(300):
        frame = bytearray(frames[int(rng.integers(len(frames)))])
        for _ in range(int(rng.integers(1, 5))):
            frame[int(rng.integers(len(frame)))] ^= \
                1 << int(rng.integers(8))
        _contract(decode_frame, bytes(frame))
    assert time.monotonic() - t0 < 30.0


def test_bad_kind_and_type_bytes():
    frame = bytearray(encode_frame(KIND_CTRL, "a", "b", "t", 0, None))
    frame[0] = 0x7F
    with pytest.raises(TransportError, match="kind"):
        decode_frame(bytes(frame))
    for t in (b"Z", b"\x00", b"\xff"):
        with pytest.raises(TransportError, match="type byte|malformed"):
            decode_payload(t + b"\x00" * 16)


def test_absurd_length_prefixes_bounded():
    """Forged length/count/shape fields must be answered with a raise in
    bounded time and bounded memory — the decoder may only allocate in
    proportion to the bytes actually present."""
    adversarial = [
        b"l" + _U32.pack(0xFFFFFFFF),                       # 4B-element list
        b"u" + _U32.pack(0xFFFFFFFF),
        b"d" + _U32.pack(0xFFFFFFFF),
        b"s" + _U32.pack(0xFFFFFFFF) + b"x" * 8,            # 4GB string
        b"b" + _U32.pack(0x7FFFFFFF),
        b"I\x00" + _U32.pack(0xFFFFFFFF),                   # 4GB bigint
        # float64 array claiming 2^60 elements in 8 header bytes
        encode_payload("x")[:0] + b"a" + _U32.pack(3) + b"<f8"
        + bytes([1]) + struct.pack("!q", 1 << 60),
        # object array with a forged 10^6-element shape over a 2-byte body
        b"O" + bytes([1]) + struct.pack("!q", 10 ** 6) + b"\x00\x00",
        # negative dimension
        b"a" + _U32.pack(3) + b"<f8" + bytes([2])
        + struct.pack("!qq", 4, -4),
    ]
    for buf in adversarial:
        t0 = time.monotonic()
        with pytest.raises(TransportError):
            decode_payload(buf)
        assert time.monotonic() - t0 < 2.0, buf[:16]


def test_roundtrip_seeded_random_payloads():
    """Structured roundtrip fuzz: random nested payloads survive
    encode -> decode exactly."""
    rng = np.random.default_rng(0x5EED)

    def gen(depth):
        kind = int(rng.integers(0, 10 if depth < 3 else 7))
        if kind == 0:
            return None
        if kind == 1:
            return bool(rng.integers(2))
        if kind == 2:
            return int(rng.integers(-2 ** 62, 2 ** 62))
        if kind == 3:
            return int(rng.integers(-2 ** 40, 2 ** 40)) ** 5    # bigint
        if kind == 4:
            return float(rng.normal())
        if kind == 5:
            return "".join(chr(int(c)) for c in
                           rng.integers(32, 0x2FF, rng.integers(0, 12)))
        if kind == 6:
            return rng.integers(0, 256, int(rng.integers(0, 20))) \
                .astype(np.uint8).tobytes()
        if kind == 7:
            return [gen(depth + 1) for _ in range(int(rng.integers(0, 4)))]
        if kind == 8:
            return tuple(gen(depth + 1)
                         for _ in range(int(rng.integers(0, 4))))
        return {f"k{i}": gen(depth + 1)
                for i in range(int(rng.integers(0, 4)))}

    for _ in range(200):
        obj = gen(0)
        assert decode_payload(encode_payload(obj)) == obj


# ---------------------------------------------------------------------------
# hypothesis properties (run where hypothesis is installed; CI chaos job)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(st.binary(max_size=512))
    @settings(max_examples=300, deadline=2000)
    def test_hyp_arbitrary_bytes_decode_contract(buf):
        _contract(decode_payload, buf)
        _contract(decode_frame, buf)

    _payloads = st.recursive(
        st.none() | st.booleans() | st.integers() |
        st.floats(allow_nan=False) |
        st.text(max_size=20) | st.binary(max_size=20),
        lambda inner: st.lists(inner, max_size=4)
        | st.dictionaries(st.text(max_size=8), inner, max_size=4),
        max_leaves=12)

    @given(_payloads)
    @settings(max_examples=200, deadline=2000)
    def test_hyp_payload_roundtrip(obj):
        assert decode_payload(encode_payload(obj)) == obj

    @given(_payloads, st.integers(min_value=0, max_value=10 ** 6))
    @settings(max_examples=150, deadline=2000)
    def test_hyp_truncation_always_raises(obj, cut_seed):
        buf = encode_payload(obj)
        if len(buf) < 2:
            return
        with pytest.raises(TransportError):
            decode_payload(buf[:cut_seed % (len(buf) - 1)])

    @given(_payloads, st.data())
    @settings(max_examples=150, deadline=2000)
    def test_hyp_frame_flip_contract(obj, data):
        frame = bytearray(encode_frame(KIND_PROTO, "guest", "host0",
                                       "enc_gh", 7, obj, seq=1))
        for _ in range(data.draw(st.integers(1, 4))):
            i = data.draw(st.integers(0, len(frame) - 1))
            frame[i] ^= 1 << data.draw(st.integers(0, 7))
        _contract(decode_frame, bytes(frame))

else:
    def test_hypothesis_unavailable_marker():
        pytest.skip("hypothesis not installed: property-based variants "
                    "skipped (seeded fuzz loops above still ran)")


# ---------------------------------------------------------------------------
# wire-schema conformance (opt-in runtime mode; DESIGN.md §15)
#
# Contract: with conformance ON, every schema-conformant frame still
# encodes and decodes exactly as before (the mode never perturbs payload
# bytes), and every NON-conformant frame raises TransportError at ship
# time -- never a different exception, never a silent pass.
# ---------------------------------------------------------------------------

@pytest.fixture
def conformance_on():
    prev = wire_schema.conformance_enabled()
    wire_schema.set_conformance(True)
    yield
    wire_schema.set_conformance(prev)


def _conformant(spec):
    """A minimal payload satisfying one registered tag's shape class."""
    if spec.payload == wire_schema.P_NONE:
        return None
    if spec.payload == wire_schema.P_STR:
        return "a peer's dying words"
    if spec.payload == wire_schema.P_ARRAY:
        return np.arange(4, dtype=np.uint8)
    if spec.payload == wire_schema.P_DICT:
        return {k: 0 for k in sorted(spec.requires)}
    return b"unconstrained"                     # P_ANY


def _endpoints(spec):
    return (("guest", "host0") if spec.direction == wire_schema.G2H
            else ("host0", "guest"))


def test_every_registered_tag_roundtrips_under_conformance(conformance_on):
    """All 26 registered tags: a conformant frame passes the ship-time
    check AND survives the codec bit-for-bit."""
    assert wire_schema.REGISTRY, "schema registry is empty?"
    for tag, spec in sorted(wire_schema.REGISTRY.items()):
        src, dst = _endpoints(spec)
        payload = _conformant(spec)
        conformance_check(spec.kind, src, dst, tag, payload)  # must not raise
        frame = encode_frame(spec.kind, src, dst, tag, 7, payload, seq=3)
        kind, fsrc, fdst, ftag, seq, nbytes, out = decode_frame(frame)
        assert (kind, fsrc, fdst, ftag, seq, nbytes) == (
            spec.kind, src, dst, tag, 3, 7)
        if isinstance(payload, dict):
            assert set(out) == set(payload)
        elif spec.payload == wire_schema.P_ARRAY:
            np.testing.assert_array_equal(out, payload)
        elif spec.payload != wire_schema.P_ANY:
            assert out == payload


def _violations():
    """(kind, src, dst, tag, payload) tuples that each break the schema
    in exactly one way: wrong kind, reversed direction, wrong payload
    type, or a missing required key."""
    for tag, spec in sorted(wire_schema.REGISTRY.items()):
        src, dst = _endpoints(spec)
        good = _conformant(spec)
        yield (1 - spec.kind, src, dst, tag, good)            # wrong kind
        yield (spec.kind, dst, src, tag, good)                # wrong direction
        if spec.payload == wire_schema.P_NONE:
            yield (spec.kind, src, dst, tag, "not-none")
        elif spec.payload == wire_schema.P_STR:
            yield (spec.kind, src, dst, tag, None)
        elif spec.payload == wire_schema.P_ARRAY:
            yield (spec.kind, src, dst, tag, {"not": "a tensor"})
        elif spec.payload == wire_schema.P_DICT:
            yield (spec.kind, src, dst, tag, "not-a-dict")
            if spec.requires:
                short = dict(good)
                short.pop(sorted(spec.requires)[0])
                yield (spec.kind, src, dst, tag, short)
    # unregistered tags are refused regardless of payload
    yield (KIND_PROTO, "guest", "host0", "gh_debug", None)
    yield (KIND_CTRL, "host0", "guest", "totally-made-up", {"x": 1})


def test_nonconformant_frames_raise_transport_error(conformance_on):
    for kind, src, dst, tag, payload in _violations():
        with pytest.raises(TransportError):
            conformance_check(kind, src, dst, tag, payload)


def test_conformance_off_is_a_noop():
    """With the mode off, the check never fires -- even for frames the
    schema would refuse (zero-cost default; production opt-in only)."""
    prev = wire_schema.conformance_enabled()
    wire_schema.set_conformance(False)
    try:
        for kind, src, dst, tag, payload in _violations():
            conformance_check(kind, src, dst, tag, payload)
    finally:
        wire_schema.set_conformance(prev)


def test_codec_stays_schema_agnostic():
    """The codec itself never enforces the schema: an unregistered-tag
    frame still roundtrips (decode tolerance is a framing property), and
    only the ship-time check refuses it."""
    frame = encode_frame(KIND_PROTO, "guest", "host0", "gh_debug", 0,
                         {"x": 1}, seq=9)
    assert decode_frame(frame)[3] == "gh_debug"


def test_ship_time_conformance_blocks_the_socket(conformance_on):
    """End-to-end: a non-conformant send through a real TransportChannel
    raises BEFORE any bytes reach the endpoint; a conformant control
    frame still flows."""
    a, b = LoopbackEndpoint.pair()
    ch = TransportChannel("guest", {"host0": a}, timeout=5.0)
    with pytest.raises(TransportError):
        ch.send("guest", "host0", "gh_debug", np.zeros(3, np.uint8), 3)
    assert not b.poll(), "non-conformant frame reached the wire"
    ch.control_send("host0", wire_schema.PING, {"t": 0.0})
    assert b.poll()
    kind, _, _, tag, _, _, payload = decode_frame(b.recv_bytes())
    assert (kind, tag) == (KIND_CTRL, wire_schema.PING)
    assert payload == {"t": 0.0}
