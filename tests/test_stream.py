"""Out-of-core data path (DESIGN.md §13): streaming quantile binning,
block-wise frontier accumulation, chunked encrypt->ship.

The load-bearing claim is bit-identity: a run with ``row_block > 0`` must
produce byte-for-byte the trees, scores, and per-tag wire-byte totals of
the monolithic run — over the in-process, loopback, and socket transports
— while its peak resident footprint scales with the block size instead of
the row count (asserted through the ``Stats`` peak gauges).  Streaming
binning pins merged-sketch thresholds against the monolithic exact
quantile fit, bit-exact below the sketch capacity.
"""

import pickle

import numpy as np
import pytest

import jax

from repro.core import SBTParams, VerticalBoosting
from repro.core.binning import bin_features, bin_features_stream
from repro.data.pipeline import (RowBlocks, synthetic_tabular,
                                 synthetic_tabular_stream)
from repro.kernels.binning import (fit_quantile_thresholds, fit_sketch,
                                   merge_sketch, sketch_thresholds)
from repro.runtime.transport import MultiHostRun

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count>=2")


def _data(n=300, d=10, seed=3):
    X, y = synthetic_tabular(n, d, seed=seed)
    return X[:, :4], [X[:, 4:]], y


def _sigs(model):
    return [t.signature() for t in model.trees]


def _fit(row_block, Xg, Xh, y, **kw):
    base = dict(n_trees=2, max_depth=3, n_bins=16, cipher="plain",
                key_bits=512, seed=1, row_block=row_block)
    base.update(kw)
    m = VerticalBoosting(SBTParams(**base))
    m.fit(Xg, y, Xh)
    return m


# ---------------------------------------------------------------------------
# streaming binning: mergeable sketch vs monolithic exact fit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,seed", [((999, 5), 0), ((64, 3), 1),
                                        ((2000, 2), 2)])
def test_sketch_thresholds_match_exact_fit(shape, seed):
    """Below capacity the merged sketch IS the exact empirical CDF, so its
    thresholds must be bit-identical to ``fit_quantile_thresholds`` —
    including duplicate-heavy and constant features."""
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, shape).astype(np.float32)
    X[:, 0] = np.round(X[:, 0])          # heavy duplicates
    if shape[1] > 2:
        X[:, 2] = 1.5                    # constant feature
    for n_bins in (8, 32):
        exact = fit_quantile_thresholds(X, n_bins)
        blocks = RowBlocks.from_array(X, 100)
        sk = None
        for _, Xb in blocks:
            part = fit_sketch(Xb, capacity=8192)
            sk = part if sk is None else merge_sketch(sk, part, 8192)
        thr = sketch_thresholds(sk, n_bins)
        assert thr.dtype == exact.dtype
        assert np.array_equal(thr, exact, equal_nan=True)


def test_sketch_merge_order_invariant():
    rng = np.random.default_rng(7)
    X = rng.normal(0, 2, (900, 4)).astype(np.float32)
    parts = [fit_sketch(X[i::3], 8192) for i in range(3)]
    a = merge_sketch(merge_sketch(parts[0], parts[1], 8192), parts[2], 8192)
    b = merge_sketch(parts[2], merge_sketch(parts[1], parts[0], 8192), 8192)
    for fa, fb in zip(a.features, b.features):
        assert np.array_equal(fa.values, fb.values)
        assert np.array_equal(fa.counts, fb.counts)


def test_sketch_compression_respects_capacity():
    rng = np.random.default_rng(11)
    X = rng.normal(0, 1, (5000, 1)).astype(np.float32)
    sk = fit_sketch(X, capacity=128)
    f = sk.features[0]
    assert len(f.values) <= 128
    assert np.all(np.diff(f.values) > 0)             # sorted distinct
    assert int(f.counts.sum()) == 5000               # mass preserved
    thr = sketch_thresholds(sk, 16)
    finite = thr[0][np.isfinite(thr[0])]
    assert np.all(np.diff(finite) > 0)


def test_bin_features_stream_matches_monolithic():
    rng = np.random.default_rng(5)
    X = rng.normal(0, 1, (700, 6)).astype(np.float32)
    X[rng.random(X.shape) < 0.3] = 0.0
    for sparse in (False, True):
        mono = bin_features(X, 16, sparse=sparse)
        stream = bin_features_stream(RowBlocks.from_array(X, 128), 16,
                                     sparse=sparse)
        assert stream.bins.dtype == np.int8          # compact resident form
        assert np.array_equal(stream.bins.astype(np.int32), mono.bins)
        assert np.array_equal(stream.thresholds, mono.thresholds,
                              equal_nan=True)
        if sparse:
            assert np.array_equal(stream.zero_bins, mono.zero_bins)
            assert np.array_equal(stream.zero_mask, mono.zero_mask)


# ---------------------------------------------------------------------------
# RowBlocks / synthetic stream source
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prefetch", [0, 2])
def test_row_blocks_iterates_in_order(prefetch):
    X = np.arange(23 * 3, dtype=np.float32).reshape(23, 3)
    rb = RowBlocks.from_array(X, 5)
    rb.prefetch = prefetch
    assert rb.n_blocks == 5
    for rep in range(2):                 # re-iterable (two binning passes)
        got = list(rb)
        assert [s for s, _ in got] == [0, 5, 10, 15, 20]
        assert np.array_equal(np.concatenate([b for _, b in got]), X)


def test_synthetic_tabular_stream_deterministic():
    blocks, y = synthetic_tabular_stream(500, 6, block=128, seed=4)
    blocks2, y2 = synthetic_tabular_stream(500, 6, block=64, seed=4)
    assert np.array_equal(y, y2)         # labels don't depend on block size
    X1 = np.concatenate([b for _, b in blocks])
    X2 = np.concatenate([b for _, b in blocks2])
    assert X1.shape == (500, 6)
    assert np.array_equal(X1, X2)
    assert set(np.unique(y)) <= {0.0, 1.0}


# ---------------------------------------------------------------------------
# satellite: BinnedData pickles without device buffers
# ---------------------------------------------------------------------------

def test_binned_data_pickle_drops_device_cache():
    X = np.random.default_rng(0).normal(0, 1, (50, 4)).astype(np.float32)
    bd = bin_features(X, 8)
    dev = bd.device_thresholds()
    assert bd.device_thresholds() is dev             # cached
    assert bd.__getstate__()["_thr_dev"] is None     # never pickled
    rt = pickle.loads(pickle.dumps(bd))
    assert rt._thr_dev is None                       # no buffer crossed
    d2 = rt.device_thresholds()
    assert rt.device_thresholds() is d2              # re-cached lazily
    assert np.array_equal(np.asarray(d2), np.asarray(dev))


# ---------------------------------------------------------------------------
# tentpole: streaming == monolithic bit-identity (in-process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cipher", ["plain", "affine"])
@pytest.mark.parametrize("objective", ["binary", "multiclass"])
def test_stream_bit_identical_inprocess(cipher, objective):
    Xg, Xh, y = _data()
    kw = dict(cipher=cipher)
    if objective == "multiclass":
        kw.update(objective="multiclass", n_classes=3)
        y = (np.abs(np.concatenate([Xg, Xh[0]], axis=1)[:, 0] * 3)
             .astype(int) % 3)
    mono = _fit(0, Xg, Xh, y, **kw)
    stream = _fit(64, Xg, Xh, y, **kw)
    assert _sigs(mono) == _sigs(stream)
    assert np.array_equal(mono.train_score_, stream.train_score_)
    # per-tag BYTE totals are identical; message counts differ (enc_gh
    # ships one frame per block), so compare bytes, not whole summaries
    s0, s1 = mono.channel.summary(), stream.channel.summary()
    assert set(s0) == set(s1)
    for tag in s0:
        assert s0[tag]["bytes"] == s1[tag]["bytes"], tag
    n_blocks = -(-len(y) // 64)
    assert s1["enc_gh"]["msgs"] == s0["enc_gh"]["msgs"] * n_blocks


@pytest.mark.parametrize("kw", [dict(goss=True, top_rate=0.3,
                                     other_rate=0.2),
                                dict(sparse=True),
                                dict(forest_size=2),
                                dict(pipeline=True),
                                dict(packing=False),
                                dict(compression=False)])
def test_stream_bit_identical_toggles(kw):
    Xg, Xh, y = _data()
    if kw.get("sparse"):
        Xg = Xg.copy()
        Xg[np.abs(Xg) < 0.4] = 0.0
    mono = _fit(0, Xg, Xh, y, **kw)
    stream = _fit(64, Xg, Xh, y, **kw)
    assert _sigs(mono) == _sigs(stream)
    assert np.array_equal(mono.train_score_, stream.train_score_)
    s0, s1 = mono.channel.summary(), stream.channel.summary()
    for tag in s0:
        assert s0[tag]["bytes"] == s1[tag]["bytes"], tag


def test_stream_gate_small_batch_stays_monolithic():
    """row_block larger than the batch: the monolithic fast path runs —
    one enc_gh frame per tree, same gauges as an untouched run."""
    Xg, Xh, y = _data(n=200)
    m = _fit(4096, Xg, Xh, y)
    m0 = _fit(0, Xg, Xh, y)
    assert m.channel.summary()["enc_gh"]["msgs"] == 2   # one per tree
    assert m.stats.peak_block_bytes == m0.stats.peak_block_bytes
    assert m.stats.peak_cts_bytes == m0.stats.peak_cts_bytes
    assert _sigs(m) == _sigs(m0)


# ---------------------------------------------------------------------------
# satellite: peak gauges — stream is O(block), monolithic O(rows)
# ---------------------------------------------------------------------------

def test_peak_gauges_block_bounded():
    Xg1, Xh1, y1 = _data(n=300, seed=3)
    Xg2, Xh2, y2 = _data(n=600, seed=3)
    mono1 = _fit(0, Xg1, Xh1, y1)
    mono2 = _fit(0, Xg2, Xh2, y2)
    st1 = _fit(50, Xg1, Xh1, y1)
    st2 = _fit(50, Xg2, Xh2, y2)
    # monolithic ciphertext residency scales with rows
    assert mono2.stats.peak_cts_bytes == 2 * mono1.stats.peak_cts_bytes
    # streamed residency is bounded by the block, not the row count
    assert st1.stats.peak_cts_bytes == st2.stats.peak_cts_bytes
    assert st2.stats.peak_cts_bytes < mono2.stats.peak_cts_bytes
    assert st1.stats.peak_block_bytes == st2.stats.peak_block_bytes
    assert st1.stats.peak_block_bytes > 0
    # the streamed per-launch footprint is exactly one block's worth
    width = mono1.cipher.hist_width
    assert st1.stats.peak_cts_bytes == 50 * 1 * width * 4


# ---------------------------------------------------------------------------
# tentpole: bit-identity over real transports
# ---------------------------------------------------------------------------

def _run_transport(p, Xg, Xh, y, transport):
    run = MultiHostRun(p, Xh, transport=transport)
    try:
        model = run.fit(Xg, y)
        return _sigs(model), model.train_score_, run.channel.summary()
    finally:
        run.close()


@pytest.mark.parametrize("kw", [dict(), dict(cipher="affine",
                                             pipeline=True)])
def test_stream_bit_identical_loopback(kw):
    Xg, Xh, y = _data()
    base = dict(n_trees=2, max_depth=3, n_bins=16, cipher="plain",
                key_bits=512, seed=1, row_block=64)
    base.update(kw)
    p = SBTParams(**base)
    mono = VerticalBoosting(p)
    mono.fit(Xg, y, Xh)
    sigs, score, summary = _run_transport(p, Xg, Xh, y, "loopback")
    assert _sigs(mono) == sigs
    assert np.array_equal(mono.train_score_, score)
    # the streaming run's ledger must be identical ACROSS transports
    assert mono.channel.summary() == summary


def test_stream_bit_identical_socket():
    Xg, Xh, y = _data(n=200)
    p = SBTParams(n_trees=2, max_depth=3, n_bins=16, cipher="plain",
                  key_bits=512, seed=1, row_block=64)
    mono = VerticalBoosting(p)
    mono.fit(Xg, y, Xh)
    sigs, score, summary = _run_transport(p, Xg, Xh, y, "socket")
    assert _sigs(mono) == sigs
    assert np.array_equal(mono.train_score_, score)
    assert mono.channel.summary() == summary


# ---------------------------------------------------------------------------
# satellite: mesh-sharded compress shuffle
# ---------------------------------------------------------------------------

@multi_device
@pytest.mark.parametrize("cipher_name", ["plain", "affine"])
def test_sharded_compress_parity(cipher_name):
    from repro.core import compress as compress_mod
    from repro.core.he import get_cipher
    from repro.launch.mesh import make_gbdt_mesh
    mesh = make_gbdt_mesh()
    dd = dict(mesh.shape).get("data", 1)
    kw = ({"bits": 512} if cipher_name == "plain"
          else {"key_bits": 512, "seed": 0})
    cipher = get_cipher(cipher_name, **kw)
    rng = np.random.default_rng(0)
    eta, b_slot = 3, 40
    for n in (7, 256 * dd * 3 + 5):      # below / above the gate
        cts = rng.integers(0, 256, (n, cipher.Ln)).astype(np.int32)
        p0, s0 = compress_mod.compress_batch(cipher, cts, eta, b_slot)
        p1, s1 = compress_mod.compress_batch(cipher, cts, eta, b_slot,
                                             mesh=mesh)
        assert np.array_equal(np.asarray(p0), np.asarray(p1))
        assert np.array_equal(s0, s1)


@multi_device
def test_stream_bit_identical_on_mesh():
    """Streamed accumulation under a live mesh: per-block sharded dispatch
    must still reproduce the single-device monolithic run bit-for-bit."""
    from repro.launch.mesh import make_gbdt_mesh
    Xg, Xh, y = _data(n=400)
    mono = _fit(0, Xg, Xh, y)
    stream = _fit(64, Xg, Xh, y, mesh=make_gbdt_mesh())
    assert _sigs(mono) == _sigs(stream)
    assert np.array_equal(mono.train_score_, stream.train_score_)
