"""Layer-batched histogram path: kernel parity, engine parity, e2e parity.

The batched pipeline (one kernel launch / reduce / cumsum / round-trip per
tree layer) must be bit-identical to the per-node path it replaced; these
tests pin that at every level.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import LocalGBDT, SBTParams, VerticalBoosting
from repro.core.binning import bin_features
from repro.core.he import get_cipher
from repro.core.histogram import CipherHistogram
from repro.core.party import Stats
from repro.kernels.histogram import (hist_ref, layer_ciphertext_histogram,
                                     layer_count_histogram, layer_hist_ref)

# shapes chosen to exercise non-divisible instance / feature / node blocks
LAYER_SHAPES = [(300, 5, 16, 32, 3), (257, 9, 8, 16, 1), (64, 3, 4, 8, 9),
                (1024, 17, 12, 32, 5), (1, 1, 4, 4, 2)]


@pytest.mark.parametrize("n_i,n_f,L,n_b,n_n", LAYER_SHAPES)
def test_layer_kernel_vs_ref_and_per_node_oracle(n_i, n_f, L, n_b, n_n):
    rng = np.random.default_rng(n_i * 7 + n_n)
    bins = rng.integers(0, n_b, (n_i, n_f)).astype(np.int32)
    bins[rng.random((n_i, n_f)) < 0.15] = -1          # masked (sparse) cells
    slot = rng.integers(-1, n_n, n_i).astype(np.int32)  # -1 = no direct node
    cts = rng.integers(0, 256, (n_i, L)).astype(np.int32)
    out = np.asarray(layer_ciphertext_histogram(bins, slot, cts, n_n, n_b,
                                                use_pallas=True))
    ref = np.asarray(layer_hist_ref(jnp.asarray(bins), jnp.asarray(slot),
                                    jnp.asarray(cts), n_n, n_b))
    np.testing.assert_array_equal(out, ref)
    # each node slice equals the single-node oracle on its masked rows
    for k in range(n_n):
        masked = np.where(slot[:, None] == k, bins, -1)
        per_node = np.asarray(hist_ref(jnp.asarray(masked),
                                       jnp.asarray(cts), n_b))
        np.testing.assert_array_equal(out[k], per_node)


def test_layer_kernel_all_masked():
    bins = np.full((50, 4), -1, np.int32)
    slot = np.zeros(50, np.int32)
    cts = np.random.default_rng(0).integers(0, 256, (50, 8)).astype(np.int32)
    out = np.asarray(layer_ciphertext_histogram(bins, slot, cts, 2, 8))
    assert (out == 0).all()


def test_layer_count_histogram_matches_bincount():
    rng = np.random.default_rng(3)
    n_i, n_f, n_b, n_n = 400, 6, 16, 4
    bins = rng.integers(0, n_b, (n_i, n_f)).astype(np.int32)
    slot = rng.integers(-1, n_n, n_i).astype(np.int32)
    cnt = np.asarray(layer_count_histogram(bins, slot, n_n, n_b))
    for k in range(n_n):
        for f in range(n_f):
            expect = np.bincount(bins[slot == k, f], minlength=n_b)
            np.testing.assert_array_equal(cnt[k, f], expect)


@pytest.mark.parametrize("cipher_name,kw", [
    ("plain", {"bits": 256}),
    ("affine", {"key_bits": 192, "seed": 7}),
])
def test_layer_histograms_match_per_node_engine(cipher_name, kw):
    """Batched direct + lazy-subtract accumulation vs node_histogram /
    subtract, for both limb ciphers, through the frontier state."""
    from repro.core.frontier import CipherFrontier

    rng = np.random.default_rng(11)
    n, n_f, n_b = 160, 4, 8
    cipher = get_cipher(cipher_name, **kw)
    X = rng.normal(0, 1, (n, n_f)).astype(np.float32)
    data = bin_features(X, n_b)
    pts = rng.integers(0, 2**40, n)
    cts = np.asarray(cipher.encrypt_ints([int(v) for v in pts]))
    cts = cts.reshape(n, 1, -1)

    engine = CipherHistogram(cipher, n_b, stats=Stats())
    frontier = CipherFrontier(engine, data, cts)
    # one parent node split into two children; right child by subtraction
    parent_rows = np.arange(n)
    left_rows = np.arange(n // 3)
    right_rows = np.arange(n // 3, n)
    cache = {0: engine.node_histogram(data, cts, parent_rows)}
    frontier.store(0, *cache[0])

    batched = frontier.layer_histograms(
        {1: left_rows, 2: right_rows}, direct=[1], subtract=[(2, 0, 1)])
    h1, c1 = engine.node_histogram(data, cts, left_rows)
    h2, c2 = engine.subtract(cache[0], (h1, c1))
    np.testing.assert_array_equal(np.asarray(batched[1][0]), np.asarray(h1))
    np.testing.assert_array_equal(batched[1][1], c1)
    np.testing.assert_array_equal(np.asarray(batched[2][0]), np.asarray(h2))
    np.testing.assert_array_equal(batched[2][1], c2)
    assert engine.stats.n_hist_launches >= 1
    # decrypted bin sums must equal plaintext bin sums
    from repro.core.he import limbs
    dec = limbs.to_pyints(np.asarray(
        cipher.decrypt_limbs(jnp.asarray(batched[2][0]))
        if cipher_name == "affine" else batched[2][0]))
    dec = np.asarray(dec, dtype=object).reshape(n_f, n_b)
    for f in range(n_f):
        for b in range(n_b):
            expect = int(sum(int(v) for v, bb in
                             zip(pts[right_rows], data.bins[right_rows, f])
                             if bb == b))
            assert int(dec[f, b]) == expect, (f, b)


def test_paillier_add_at_matches_loop():
    cipher = get_cipher("paillier", key_bits=128, seed=5)
    rng = np.random.default_rng(2)
    k, m, n_slots = 40, 6, 2
    vals = cipher.encrypt_ints([int(v) for v in
                                rng.integers(0, 1000, k * n_slots)])
    vals = vals.reshape(k, n_slots)
    idx = rng.integers(0, m, k)
    acc_fast = cipher.zero((m, n_slots))
    cipher.add_at(acc_fast, idx, vals)
    acc_slow = cipher.zero((m, n_slots))
    for i in range(k):
        acc_slow[idx[i]] = cipher.add(acc_slow[idx[i]], vals[i])
    dec_fast = cipher.decrypt_to_ints(acc_fast)
    dec_slow = cipher.decrypt_to_ints(acc_slow)
    assert dec_fast == dec_slow


def test_layer_batched_grower_bit_identical_and_o_depth():
    """End-to-end: federated (plain cipher) == local baseline bit-for-bit
    under the layer-batched grower, and kernel launches / split_infos
    round-trips per tree are O(depth), not O(#nodes)."""
    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (500, 6)).astype(np.float32)
    w = rng.normal(0, 1, 6)
    y = (X @ w + 0.3 * rng.normal(0, 1, 500) > 0).astype(np.float64)

    n_trees, max_depth = 3, 4
    loc = LocalGBDT(SBTParams(n_trees=n_trees, max_depth=max_depth,
                              n_bins=16)).fit(X, y)
    fed = VerticalBoosting(SBTParams(n_trees=n_trees, max_depth=max_depth,
                                     n_bins=16, cipher="plain")).fit(
        X[:, :3], y, [X[:, 3:]])
    np.testing.assert_array_equal(fed.predict_proba(X[:, :3], [X[:, 3:]]),
                                  loc.predict_proba(X))

    n_internal = sum(1 for t in fed.trees for nd in t.nodes if nd.left != -1)
    assert fed.stats.n_split_roundtrips <= n_trees * max_depth
    assert fed.stats.n_hist_launches <= n_trees * max_depth
    assert n_internal > n_trees * max_depth      # the collapse is real
    # channel: exactly one split_infos message per (layer, host) pair
    assert fed.channel.msgs["split_infos"] == fed.stats.n_split_roundtrips
