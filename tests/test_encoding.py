"""GH packing (Alg 3/6), multi-class packing (Alg 7/8), compress (Alg 4)."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import compress, encoding, mo_encoding
from repro.core.he import get_cipher, limbs


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31), st.integers(8, 53))
def test_pack_unpack_bit_exact(seed, r):
    rng = np.random.default_rng(seed)
    n = 64
    g = rng.uniform(-1, 1, n)
    h = rng.uniform(0, 1, n)
    plan = encoding.plan_packing(g, h, n, plaintext_bits=1023, r=r)
    packed = encoding.pack_gh(g, h, plan)
    ints = limbs.to_pyints(packed)
    g_int = encoding.encode_int64(g + plan.g_off, plan.r)
    h_int = encoding.encode_int64(h, plan.r)
    for i in range(n):
        assert ints[i] == (int(g_int[i]) << plan.b_h) | int(h_int[i])
    # unpack a random subset sum
    idx = rng.choice(n, 20, replace=False)
    tot = sum(ints[i] for i in idx)
    gs, hs = encoding.unpack_gh_int(tot, plan, len(idx))
    tol = 2.0 ** -(plan.r - 8)
    assert abs(gs - g[idx].sum()) < tol and abs(hs - h[idx].sum()) < tol


def test_plan_shrinks_precision_when_iota_small():
    g = np.array([-0.9, 0.4]); h = np.array([0.2, 0.9])
    plan = encoding.plan_packing(g, h, 10 ** 6, plaintext_bits=80, r=53)
    assert plan.b_gh <= 80 and plan.r < 53


@pytest.mark.parametrize("cipher_name", ["plain", "affine", "paillier"])
def test_compress_roundtrip(cipher_name):
    cipher = get_cipher(cipher_name, **(
        {"bits": 512} if cipher_name == "plain"
        else {"key_bits": 256, "seed": 5}))
    rng = np.random.default_rng(0)
    g = rng.uniform(-1, 1, 30); h = rng.uniform(0, 1, 30)
    plan = encoding.plan_packing(g, h, 30, cipher.plaintext_bits, r=24)
    eta = plan.compress_capacity
    assert eta >= 2
    packed = encoding.pack_gh(g, h, plan)
    ints = limbs.to_pyints(packed)
    if cipher.backend == "limb":
        cts = cipher.encrypt_limbs(jnp.asarray(packed))
    else:
        cts = cipher.encrypt_ints(ints)
    pkgs, sizes = compress.compress_batch(cipher, cts, eta, plan.b_gh)
    dec = cipher.decrypt_to_ints(pkgs)
    rec = compress.decompress_ints(dec, sizes, eta, plan.b_gh,
                                   padded=(cipher.backend == "limb"))
    assert rec == ints
    assert len(dec) == -(-30 // eta)      # eta-fold fewer decryptions


@pytest.mark.parametrize("n_classes", [2, 3, 7, 11])
def test_mo_packing(n_classes):
    rng = np.random.default_rng(n_classes)
    G = rng.uniform(-1, 1, (40, n_classes))
    H = rng.uniform(0, 1, (40, n_classes))
    plan = mo_encoding.plan_mo_packing(G, H, 40, plaintext_bits=511, r=24)
    assert plan.n_k == -(-n_classes // plan.eta_c)
    pk = mo_encoding.pack_gh_mo(G, H, plan)
    sel = list(range(25))
    tots = []
    for k in range(plan.n_k):
        ints_k = limbs.to_pyints(pk[:, k, :])
        tots.append(sum(int(ints_k[i]) for i in sel))
    gs, hs = mo_encoding.unpack_gh_mo_ints(tots, plan, len(sel))
    np.testing.assert_allclose(gs, G[sel].sum(0), atol=1e-4)
    np.testing.assert_allclose(hs, H[sel].sum(0), atol=1e-4)
