"""Mesh-sharded crypto endpoints (DESIGN.md §8): encrypt/decrypt parity,
born-sharded ciphertexts, guest/host overlap accounting, cache eviction.

The parity tests need a forced multi-device CPU
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the CI multidevice
job) and skip otherwise; the rule-table, overlap, and eviction tests run
anywhere.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SBTParams, VerticalBoosting, encoding
from repro.core.binning import bin_features
from repro.core.he import get_cipher, limbs
from repro.core.histogram import CipherHistogram
from repro.core.party import Channel, Stats
from repro.core.tree import (HostRuntime, PackedCodec, TreeContext,
                             _encrypt_all)
from repro.kernels.modmul import decrypt_batch, encrypt_batch
from repro.launch.mesh import make_gbdt_mesh
from repro.parallel.sharding import GBDT_RULES, data_pad, gbdt_sharding

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count>=2")


def _data(n=400, d=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d)
    y = (X @ w + 0.3 * rng.normal(0, 1, n) > 0).astype(np.float64)
    return X, y


# ---------------------------------------------------------------------------
# rule table (single device)
# ---------------------------------------------------------------------------

def test_crypto_endpoint_rules():
    """enc_plain / split_infos shard their row axis over "data" with every
    other axis replicated (embarrassingly parallel, no collective)."""
    assert GBDT_RULES["enc_plain"] == ("data", None, None)
    assert GBDT_RULES["split_infos"] == ("data", None, None)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P
    assert gbdt_sharding(mesh, "enc_plain").spec == P("data", None, None)
    assert gbdt_sharding(mesh, "split_infos", ndim=2).spec == P("data", None)


def test_data_pad_divisibility():
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model")) \
        if len(jax.devices()) > 1 else jax.make_mesh((1, 1), ("data", "model"))
    d = dict(mesh.shape)["data"]
    for n in (1, 7, d, d + 1, 5 * d):
        assert (n + data_pad(mesh, n)) % d == 0


# ---------------------------------------------------------------------------
# sharded encrypt/decrypt bit-identity (multi-device only)
# ---------------------------------------------------------------------------

@multi_device
@pytest.mark.parametrize("n", [64, 301, 1024])
def test_sharded_encrypt_bit_identical(n):
    """Row-sharded encrypt == single-device encrypt, limb for limb,
    including non-divisible row counts (internal zero pad rows)."""
    mesh = make_gbdt_mesh()
    c = get_cipher("affine", key_bits=256, seed=11)
    rng = np.random.default_rng(n)
    xs = [int(v) for v in rng.integers(0, 2 ** 60, n)]
    pl = jnp.asarray(limbs.from_pyints(xs, c.Ln))
    single = np.asarray(encrypt_batch(c, pl))
    shard = encrypt_batch(c, pl, mesh=mesh)
    np.testing.assert_array_equal(single, np.asarray(shard)[:n])
    # born at histogram width, 3-D (instance, slot, limb) layout
    sh3 = encrypt_batch(c, pl.reshape(n, 1, -1), mesh=mesh,
                        out_width=c.hist_width)
    assert sh3.shape[-1] == c.hist_width
    np.testing.assert_array_equal(single, np.asarray(sh3)[:n, 0, : c.Ln])
    assert not np.asarray(sh3)[:, :, c.Ln:].any()


@multi_device
@pytest.mark.parametrize("n", [64, 301])
def test_sharded_decrypt_bit_identical(n):
    mesh = make_gbdt_mesh()
    c = get_cipher("affine", key_bits=256, seed=7)
    rng = np.random.default_rng(n + 1)
    xs = [int(v) for v in rng.integers(0, 2 ** 60, n)]
    ct = encrypt_batch(c, jnp.asarray(limbs.from_pyints(xs, c.Ln)))
    single = np.asarray(decrypt_batch(c, ct))
    shard = np.asarray(decrypt_batch(c, ct, mesh=mesh))
    np.testing.assert_array_equal(single, shard)
    assert limbs.to_pyints(shard) == xs


# ---------------------------------------------------------------------------
# born-sharded ciphertexts: zero host->device re-placements after encrypt
# ---------------------------------------------------------------------------

def _encrypt_ctx(cipher_name: str, mesh, n=300, d=4):
    rng = np.random.default_rng(3)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    g = rng.normal(0, 1, n)
    h = rng.random(n) + 0.5
    cipher = (get_cipher("plain", bits=256) if cipher_name == "plain"
              else get_cipher("affine", key_bits=256, seed=11))
    data = bin_features(X, 16)
    plan = encoding.plan_packing(g, h, n, cipher.plaintext_bits, 20)
    stats = Stats()
    engine = CipherHistogram(cipher, 16, stats=stats, mesh=mesh)
    host = HostRuntime(hid=0, data=data, engine=engine)
    ctx = TreeContext(params=SBTParams(cipher=cipher_name, precision=20,
                                       mesh=mesh),
                      cipher=cipher, codec=PackedCodec(plan),
                      channel=Channel(), stats=stats, guest_data=data,
                      g=g, h=h, sel_rows=np.arange(n), hosts=[host])
    _encrypt_all(ctx, g, h)
    return ctx, host, cipher


@multi_device
@pytest.mark.parametrize("cipher_name", ["plain", "affine"])
def test_encrypt_all_births_sharded_cts(cipher_name):
    """Frontier state inspection: ciphertexts arrive at histogram width with
    the gh_cts at-rest sharding and the frontier performs ZERO host->device
    re-placements after encryption."""
    mesh = make_gbdt_mesh()
    ctx, host, cipher = _encrypt_ctx(cipher_name, mesh)
    fr = host.frontier
    assert fr.n_cts_placements == 0
    assert ctx.stats.n_cts_placements == 0
    cts = fr.state.cts
    assert cts.shape[-1] == cipher.hist_width
    assert cts.shape[0] == 300 + data_pad(mesh, 300)
    assert cts.sharding.is_equivalent_to(gbdt_sharding(mesh, "gh_cts"),
                                         cts.ndim)
    assert ctx.stats.encrypt_seconds > 0


def test_encrypt_all_single_device_also_born_at_width():
    """Without a mesh the frontier still adopts the encrypt output as-is
    (width-padded at birth): no second placement/pad pass."""
    for name in ("plain", "affine"):
        ctx, host, cipher = _encrypt_ctx(name, mesh=None)
        assert host.frontier.n_cts_placements == 0
        assert host.frontier.state.cts.shape[-1] == cipher.hist_width


def test_legacy_cts_still_accepted():
    """Narrow unsharded ciphertexts (the pre-§8 layout) still build a
    frontier — with exactly one placement tallied."""
    rng = np.random.default_rng(0)
    n = 64
    cipher = get_cipher("plain", bits=256)
    data = bin_features(rng.normal(0, 1, (n, 3)).astype(np.float32), 8)
    cts = jnp.asarray(rng.integers(0, 256, (n, 1, cipher.Ln)), jnp.int32)
    from repro.core.frontier import CipherFrontier
    fr = CipherFrontier(CipherHistogram(cipher, 8), data, cts)
    assert fr.n_cts_placements == 1
    assert fr.state.cts.shape[-1] == cipher.hist_width


@multi_device
def test_mesh_fit_zero_replacements_and_parity():
    """End-to-end: mesh training performs zero ciphertext re-placements and
    stays bit-identical to the unsharded run."""
    X, y = _data(n=437)
    mesh = make_gbdt_mesh()
    base = dict(n_trees=2, max_depth=3, n_bins=16, cipher="plain")
    m1 = VerticalBoosting(SBTParams(**base, mesh=mesh)).fit(
        X[:, :3], y, [X[:, 3:]])
    m2 = VerticalBoosting(SBTParams(**base)).fit(X[:, :3], y, [X[:, 3:]])
    np.testing.assert_array_equal(m1.predict_proba(X[:, :3], [X[:, 3:]]),
                                  m2.predict_proba(X[:, :3], [X[:, 3:]]))
    assert m1.stats.n_cts_placements == 0
    assert m2.stats.n_cts_placements == 0


# ---------------------------------------------------------------------------
# guest/host overlap accounting
# ---------------------------------------------------------------------------

def test_overlap_stats_recorded():
    X, y = _data(n=300)
    m = VerticalBoosting(SBTParams(n_trees=2, max_depth=3, n_bins=16)).fit(
        X[:, :3], y, [X[:, 3:]])
    s = m.stats
    assert s.encrypt_seconds > 0
    assert s.layer_overlap and all(0.0 <= f <= 1.0 for f in s.layer_overlap)
    assert 0.0 <= s.overlap_fraction <= 1.0
    assert s.guest_hist_seconds > 0 and s.host_wait_seconds > 0
    d = s.as_dict()
    assert "layer_overlap" in d and "encrypt_seconds" in d


def test_guest_only_layers_record_no_overlap():
    """mix-mode guest-local trees have no host dispatch to overlap with."""
    X, y = _data(n=200)
    m = VerticalBoosting(SBTParams(n_trees=1, max_depth=2, tree_mode="mix",
                                   trees_per_party=1)).fit(
        X[:, :3], y, [X[:, 3:]])
    assert m.stats.layer_overlap == []


# ---------------------------------------------------------------------------
# frontier cache eviction
# ---------------------------------------------------------------------------

def test_hist_cache_bounded_by_frontier_width():
    """Deep tree with many dead branches: cached parent histograms never
    outnumber the frontier (the pre-fix code leaked every leaf's cached
    histogram for the tree's remainder)."""
    X, y = _data(n=250, seed=4)
    m = VerticalBoosting(SBTParams(n_trees=2, max_depth=6, n_bins=16,
                                   min_leaf=8, min_gain=1e-3)).fit(
        X[:, :3], y, [X[:, 3:]])
    s = m.stats
    assert s.peak_frontier >= 2
    assert s.peak_hist_cache <= s.peak_frontier
    assert s.peak_hist_cache <= 2 ** 5          # <= splits per layer bound

    # eviction must not change the model: parity with a shallow rerun
    m2 = VerticalBoosting(SBTParams(n_trees=2, max_depth=6, n_bins=16,
                                    min_leaf=8, min_gain=1e-3)).fit(
        X[:, :3], y, [X[:, 3:]])
    np.testing.assert_array_equal(m.predict_proba(X[:, :3], [X[:, 3:]]),
                                  m2.predict_proba(X[:, :3], [X[:, 3:]]))


def test_subtraction_off_evicts_everything():
    X, y = _data(n=200, seed=2)
    m = VerticalBoosting(SBTParams(n_trees=1, max_depth=4, n_bins=16,
                                   histogram_subtraction=False)).fit(
        X[:, :3], y, [X[:, 3:]])
    assert m.stats.peak_hist_cache == 0
