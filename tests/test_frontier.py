"""Frontier engine: device-resident layer state + mesh-sharded dispatch.

Single-device tests always run; the sharded tests need a forced multi-device
CPU (``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the CI
multidevice job) and skip otherwise.  The load-bearing claim everywhere is
*bit-identity*: lazy limb sums are plain int32 additions, so any shard
partitioning followed by psum-then-carry-fix must equal the single-device
accumulation exactly.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import LocalGBDT, SBTParams, VerticalBoosting
from repro.core.binning import bin_features
from repro.core.frontier import CipherFrontier, FrontierState, GuestFrontier
from repro.core.he import get_cipher, limbs
from repro.core.histogram import CipherHistogram, PlainHistogram
from repro.core.party import Stats
from repro.kernels.histogram import (layer_ciphertext_histogram,
                                     sharded_layer_ciphertext_histogram)
from repro.launch.mesh import make_gbdt_mesh

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count>=2")


def _data(n=500, d=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d)
    y = (X @ w + 0.3 * rng.normal(0, 1, n) > 0).astype(np.float64)
    return X, y


# ---------------------------------------------------------------------------
# FrontierState / CipherFrontier basics (single device)
# ---------------------------------------------------------------------------

def test_frontier_state_is_pytree():
    s = FrontierState(bins=jnp.zeros((4, 2), jnp.int32),
                      cts=jnp.zeros((4, 1, 8), jnp.int32),
                      hists={3: jnp.ones((2, 8, 1, 8), jnp.int32)})
    leaves, treedef = jax.tree_util.tree_flatten(s)
    assert len(leaves) == 3
    s2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(s2, FrontierState) and 3 in s2.hists
    np.testing.assert_array_equal(np.asarray(s2.hists[3]),
                                  np.asarray(s.hists[3]))


def test_frontier_state_stays_on_device():
    """bins are masked and cts width-padded ONCE at construction, cached
    parent histograms remain jax device arrays between layers."""
    rng = np.random.default_rng(0)
    n, n_f, n_b = 120, 3, 8
    cipher = get_cipher("plain", bits=256)
    X = rng.normal(0, 1, (n, n_f)).astype(np.float32)
    X[rng.random(X.shape) < 0.5] = 0.0
    data = bin_features(X, n_b, sparse=True)
    cts = np.asarray(cipher.encrypt_ints(
        [int(v) for v in rng.integers(0, 2**30, n)])).reshape(n, 1, -1)
    engine = CipherHistogram(cipher, n_b, sparse=True, stats=Stats())
    fr = CipherFrontier(engine, data, cts)
    assert isinstance(fr.state.bins, jax.Array)
    assert fr.state.cts.shape[-1] == cipher.hist_width
    # sparse masking applied once: masked cells are -1 on device and host
    assert (np.asarray(fr.state.bins) == fr.bins_np).all()
    assert (fr.bins_np == -1).any()
    out = fr.layer_histograms({0: np.arange(n)}, [0], [])
    assert isinstance(fr.hist(0), jax.Array)       # cached as device array
    assert 0 in fr and 1 not in fr
    fr.evict([0])
    assert 0 not in fr
    assert out[0][1].sum() == n * n_f


def test_guest_frontier_matches_plain_engine():
    rng = np.random.default_rng(1)
    n, n_f, n_b = 200, 4, 8
    X = rng.normal(0, 1, (n, n_f)).astype(np.float32)
    data = bin_features(X, n_b)
    g = rng.normal(0, 1, n)
    h = rng.random(n)
    engine = PlainHistogram(n_b)
    fr = GuestFrontier(engine, data, g, h)
    rows = {0: np.arange(n)}
    out = fr.layer_histograms(rows, [0], [])
    G, H, C = engine.node_histogram(data, g, h, np.arange(n))
    np.testing.assert_allclose(out[0][0], G)
    np.testing.assert_allclose(out[0][1], H)
    np.testing.assert_array_equal(out[0][2], C)
    assert 0 in fr
    fr.evict([0])
    assert 0 not in fr


# ---------------------------------------------------------------------------
# lazy-limb psum property: shard-then-carry == carry-then-add
# ---------------------------------------------------------------------------

def _check_psum_property(seed: int, n_shards: int, per: int) -> None:
    """The collective-exactness claim behind the sharded dispatch
    (DESIGN.md §3/§7): for per-shard lazy accumulators -- including the
    mixed-sign limbs produced by lazy subtraction -- summing raw int32 limb
    vectors across shards and carry-fixing ONCE equals canonicalizing every
    shard first and adding canonically."""
    L = 8
    rng = np.random.default_rng(seed)
    # per-shard lazy sums of canonical radix-2**8 vectors
    vals = rng.integers(0, 256, (n_shards, per, L)).astype(np.int64)
    shard_lazy = vals.sum(axis=1).astype(np.int32)        # (n_shards, L)
    # headroom so the total cannot overflow the top limb
    shard_lazy = np.concatenate(
        [shard_lazy, np.zeros((n_shards, 2), np.int32)], axis=1)

    # psum-then-carry
    a = np.asarray(limbs.carry_fix(jnp.asarray(shard_lazy.sum(axis=0))))
    # canonicalize-then-add
    acc = np.asarray(limbs.carry_fix(jnp.asarray(shard_lazy[0])))
    for i in range(1, n_shards):
        acc = np.asarray(limbs.add(
            jnp.asarray(acc), limbs.carry_fix(jnp.asarray(shard_lazy[i]))))
    np.testing.assert_array_equal(a, acc)

    # mixed-sign: parent - sum_of_shard_children stays exact through a
    # single carry_fix as long as the represented value is >= 0
    parent = acc                                           # == total
    child_lazy = shard_lazy[: n_shards - 1]
    diff = parent.astype(np.int32) - child_lazy.sum(axis=0)
    fixed = np.asarray(limbs.carry_fix(jnp.asarray(diff)))
    expect = np.asarray(limbs.carry_fix(jnp.asarray(
        shard_lazy[n_shards - 1])))
    np.testing.assert_array_equal(fixed, expect)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(deadline=None, max_examples=40)
    @given(st.integers(0, 2**32 - 1), st.integers(2, 6), st.integers(1, 24))
    def test_lazy_psum_then_carry_equals_canonicalize_then_add(seed, n_shards,
                                                               per):
        _check_psum_property(seed, n_shards, per)
except ImportError:
    def test_lazy_psum_then_carry_equals_canonicalize_then_add():
        # hypothesis unavailable: seeded sweep over the same space
        rng = np.random.default_rng(0)
        for _ in range(40):
            _check_psum_property(int(rng.integers(0, 2**32)),
                                 int(rng.integers(2, 7)),
                                 int(rng.integers(1, 25)))


# ---------------------------------------------------------------------------
# sparse layer path: multi-host + subtraction coverage
# ---------------------------------------------------------------------------

def test_sparse_multihost_subtraction_parity():
    """Sparse (zero-bin recovery) layer path with two hosts and histogram
    subtraction active: identical predictions to the dense path."""
    X, y = _data(n=420)
    rng = np.random.default_rng(3)
    Xs = X.copy()
    Xs[rng.random(X.shape) < 0.6] = 0.0
    cfg = dict(n_trees=3, max_depth=4, n_bins=16,
               histogram_subtraction=True)
    sp = VerticalBoosting(SBTParams(**cfg, sparse=True)).fit(
        Xs[:, :2], y, [Xs[:, 2:4], Xs[:, 4:]])
    ns = VerticalBoosting(SBTParams(**cfg, sparse=False)).fit(
        Xs[:, :2], y, [Xs[:, 2:4], Xs[:, 4:]])
    np.testing.assert_array_equal(
        sp.predict_proba(Xs[:, :2], [Xs[:, 2:4], Xs[:, 4:]]),
        ns.predict_proba(Xs[:, :2], [Xs[:, 2:4], Xs[:, 4:]]))
    # depth 4 guarantees subtract-mode nodes actually ran
    internal = sum(1 for t in sp.trees for nd in t.nodes if nd.left != -1)
    assert internal > len(sp.trees)


# ---------------------------------------------------------------------------
# mesh-sharded dispatch (multi-device only)
# ---------------------------------------------------------------------------

@multi_device
@pytest.mark.parametrize("n_i,n_f,L,n_b,n_n",
                         [(300, 5, 16, 32, 3), (257, 9, 8, 16, 1),
                          (1024, 7, 12, 32, 5), (64, 3, 35, 8, 16)])
def test_sharded_layer_kernel_bit_identical(n_i, n_f, L, n_b, n_n):
    mesh = make_gbdt_mesh()
    rng = np.random.default_rng(n_i + n_n)
    bins = rng.integers(0, n_b, (n_i, n_f)).astype(np.int32)
    bins[rng.random((n_i, n_f)) < 0.15] = -1
    slot = rng.integers(-1, n_n, n_i).astype(np.int32)
    cts = rng.integers(0, 256, (n_i, L)).astype(np.int32)
    single = np.asarray(layer_ciphertext_histogram(bins, slot, cts, n_n, n_b))
    sharded = np.asarray(sharded_layer_ciphertext_histogram(
        bins, slot, cts, n_n, n_b, mesh))
    np.testing.assert_array_equal(single, sharded)


@multi_device
def test_mesh_training_bit_identical_to_local_with_collectives():
    """Acceptance: federated training on a forced multi-device CPU mesh is
    bit-identical to the single-device plain-cipher path (and to the local
    baseline), with intra-party collective bytes tallied separately from
    cross-party wire bytes."""
    X, y = _data(n=500)
    mesh = make_gbdt_mesh()
    loc = LocalGBDT(SBTParams(n_trees=3, max_depth=4, n_bins=16)).fit(X, y)
    fed = VerticalBoosting(SBTParams(n_trees=3, max_depth=4, n_bins=16,
                                     cipher="plain", mesh=mesh)).fit(
        X[:, :3], y, [X[:, 3:]])
    np.testing.assert_array_equal(fed.predict_proba(X[:, :3], [X[:, 3:]]),
                                  loc.predict_proba(X))
    assert fed.stats.coll_bytes > 0 and fed.stats.n_collectives > 0
    coll = fed.channel.collective_summary()
    # assert on whichever collectives this mesh factorization exercises
    # (axes of extent 1 run none): data>1 -> psum, model>1 -> all-gather
    sizes = dict(mesh.shape)
    if sizes.get("data", 1) > 1:
        assert coll["hist_psum"]["bytes"] > 0
    if sizes.get("model", 1) > 1:
        assert coll["hist_allgather"]["bytes"] > 0
    # collectives are NOT wire bytes: the cross-party ledger is unchanged
    # (prediction above counted predict_* wire traffic too, so the
    # single-device reference serves the same batch before comparing)
    fed1 = VerticalBoosting(SBTParams(n_trees=3, max_depth=4, n_bins=16,
                                      cipher="plain")).fit(
        X[:, :3], y, [X[:, 3:]])
    fed1.predict_proba(X[:, :3], [X[:, 3:]])
    assert fed.channel.total_bytes == fed1.channel.total_bytes
    assert fed1.stats.coll_bytes == 0


@multi_device
def test_mesh_training_nondivisible_rows_and_goss():
    """Regression: selected row counts that don't divide the data-axis
    extent (arbitrary n, and GOSS subsampling) must train — the frontier
    pads the device arrays — and stay bit-identical."""
    X, y = _data(n=437, seed=9)              # 437 % 4 != 0
    mesh = make_gbdt_mesh()
    base = dict(n_trees=2, max_depth=3, n_bins=16, cipher="plain")
    m1 = VerticalBoosting(SBTParams(**base, mesh=mesh)).fit(
        X[:, :3], y, [X[:, 3:]])
    m2 = VerticalBoosting(SBTParams(**base)).fit(X[:, :3], y, [X[:, 3:]])
    np.testing.assert_array_equal(m1.predict_proba(X[:, :3], [X[:, 3:]]),
                                  m2.predict_proba(X[:, :3], [X[:, 3:]]))
    g1 = VerticalBoosting(SBTParams(**base, goss=True, seed=1,
                                    mesh=mesh)).fit(X[:, :3], y, [X[:, 3:]])
    g2 = VerticalBoosting(SBTParams(**base, goss=True, seed=1)).fit(
        X[:, :3], y, [X[:, 3:]])
    np.testing.assert_array_equal(g1.predict_proba(X[:, :3], [X[:, 3:]]),
                                  g2.predict_proba(X[:, :3], [X[:, 3:]]))


@multi_device
def test_mesh_training_affine_and_sparse_parity():
    X, y = _data(n=300, seed=5)
    mesh = make_gbdt_mesh()
    base = dict(n_trees=2, max_depth=3, n_bins=16)
    a1 = VerticalBoosting(SBTParams(**base, cipher="affine", key_bits=256,
                                    precision=20, mesh=mesh)).fit(
        X[:, :3], y, [X[:, 3:]])
    a2 = VerticalBoosting(SBTParams(**base, cipher="affine", key_bits=256,
                                    precision=20)).fit(X[:, :3], y, [X[:, 3:]])
    np.testing.assert_array_equal(a1.predict_proba(X[:, :3], [X[:, 3:]]),
                                  a2.predict_proba(X[:, :3], [X[:, 3:]]))
    rng = np.random.default_rng(7)
    Xs = X.copy()
    Xs[rng.random(X.shape) < 0.5] = 0.0
    s1 = VerticalBoosting(SBTParams(**base, sparse=True, mesh=mesh)).fit(
        Xs[:, :3], y, [Xs[:, 3:]])
    s2 = VerticalBoosting(SBTParams(**base, sparse=True)).fit(
        Xs[:, :3], y, [Xs[:, 3:]])
    np.testing.assert_array_equal(s1.predict_proba(Xs[:, :3], [Xs[:, 3:]]),
                                  s2.predict_proba(Xs[:, :3], [Xs[:, 3:]]))
