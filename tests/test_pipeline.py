"""Pipelined boosting (DESIGN.md §12): rounds in flight, round-forests,
and async transport overlap.

The load-bearing claim is bit-identity: with ``forest_size=1``, a
pipelined run — encrypt pump, dual-buffer enc_gh staging, broker inbox —
must produce byte-for-byte the trees, scores, and converged per-tag
ledgers of the sequential run; the pipeline may only move work in TIME,
never change it.  Round-forests (``forest_size=k``) are a different
model by design, so their parity axis is plain-vs-affine cipher
bit-identity and kernel-vs-reference equality instead.

Single-device tests always run; sharded tests need the forced
multi-device CPU (``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
and skip otherwise.  Socket tests spawn real host processes.
"""

import dataclasses
import math
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import SBTParams, VerticalBoosting
from repro.core.party import Stats
from repro.runtime.chaos import RECV, Delay, FaultPlan
from repro.runtime.transport import MultiHostRun

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count>=2")


def _data(n=300, d=8, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d)
    y = (X @ w + 0.3 * rng.normal(0, 1, n) > 0).astype(np.float64)
    return X, y


def _data3(n=300, d=8, seed=0):
    X, _ = _data(n, d, seed)
    s = X @ np.ones(d)
    y = ((s > np.quantile(s, 0.33)).astype(float)
         + (s > np.quantile(s, 0.66)).astype(float))
    return X, y


def _sigs(model):
    return [t.signature() for t in model.trees]


# ---------------------------------------------------------------------------
# satellite: overlap_fraction / wire_overlap_frac zero-guards
# ---------------------------------------------------------------------------

def test_wire_overlap_frac_zero_encrypt_guard():
    """A run that never encrypts (plain cipher) records
    encrypt_seconds == 0; the derived overlap fraction must be exactly
    0.0 — not NaN, not a ZeroDivisionError."""
    s = Stats()
    assert s.wire_overlap_frac == 0.0
    s.prefetch_seconds = 0.5            # pathological: prefetch w/o encrypt
    assert s.wire_overlap_frac == 0.0
    s.encrypt_seconds = float("nan")
    assert s.wire_overlap_frac == 0.0
    s.encrypt_seconds = 1.0
    assert s.wire_overlap_frac == 0.5
    s.prefetch_seconds = 7.0            # clamped: hidden <= total by defn
    assert s.wire_overlap_frac == 1.0
    assert math.isfinite(s.overlap_fraction)


def test_plain_run_overlap_fractions_finite():
    X, y = _data(n=150)
    p = SBTParams(n_trees=2, max_depth=2, n_bins=8, cipher="plain",
                  pipeline=True, seed=1)
    m = VerticalBoosting(p).fit(X[:, :3], y, [X[:, 3:]])
    # plain runs still time their (no-op) encrypt step, so the guard's
    # zero-denominator branch is synthetic-only (test above); the live
    # invariant is clamping and finiteness
    assert 0.0 <= m.stats.wire_overlap_frac <= 1.0
    assert math.isfinite(m.stats.overlap_fraction)
    d = m.stats.as_dict()
    assert all(math.isfinite(v) for v in d.values()
               if isinstance(v, float))


# ---------------------------------------------------------------------------
# tentpole: pipelined == sequential bit-identity (in-process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cipher", ["plain", "affine"])
@pytest.mark.parametrize("objective", ["binary", "multiclass"])
def test_pipelined_bit_identical_inprocess(cipher, objective):
    if objective == "multiclass":
        X, y = _data3(n=250)
        extra = dict(objective="multiclass", n_classes=3)
    else:
        X, y = _data(n=250)
        extra = {}
    kw = (dict(key_bits=256, precision=20) if cipher == "affine" else {})
    base = SBTParams(n_trees=2, max_depth=3, n_bins=16, cipher=cipher,
                     goss=True, seed=3, **extra, **kw)
    Xg, Xh = X[:, :3], [X[:, 3:]]
    seq = VerticalBoosting(dataclasses.replace(base, pipeline=False)).fit(
        Xg, y, [h.copy() for h in Xh])
    pipe = VerticalBoosting(dataclasses.replace(base, pipeline=True)).fit(
        Xg, y, Xh)
    np.testing.assert_array_equal(pipe.train_score_, seq.train_score_)
    assert _sigs(pipe) == _sigs(seq)
    # identical wire ledger: the pump moved the encrypt in time, not the
    # protocol in shape
    assert pipe.channel.summary() == seq.channel.summary()
    if cipher == "affine" and objective == "multiclass":
        # cross-class prefetch: class c+1's gradients are known at round
        # start, so its encrypt hides behind class c's growth
        assert pipe.stats.wire_overlap_frac > 0.0
        assert pipe.stats.prefetch_seconds > 0.0


# ---------------------------------------------------------------------------
# tentpole: pipelined == sequential over the transports
# ---------------------------------------------------------------------------

def _transport_pair(params, X, y, transport, n_hosts=2):
    Xg = X[:, :3]
    cols = np.array_split(np.arange(X.shape[1] - 3) + 3, n_hosts)
    Xh = [X[:, c] for c in cols]
    seq = VerticalBoosting(dataclasses.replace(params, pipeline=False)).fit(
        Xg, y, [h.copy() for h in Xh])
    run = MultiHostRun(params, Xh, transport=transport,
                       export_dir=tempfile.mkdtemp())
    return seq, run, Xg, Xh


def test_pipelined_loopback_bit_identical_and_staged():
    """Loopback: the guest's encrypt pump delivers the next class's
    enc_gh mid-tree; the PartyProcess must stage it (dual-buffer) and
    activate at the first assign_sync of the new tree — bit-identically,
    with converged ledgers, and with the stage->activate path actually
    exercised."""
    X, y = _data3(n=250)
    params = SBTParams(n_trees=2, max_depth=3, n_bins=16, cipher="affine",
                       key_bits=256, precision=20, objective="multiclass",
                       n_classes=3, pipeline=True, seed=7)
    seq, run, Xg, Xh = _transport_pair(params, X, y, "loopback")
    try:
        model = run.fit(Xg, y)
        np.testing.assert_array_equal(model.train_score_, seq.train_score_)
        assert _sigs(model) == _sigs(seq)
        assert run.channel.summary() == seq.channel.summary()
        # out-of-order arrival really happened: enc_gh frames for a
        # future tree were accepted and staged while a tree was in flight
        assert sum(pp.staged_activations for pp in run.parties) > 0
        assert model.stats.wire_overlap_frac > 0.0
        # serving from the per-party exports stays bit-identical too
        run.serve()
        np.testing.assert_array_equal(
            run.predict_score(Xg, staged=True),
            seq.predict_score(Xg, Xh))
    finally:
        run.close()


def test_pipelined_socket_bit_identical():
    """Forced-2-process acceptance: pipelined training over real sockets
    (broker inbox active on the hosts) is bit-identical to the
    sequential in-process oracle with identical converged per-tag
    ledgers."""
    X, y = _data3(n=200)
    params = SBTParams(n_trees=2, max_depth=3, n_bins=8, cipher="affine",
                       key_bits=256, precision=20, objective="multiclass",
                       n_classes=3, pipeline=True, seed=5)
    seq, run, Xg, Xh = _transport_pair(params, X, y, "socket", n_hosts=1)
    try:
        model = run.fit(Xg, y)
        np.testing.assert_array_equal(model.train_score_, seq.train_score_)
        assert _sigs(model) == _sigs(seq)
        assert run.channel.summary() == seq.channel.summary()
    finally:
        run.close()


def test_pipelined_socket_chaos_delayed_enc_gh():
    """Chaos: delay the prefetched enc_gh frames on the host's receive
    path — the broker's per-tag inbox absorbs the perturbed arrival
    timing (late prefetch, compute already waiting) without changing a
    single byte of the result."""
    X, y = _data3(n=150)
    params = SBTParams(n_trees=2, max_depth=2, n_bins=8, cipher="affine",
                       key_bits=256, precision=20, objective="multiclass",
                       n_classes=3, pipeline=True, seed=9)
    plans = {0: FaultPlan(rules=[
        Delay(tag="enc_gh", nth=2, direction=RECV, seconds=0.2),
        Delay(tag="enc_gh", nth=4, direction=RECV, seconds=0.2),
    ], seed=17)}
    Xg, Xh = X[:, :3], [X[:, 3:]]
    seq = VerticalBoosting(dataclasses.replace(params, pipeline=False)).fit(
        Xg, y, [Xh[0].copy()])
    run = MultiHostRun(params, Xh, transport="socket",
                       export_dir=tempfile.mkdtemp(), fault_plans=plans,
                       timeout=120.0)
    try:
        model = run.fit(Xg, y)
        np.testing.assert_array_equal(model.train_score_, seq.train_score_)
        assert _sigs(model) == _sigs(seq)
        assert run.channel.summary() == seq.channel.summary()
    finally:
        run.close()


def test_pipeline_resilient_incompatible():
    X, y = _data(n=100)
    params = SBTParams(n_trees=1, max_depth=2, n_bins=8, pipeline=True)
    run = MultiHostRun(params, [X[:, 3:]], transport="loopback")
    try:
        with pytest.raises(ValueError, match="resilient"):
            run.fit(X[:, :3], y, resilient=True, ckpt_dir=None)
    finally:
        run.close()


# ---------------------------------------------------------------------------
# round-forests (forest_size = k)
# ---------------------------------------------------------------------------

def test_forest_grows_k_trees_per_round_and_cipher_parity():
    """k bagged member trees per round off ONE enc_gh; the affine cipher
    pipeline must agree bit-for-bit with the plain debugging cipher on
    every member's structure."""
    X, y = _data(n=250)
    base = SBTParams(n_trees=2, max_depth=3, n_bins=16, forest_size=3,
                     seed=11)
    Xg, Xh = X[:, :3], [X[:, 3:]]
    plain = VerticalBoosting(base).fit(Xg, y, [Xh[0].copy()])
    aff = VerticalBoosting(dataclasses.replace(
        base, cipher="affine", key_bits=256, precision=20)).fit(Xg, y, Xh)
    assert len(plain.trees) == 2 * 3 == len(aff.trees)
    assert plain.trees_per_round == 3
    assert _sigs(aff) == _sigs(plain)
    np.testing.assert_array_equal(aff.train_score_, plain.train_score_)
    # one enc_gh round-trip per ROUND, not per member tree
    assert aff.channel.msgs["enc_gh"] == 2


def test_forest_requires_binary():
    X, y = _data3(n=100)
    p = SBTParams(n_trees=1, max_depth=2, n_bins=8, forest_size=2,
                  objective="multiclass", n_classes=3)
    with pytest.raises(ValueError, match="forest_size"):
        VerticalBoosting(p).fit(X[:, :3], y, [X[:, 3:]])


def test_forest_transport_bit_identical():
    """Round-forest training over the framed transport == in-process,
    including serving from per-member split tables (table_sinks demux)."""
    X, y = _data(n=200)
    params = SBTParams(n_trees=2, max_depth=3, n_bins=8, cipher="affine",
                       key_bits=256, precision=20, forest_size=3,
                       pipeline=True, seed=13)
    seq, run, Xg, Xh = _transport_pair(params, X, y, "loopback")
    try:
        model = run.fit(Xg, y)
        np.testing.assert_array_equal(model.train_score_, seq.train_score_)
        assert _sigs(model) == _sigs(seq)
        assert run.channel.summary() == seq.channel.summary()
        run.serve()
        np.testing.assert_array_equal(
            run.predict_score(Xg, staged=True),
            seq.predict_score(Xg, Xh))
        # the host demuxed its combined gid table into one local-nid
        # table per member tree (what serving export keys on)
        pp = run.parties[0]
        assert sorted(pp.tables) == list(range(2 * 3))
    finally:
        run.close()


def test_forest_kernel_matches_reference():
    """The (tree, node)-batched Pallas launch == the einsum reference on
    random masked inputs."""
    from repro.kernels.histogram import (forest_ciphertext_histogram,
                                         forest_hist_ref)
    rng = np.random.default_rng(0)
    n_i, n_f, n_b, k, n_nodes, L = 257, 5, 8, 3, 4, 6
    bins = rng.integers(-1, n_b, (n_i, n_f)).astype(np.int32)
    slot = rng.integers(-1, n_nodes, (n_i, k)).astype(np.int32)
    cts = rng.integers(0, 256, (n_i, L)).astype(np.int32)
    ref = forest_hist_ref(jnp.asarray(bins), jnp.asarray(slot),
                          jnp.asarray(cts), n_nodes, n_b)
    out = forest_ciphertext_histogram(bins, slot, cts, n_nodes, n_b,
                                      use_pallas=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert out.shape == (k, n_nodes, n_f, n_b, L)


# ---------------------------------------------------------------------------
# sharded layer cumsum + sharded forest dispatch (forced multi-device)
# ---------------------------------------------------------------------------

@multi_device
def test_sharded_forest_training_bit_identical():
    """Full federated forest training on the forced mesh == single
    device, member for member."""
    from repro.launch.mesh import make_gbdt_mesh
    X, y = _data(n=512)
    base = SBTParams(n_trees=1, max_depth=3, n_bins=8, cipher="affine",
                     key_bits=256, precision=20, forest_size=3, seed=2)
    Xg, Xh = X[:, :3], [X[:, 3:]]
    one = VerticalBoosting(base).fit(Xg, y, [Xh[0].copy()])
    mesh = make_gbdt_mesh()
    many = VerticalBoosting(dataclasses.replace(base, mesh=mesh)).fit(
        Xg, y, Xh)
    assert _sigs(many) == _sigs(one)
    np.testing.assert_array_equal(many.train_score_, one.train_score_)


@multi_device
def test_sharded_cumsum_bit_identical_and_gated():
    """The ciphertext-domain layer cumsum shards over 'data' above the
    same >=256-rows-per-shard gate as the batched decrypt; below the
    gate it must fall back (return None) rather than pad-shard tiny
    layers."""
    from repro.core.binning import bin_features
    from repro.core.he import get_cipher
    from repro.core.histogram import CipherHistogram
    from repro.launch.mesh import make_gbdt_mesh

    cipher = get_cipher("affine", key_bits=256)
    mesh = make_gbdt_mesh()
    dd = dict(mesh.shape).get("data", 1)
    rng = np.random.default_rng(0)

    single = CipherHistogram(cipher, n_bins=16, use_pallas=False)
    sharded = CipherHistogram(cipher, n_bins=16, use_pallas=False,
                              mesh=mesh)
    # (nodes, features, bins, slots, L): leading axes flatten to the
    # group extent G = nodes*features the gate tests against; 64*dd nodes
    # of 4 features lands exactly at G = 256*dd = BLOCK_N*dd
    Ln = cipher.Ln
    big = rng.integers(0, 200, (64 * dd, 4, 16, 1, Ln)).astype(np.int32)
    wide = np.pad(big, [(0, 0)] * 4 + [(0, cipher.hist_width - Ln)])
    out = sharded._sharded_cumsum(jnp.asarray(wide), 2)
    assert out is not None          # the gate admitted this layer
    ref = np.asarray(single.cumsum(jnp.asarray(big)))
    np.testing.assert_array_equal(np.asarray(out), ref)
    np.testing.assert_array_equal(
        np.asarray(sharded.cumsum(jnp.asarray(big))), ref)

    small = rng.integers(0, 200, (2, 2, 16, 1, Ln)).astype(np.int32)
    assert sharded._sharded_cumsum(
        jnp.asarray(np.pad(small, [(0, 0)] * 4
                           + [(0, cipher.hist_width - Ln)])), 2) is None
    np.testing.assert_array_equal(
        np.asarray(sharded.cumsum(jnp.asarray(small))),
        np.asarray(single.cumsum(jnp.asarray(small))))
