"""Tests for the static-analysis subsystem (DESIGN.md §15).

Three layers of assurance:

1. Unit tests of each pass against tiny synthetic source trees — the
   taint pass catches direct / transitive / attribute leaks and honors
   sanitizers; the wire pass refuses unregistered tags; the lock pass
   flags unguarded access; the dtype pass flags naked ``asarray``.
2. The analyzer runs CLEAN over the real tree: zero findings beyond the
   reviewed baseline, and nothing in the baseline is stale.
3. Seeded-mutation self-tests: three representative violations (a
   plaintext-gradient leak under a fresh tag, an unregistered-tag send,
   an unlocked guarded write) are injected into a COPY of the real
   source, and each is caught by its pass as a NEW finding against the
   shipped baseline — proof the CI gate actually fires.

Plus runtime twins: the export audit (both leak directions) and the
checkpoint float64 round-trip the dtype lint exists to protect.
"""

import json
import os
import shutil
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import astutil, locks, report, schema, taint, wire
from repro.analysis import dtype as dtype_pass
from repro.analysis.__main__ import _DEFAULT_ROOT as ROOT
from repro.analysis.__main__ import analyze
from repro.analysis.schema import WireSchemaError
from repro.checkpoint import checkpoint as ckpt
from repro.serving import export
BASELINE = os.path.join(ROOT, "analysis", "baseline.json")


# ---------------------------------------------------------------------------
# schema registry
# ---------------------------------------------------------------------------

def test_registry_partitions_proto_and_ctrl():
    assert schema.PROTO_TAGS and schema.CTRL_TAGS
    assert not (schema.PROTO_TAGS & schema.CTRL_TAGS)
    assert schema.PROTO_TAGS | schema.CTRL_TAGS == set(schema.REGISTRY)
    for tag, spec in schema.REGISTRY.items():
        assert spec.tag == tag
        assert spec.direction in (schema.G2H, schema.H2G)


def test_validate_refuses_each_violation_class():
    ok = {"tree": 0, "seed": 1, "forest": 0, "codec": {}, "cts": None}
    schema.validate(schema.KIND_PROTO, "guest", "host0", schema.ENC_GH, ok)
    with pytest.raises(WireSchemaError, match="unregistered"):
        schema.validate(schema.KIND_PROTO, "guest", "host0", "gh_debug", ok)
    with pytest.raises(WireSchemaError, match="kind"):
        schema.validate(schema.KIND_CTRL, "guest", "host0",
                        schema.ENC_GH, ok)
    with pytest.raises(WireSchemaError, match="direction"):
        schema.validate(schema.KIND_PROTO, "host0", "guest",
                        schema.ENC_GH, ok)
    with pytest.raises(WireSchemaError, match="missing required"):
        schema.validate(schema.KIND_PROTO, "guest", "host0",
                        schema.ENC_GH, {"tree": 0})
    with pytest.raises(WireSchemaError, match="must be None"):
        schema.validate(schema.KIND_CTRL, "guest", "host0",
                        schema.BYE, {"x": 1})
    # unknown roles never flag direction (simulation channels say "?")
    schema.validate(schema.KIND_PROTO, "?", "?", schema.ENC_GH, ok)


def test_finding_fingerprint_ignores_line_numbers():
    a = report.Finding("taint", "core/tree.py", "f", "r", "d", line=10)
    b = report.Finding("taint", "core/tree.py", "f", "r", "d", line=99)
    c = report.Finding("taint", "core/tree.py", "f", "r", "other", line=10)
    assert a.fingerprint == b.fingerprint != c.fingerprint


# ---------------------------------------------------------------------------
# pass unit tests on synthetic trees
# ---------------------------------------------------------------------------

def _tree_from(tmp_path, files: dict):
    root = tmp_path / "src"
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    return astutil.load_tree(str(root))


def test_taint_pass_direct_transitive_attr_and_sanitized(tmp_path):
    mods = _tree_from(tmp_path, {"core/tree.py": (
        "def helper(x):\n"
        "    return x + 1\n"
        "def leak_direct(ch, g):\n"
        "    ch.send('guest', 'host0', 'gh_debug', g, 8)\n"
        "def leak_transitive(ch, h):\n"
        "    ch.send('guest', 'host0', 'gh_debug', helper(h), 8)\n"
        "def leak_attr(ch, ctx):\n"
        "    ch.send('guest', 'host0', 'gh_debug', ctx.g, 8)\n"
        "def clean(ch, g, cipher):\n"
        "    ch.send('guest', 'host0', 'enc_gh', cipher.encrypt_ints(g), 8)\n"
        "def clean_len(ch, g):\n"
        "    ch.send('guest', 'host0', 'enc_gh', {'n': len(g)}, 8)\n")})
    found = {f.qualname for f in taint.run(mods)}
    assert found == {"leak_direct", "leak_transitive", "leak_attr"}


def test_wire_pass_unregistered_and_dynamic_tags(tmp_path):
    mods = _tree_from(tmp_path, {"runtime/x.py": (
        "import repro.analysis.schema as wire\n"
        "def ok(ch, p):\n"
        "    ch.send('guest', 'host0', wire.ENC_GH, p, 8)\n"
        "    ch.send('guest', 'host0', 'assign_sync', p, 8)\n"
        "def bad(ch, p, t):\n"
        "    ch.send('guest', 'host0', 'gh_debug', p, 8)\n"
        "    ch.send('guest', 'host0', t, p, 8)\n")})
    rules = sorted((f.rule, f.qualname) for f in wire.run(mods))
    assert rules == [("dynamic-tag", "bad"), ("unregistered-tag", "bad")]


def test_lock_pass_synthetic(tmp_path):
    # reuse a real contract: obs/trace.py Tracer guards _events via _lock
    mods = _tree_from(tmp_path, {"obs/trace.py": (
        "import threading\n"
        "class Tracer:\n"
        "    def __init__(self):\n"
        "        self._events = []\n"          # __init__ exempt
        "        self._lock = threading.Lock()\n"
        "    def good(self):\n"
        "        with self._lock:\n"
        "            return len(self._events)\n"
        "    def bad(self):\n"
        "        return len(self._events)\n")})
    found = [(f.qualname, f.rule) for f in locks.run(mods)]
    assert found == [("Tracer.bad", "unlocked-access")]


def test_dtype_pass_only_fires_on_lint_paths(tmp_path):
    src = ("import numpy as np\n"
           "def f(x):\n"
           "    return np.asarray(x)\n"
           "def g(x):\n"
           "    return np.asarray(x, dtype=np.float64)\n")
    mods = _tree_from(tmp_path, {"checkpoint/c.py": src,
                                 "core/free.py": src})
    found = [(f.module, f.qualname) for f in dtype_pass.run(mods)]
    assert found == [("checkpoint/c.py", "f")]


# ---------------------------------------------------------------------------
# the real tree is clean (modulo the reviewed baseline)
# ---------------------------------------------------------------------------

def test_analyzer_clean_on_real_tree():
    findings = analyze(ROOT)
    new, known, stale = report.diff_against_baseline(
        findings, report.load_baseline(BASELINE))
    assert not new, "unbaselined findings:\n" + \
        "\n".join(str(f) for f in new)
    assert not stale, f"baseline entries no longer produced: {stale}"
    assert known, "baseline diff saw no findings at all — passes broken?"


def test_cli_json_report_exits_zero():
    env = dict(os.environ, PYTHONPATH=os.path.dirname(ROOT),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--json"],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    out = json.loads(proc.stdout)
    assert out["summary"]["new"] == 0
    assert out["summary"]["stale_baseline"] == 0
    assert out["summary"]["total"] == out["summary"]["baselined"]


# ---------------------------------------------------------------------------
# seeded-mutation self-tests: each violation class is CAUGHT
# ---------------------------------------------------------------------------

def _mutated_tree(tmp_path, relpath: str, marker: str, insert: str):
    """Copy the real package, splice ``insert`` right after ``marker`` in
    ``relpath``, and return the parsed module list."""
    root = str(tmp_path / "repro")
    shutil.copytree(ROOT, root,
                    ignore=shutil.ignore_patterns("__pycache__"))
    path = os.path.join(root, relpath)
    with open(path) as f:
        src = f.read()
    assert marker in src, f"mutation marker drifted in {relpath}"
    with open(path, "w") as f:
        f.write(src.replace(marker, marker + insert, 1))
    return astutil.load_tree(root)


def _new_findings(run, mods):
    new, _, _ = report.diff_against_baseline(
        run(mods), report.load_baseline(BASELINE))
    return new


_ENC_ALL_MARKER = ("    blk = _stream_block(p, ctx.cipher, len(g_sel))\n"
                   "    if blk:\n"
                   "        _encrypt_all_chunked(ctx, g_sel, h_sel, blk)\n"
                   "        return")


def test_mutation_plaintext_gradient_leak_is_caught(tmp_path):
    """Shipping plaintext g_sel under a fresh tag from _encrypt_all must
    surface as a NEW taint finding (not absorbed by the baseline)."""
    mods = _mutated_tree(
        tmp_path, "core/tree.py", _ENC_ALL_MARKER,
        '\n    ctx.channel.send("guest", "host0", "gh_debug", g_sel, 8)')
    new = _new_findings(taint.run, mods)
    assert any(f.module == "core/tree.py" and f.rule == "unsanitized-flow"
               and "g_sel" in f.detail for f in new), \
        [str(f) for f in new]


def test_mutation_unregistered_tag_send_is_caught(tmp_path):
    mods = _mutated_tree(
        tmp_path, "core/tree.py", _ENC_ALL_MARKER,
        '\n    ctx.channel.send("guest", "host0", "dbg_probe", None, 0)')
    new = _new_findings(wire.run, mods)
    assert any(f.rule == "unregistered-tag" and "dbg_probe" in f.detail
               for f in new), [str(f) for f in new]


def test_mutation_unlocked_guarded_write_is_caught(tmp_path):
    mods = _mutated_tree(
        tmp_path, "runtime/transport.py",
        "    def close(self) -> None:\n        self.stop_broker()",
        '\n        self.tx_bytes["chaos"] += 1')
    new = _new_findings(locks.run, mods)
    assert any(f.qualname == "TransportChannel.close"
               and f.rule == "unlocked-access"
               and "tx_bytes" in f.detail for f in new), \
        [str(f) for f in new]


# ---------------------------------------------------------------------------
# runtime export audit (satellite: both leak directions)
# ---------------------------------------------------------------------------

def _arrays(names):
    return {k: np.zeros(1) for k in names}


def test_export_audit_accepts_declared_halves():
    export._audit_party({"role": "guest"}, _arrays(export._GUEST_ARRAYS))
    export._audit_party({"role": "host"}, _arrays(export._HOST_ARRAYS))


def test_export_audit_host_refuses_guest_content():
    with pytest.raises(ValueError, match="undeclared"):
        export._audit_party(
            {"role": "host"},
            _arrays(export._HOST_ARRAYS + ("leaf_w", "tree_class")))


def test_export_audit_guest_refuses_extra_arrays():
    with pytest.raises(ValueError, match="undeclared"):
        export._audit_party(
            {"role": "guest"},
            _arrays(export._GUEST_ARRAYS + ("split_gain",)))


def test_export_audit_refuses_secret_field_names_in_manifest():
    # the secret registry is checked over NESTED manifest keys too
    for secret in ("g", "labels", "_lam"):
        with pytest.raises(ValueError, match="secret field"):
            export._audit_party(
                {"role": "host", "stats": {secret: [0.5]}},
                _arrays(export._HOST_ARRAYS))


def test_export_audit_refuses_unknown_role():
    with pytest.raises(ValueError, match="unknown party role"):
        export._audit_party({"role": "auditor"}, {})


def test_write_party_audits_before_touching_disk(tmp_path):
    out = str(tmp_path / "host0")
    with pytest.raises(ValueError, match="undeclared"):
        export._write_party(out, {"role": "host"},
                            _arrays(export._HOST_ARRAYS + ("leaf_w",)))
    assert not os.path.exists(os.path.join(out, "arrays.npz"))
    assert not os.path.exists(os.path.join(out, "manifest.json"))


# ---------------------------------------------------------------------------
# dtype regression: the float64 state the lint protects stays float64
# ---------------------------------------------------------------------------

def test_restore_any_preserves_float64_bit_exact(tmp_path):
    score = np.linspace(-3.0, 3.0, 17).astype(np.float64)
    score[3] = 1.0 + 2.0 ** -40        # truncates to 1.0 in float32
    ckpt.save(str(tmp_path / "ck"), 0, {"score": score,
                                        "step": np.arange(3, dtype=np.int64)})
    out = ckpt.restore_any(str(tmp_path / "ck"), 0)
    f64 = [a for a in out.values() if a.dtype == np.float64]
    assert len(f64) == 1
    np.testing.assert_array_equal(f64[0], score)
    assert f64[0][3] != np.float32(f64[0][3])      # the bit the lint guards
