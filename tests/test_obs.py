"""Observability layer (DESIGN.md §14): tracer/metrics units, the Stats
merge round-trip property, monotonic liveness, and the federated trace
audit — tracing DISABLED must leave the transport runs bit-identical
with ≤2% wall-time overhead; tracing ENABLED must produce a merged
Perfetto trace whose per-party wire-event byte sums equal the converged
per-tag ``Channel`` ledger totals exactly (the trace is audited, not
decorative).
"""

import dataclasses
import json
import os
import tempfile
import time

import numpy as np
import pytest

try:        # property tests run where hypothesis exists (the CI jobs
            # install it); the deterministic cases below run everywhere
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import SBTParams, VerticalBoosting
from repro.core.party import Stats
from repro.obs.export import (audit_wire_events, estimate_offset,
                              merge_traces, self_time, trace_summary,
                              waterfall, wire_bytes_by_tag, write_perfetto)
from repro.obs.trace import NULL_TRACER, Tracer, _NULL_SPAN
from repro.runtime.transport import MultiHostRun


def _data(n=300, d=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d)
    y = (X @ w + 0.3 * rng.normal(0, 1, n) > 0).astype(np.float64)
    return X, y


# ---------------------------------------------------------------------------
# tracer + metrics units
# ---------------------------------------------------------------------------

def test_tracer_span_instant_complete_and_ring_drop():
    tr = Tracer("t", capacity=4)
    with tr.span("a", tree=1):
        pass
    tr.instant("b", cat="wire", nbytes=7)
    tr.complete("c", 100, 50, depth=2)
    assert len(tr) == 3 and tr.dropped == 0
    for _ in range(10):
        tr.instant("spam")
    assert len(tr) == 4                  # bounded ring: oldest dropped
    assert tr.dropped == 9
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_span_records_exception_and_duration():
    tr = Tracer("t")
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    ph, name, cat, ts, dur, tid, attrs = tr.export_events()[0]
    assert (ph, name, attrs["error"]) == ("X", "boom", "ValueError")
    assert dur >= 0 and ts > 0


def test_null_tracer_is_free_and_shared():
    assert NULL_TRACER.span("x") is _NULL_SPAN
    NULL_TRACER.instant("x")
    NULL_TRACER.complete("x", 0, 1)
    assert len(NULL_TRACER) == 0


def test_negative_duration_clamped():
    tr = Tracer("t")
    tr.complete("backwards", 100, -5)
    assert tr.export_events()[0][4] == 0


def test_estimate_offset_min_rtt_sample_wins():
    # sample 2 has the smaller RTT (4 ns) -> its midpoint decides
    samples = [(0, 1000, 100), (10, 1007, 14)]
    off, rtt = estimate_offset(samples)
    assert (off, rtt) == (1007 - 12, 4)
    assert estimate_offset([]) == (0, 0)


def test_merge_and_self_time_nested_attribution():
    ev = [["X", "outer", "train", 0, 100, 1, {}],
          ["X", "inner", "train", 10, 30, 1, {}]]
    merged = merge_traces([{"party": "p", "pid": 0, "events": ev,
                            "offset_ns": 0}])
    st_ = self_time(merged)
    assert st_ == {"outer": 70, "inner": 30}
    summ = trace_summary(merged)
    assert summ["events"] == 2
    assert summ["top_self_time"][0]["name"] == "outer"


def test_merge_applies_clock_offset():
    ev = [["i", "e", "wire", 1000, 0, 1, {"tag": "t", "nbytes": 3}]]
    merged = merge_traces([{"party": "h", "pid": 1, "events": ev,
                            "offset_ns": 400}])
    assert merged[0]["ts_ns"] == 600


def test_wire_audit_detects_mismatch_and_passes_exact():
    ev = [["i", "enc_gh", "wire", 0, 0, 1, {"tag": "enc_gh", "nbytes": 10}],
          ["i", "enc_gh", "wire", 1, 0, 1, {"tag": "enc_gh", "nbytes": 5}],
          ["X", "ship", "transport", 2, 9, 1,
           {"tag": "enc_gh", "nbytes": 999}]]      # physical: excluded
    assert wire_bytes_by_tag(ev) == {"enc_gh": 15}
    assert audit_wire_events(ev, {"enc_gh": 15}) == {}
    assert audit_wire_events(ev, {"enc_gh": 16}) == {"enc_gh": (15, 16)}
    assert audit_wire_events(ev, {"enc_gh": 15, "other": 4}) == {
        "other": (0, 4)}


def test_perfetto_export_and_waterfall(tmp_path):
    ev = [["X", "layer", "train", 1000, 2000, 1, {"tree": 0}],
          ["i", "mark", "chaos", 1500, 0, 1, {}]]
    merged = merge_traces([{"party": "guest", "pid": 0, "events": ev,
                            "offset_ns": 0}])
    path = tmp_path / "trace.json"
    write_perfetto(str(path), merged,
                   [{"party": "guest", "pid": 0}])
    data = json.loads(path.read_text())
    phases = [e["ph"] for e in data["traceEvents"]]
    assert phases == ["M", "X", "i"]
    assert data["traceEvents"][1]["dur"] == 2.0     # µs
    text = waterfall(merged)
    assert "tree 0" in text and "layer" in text


def test_metrics_registry_snapshot_and_clear():
    from repro.obs.metrics import MetricsRegistry
    m = MetricsRegistry()
    m.counter("c").add(2)
    m.counter("c").add()
    m.gauge("g").observe(5)
    m.gauge("g").observe(3)              # gauge keeps the max
    m.histogram("h").observe(1.0)
    m.histogram("h").observe(3.0)
    m.series("s").data.extend([1, 2])
    snap = m.snapshot()
    assert snap["counters"]["c"] == 3.0
    assert snap["gauges"]["g"] == 5.0
    assert snap["histograms"]["h"]["count"] == 2
    assert snap["histograms"]["h"]["mean"] == 2.0
    assert snap["series"]["s"] == [1, 2]
    m.clear()
    assert m.snapshot() == {"counters": {}, "gauges": {}, "histograms": {},
                            "series": {}}


# ---------------------------------------------------------------------------
# Stats: metrics-backed timers + version-skew-safe merge
# ---------------------------------------------------------------------------

def test_stats_timer_and_series_properties_behave_like_fields():
    s = Stats()
    s.encrypt_seconds += 1.5
    s.encrypt_seconds += 0.5
    assert s.encrypt_seconds == 2.0
    s.tree_seconds.append(0.25)
    s.tree_seconds.extend([0.5, 0.75])
    del s.tree_seconds[1:]               # rollback_to_round truncation
    assert s.tree_seconds == [0.25]
    d = s.as_dict()                      # wire format keeps the old keys
    assert d["encrypt_seconds"] == 2.0 and d["tree_seconds"] == [0.25]
    s2 = Stats()
    s2.merge_counts(d)
    assert s2.encrypt_seconds == 2.0 and s2.tree_seconds == [0.25]


def _merge_roundtrip_case(parties):
    """Merging N per-party ``as_dict()`` snapshots must reconstruct the
    single shared-Stats view of an in-process run: counters add, gauges
    max, lists concatenate (integer-valued floats keep sums exact)."""
    shared = Stats()
    dicts = []
    for p in parties:
        s = Stats()
        for k in ("n_encrypt", "n_hom_add"):
            setattr(s, k, getattr(s, k) + p[k])
            setattr(shared, k, getattr(shared, k) + p[k])
        s.peak_frontier = max(s.peak_frontier, p["peak_frontier"])
        shared.peak_frontier = max(shared.peak_frontier, p["peak_frontier"])
        for k in ("encrypt_seconds", "host_wait_seconds"):
            setattr(s, k, getattr(s, k) + float(p[k]))
            setattr(shared, k, getattr(shared, k) + float(p[k]))
        for k in ("tree_seconds", "layer_overlap"):
            getattr(s, k).extend(float(v) for v in p[k])
            getattr(shared, k).extend(float(v) for v in p[k])
        dicts.append(s.as_dict())
    merged = Stats()
    for d in dicts:
        merged.merge_counts(d)
    assert merged.as_dict() == shared.as_dict()
    assert merged.unmerged == {}


def test_stats_merge_roundtrip_deterministic_cases():
    _merge_roundtrip_case([
        {"n_encrypt": 3, "n_hom_add": 0, "peak_frontier": 7,
         "encrypt_seconds": 2, "host_wait_seconds": 0,
         "tree_seconds": [1, 2], "layer_overlap": []},
        {"n_encrypt": 0, "n_hom_add": 11, "peak_frontier": 2,
         "encrypt_seconds": 5, "host_wait_seconds": 3,
         "tree_seconds": [], "layer_overlap": [4]},
        {"n_encrypt": 1, "n_hom_add": 1, "peak_frontier": 1,
         "encrypt_seconds": 0, "host_wait_seconds": 0,
         "tree_seconds": [0], "layer_overlap": [0, 0]},
    ])


if HAVE_HYPOTHESIS:
    _INT = st.integers(0, 1000)

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.fixed_dictionaries({
        "n_encrypt": _INT, "n_hom_add": _INT, "peak_frontier": _INT,
        "encrypt_seconds": _INT, "host_wait_seconds": _INT,
        "tree_seconds": st.lists(_INT, max_size=4),
        "layer_overlap": st.lists(_INT, max_size=3),
    }), min_size=1, max_size=4))
    def test_stats_merge_roundtrip_matches_shared(parties):
        _merge_roundtrip_case(parties)


def test_stats_merge_version_skew_lands_in_unmerged():
    s = Stats()
    s.merge_counts({"future_counter": 3, "future_list": [1], "n_encrypt": 2})
    s.merge_counts({"future_counter": 4, "future_list": [2],
                    "future_tag": "x"})
    assert s.n_encrypt == 2
    assert s.unmerged == {"future_counter": 7, "future_list": [1, 2],
                          "future_tag": "x"}


# ---------------------------------------------------------------------------
# monotonic liveness (runtime/fault.py satellite)
# ---------------------------------------------------------------------------

def test_heartbeat_liveness_survives_wallclock_steps(tmp_path):
    """An NTP wall-clock step must not change the liveness verdict: a
    beat whose file mtime CHANGED is alive even if the stamp reads hours
    in the past (backward step), and a peer is wedged only after its
    mtime stays unchanged for ``timeout`` seconds of the observer's own
    monotonic clock."""
    from repro.runtime.fault import Heartbeat
    path = str(tmp_path / "hb")
    Heartbeat(path).beat()
    assert Heartbeat.is_alive(path, timeout=5.0)
    # backward wall-clock step: the beat's stamp/mtime jumps an hour into
    # the past — under the old wall-clock compare this read as >timeout
    # stale and triggered a pointless restart
    past = time.time() - 3600
    os.utime(path, (past, past))
    assert Heartbeat.is_alive(path, timeout=5.0)
    # the mtime keeps CHANGING (peer still beating on its skewed clock):
    # alive, forever, regardless of the stamp value
    os.utime(path, (past - 100, past - 100))
    assert Heartbeat.is_alive(path, timeout=5.0)
    # mtime UNCHANGED past the monotonic timeout: wedged
    time.sleep(0.05)
    assert not Heartbeat.is_alive(path, timeout=0.01)
    # missing file: dead
    assert not Heartbeat.is_alive(str(tmp_path / "gone"), timeout=5.0)


# ---------------------------------------------------------------------------
# federated runs: disabled = bit-identical + cheap; enabled = audited
# ---------------------------------------------------------------------------

def _fit_params(**kw):
    base = dict(n_trees=2, max_depth=3, n_bins=16, cipher="plain", seed=3)
    base.update(kw)
    return SBTParams(**base)


def test_loopback_tracing_enabled_is_audited_per_party():
    """Loopback 2-party run with tracing on: each party's wire-event
    byte sums must equal its converged per-tag ledger totals EXACTLY,
    and the model must match a tracing-off oracle bit for bit (tracing
    is observation only, never control flow)."""
    X, y = _data(n=300)
    Xg, Xh = X[:, :3], [X[:, 3:]]
    ref = VerticalBoosting(_fit_params()).fit(Xg, y, Xh)
    run = MultiHostRun(_fit_params(trace=True), Xh, transport="loopback",
                       export_dir=tempfile.mkdtemp())
    try:
        model = run.fit(Xg, y)
        np.testing.assert_array_equal(model.train_score_, ref.train_score_)
        assert run.channel.summary() == ref.channel.summary()
        # guest audit: its tracer vs its own ledger
        assert model.tracer.enabled and model.tracer.dropped == 0
        assert audit_wire_events(model.tracer.export_events(),
                                 run.channel.totals) == {}
        # host audit: its own tracer vs its own (converged) ledger
        pp = run.parties[0]
        assert pp.tracer.enabled and pp.tracer.dropped == 0
        assert audit_wire_events(pp.tracer.export_events(),
                                 pp.channel.totals) == {}
        # both parties recorded training spans, not just wire instants
        g_names = {e[1] for e in model.tracer.export_events()}
        h_names = {e[1] for e in pp.tracer.export_events()}
        assert {"round", "tree", "layer", "encrypt"} <= g_names
        assert "host_layer" in h_names
    finally:
        run.close()


def test_loopback_trace_merge_and_party_status(tmp_path):
    X, y = _data(n=250, seed=1)
    Xg, Xh = X[:, :3], [X[:, 3:]]
    run = MultiHostRun(_fit_params(trace=True), Xh, transport="loopback",
                       export_dir=tempfile.mkdtemp())
    try:
        run.fit(Xg, y)
        path = tmp_path / "trace.json"
        merged = run.trace(str(path))
        assert {e["party"] for e in merged} == {"guest", "host0"}
        data = json.loads(path.read_text())
        meta = {e["args"]["name"] for e in data["traceEvents"]
                if e["ph"] == "M"}
        assert meta == {"guest", "host0"}
        assert "tree 0" in waterfall(merged)
        # live introspection over the control plane
        status = run.party_status(0)
        assert status["trace"]["enabled"] and status["trace"]["events"] > 0
        assert status["stats"]["n_hist_launches"] > 0
        assert status["n_complete"] >= 1
        # per-tag RTT histograms landed in the guest's transport metrics
        rtts = run.channel.metrics.snapshot()["histograms"]
        assert any(k.startswith("rtt:") for k in rtts)
    finally:
        run.close()


def test_socket_tracing_disabled_bit_identical_enabled_audited(tmp_path):
    """The acceptance run: forced-2-process socket training.  With
    tracing DISABLED the model and per-tag ledgers are identical to the
    in-process oracle (zero-cost contract); with tracing ENABLED the
    model is STILL bit-identical, and the merged Perfetto trace's
    guest+host wire spans sum exactly to the per-tag ledger totals."""
    X, y = _data(n=250)
    Xg, Xh = X[:, :3], [X[:, 3:]]
    ref = VerticalBoosting(_fit_params()).fit(Xg, y, Xh)

    run = MultiHostRun(_fit_params(), Xh, transport="socket",
                       export_dir=tempfile.mkdtemp(), timeout=300.0)
    try:
        model_off = run.fit(Xg, y)
        np.testing.assert_array_equal(model_off.train_score_,
                                      ref.train_score_)
        assert run.channel.summary() == ref.channel.summary()
        assert not model_off.tracer.enabled     # NULL tracer end to end
    finally:
        run.close()

    run = MultiHostRun(_fit_params(trace=True), Xh, transport="socket",
                       export_dir=tempfile.mkdtemp(), timeout=300.0)
    try:
        model = run.fit(Xg, y)
        np.testing.assert_array_equal(model.train_score_, ref.train_score_)
        assert run.channel.summary() == ref.channel.summary()
        # guest audit against the converged ledger
        assert audit_wire_events(model.tracer.export_events(),
                                 run.channel.totals) == {}
        # host audit: its trace ships over the trace_sync control tag;
        # its ledger converged to the same per-tag totals by mirroring
        dumps = run.collect_traces()
        assert dumps[0]["dropped"] == 0
        assert audit_wire_events(dumps[0]["events"],
                                 run.channel.totals) == {}
        # one merged Perfetto file with BOTH parties' events on the
        # guest timeline
        path = tmp_path / "trace.json"
        merged = run.trace(str(path))
        assert {e["party"] for e in merged} == {"guest", "host0"}
        assert path.stat().st_size > 0
        # host status over the wire mirrors the local status() shape
        status = run.party_status(0)
        assert status["trace"]["enabled"]
        assert "transport" in status and "metrics" in status
    finally:
        run.close()


def test_tracing_off_overhead_within_bound():
    """The zero-cost-when-disabled contract, measured: paired loopback
    fits with the obs layer present-but-disabled vs enabled.  The
    DISABLED side is the default path every existing benchmark takes, so
    it must not regress; the bound is the same style as PR 6's
    ``resilient_overhead`` (min-of-N, small tolerance)."""
    X, y = _data(n=400)
    Xg, Xh = X[:, :3], [X[:, 3:]]

    def one_fit(trace: bool) -> float:
        run = MultiHostRun(_fit_params(trace=trace), Xh,
                           transport="loopback",
                           export_dir=tempfile.mkdtemp())
        try:
            t0 = time.perf_counter()
            run.fit(Xg, y)
            return time.perf_counter() - t0
        finally:
            run.close()

    one_fit(False)                       # warm the jits once per side —
    one_fit(True)                        # both paths hit the same caches
    # timing in CI is noisy: interleave the sides so machine-load drift
    # hits both equally, take min-of-N per side, and accept the first
    # attempt that lands inside the bound
    last = None
    for _ in range(4):
        offs, ons = [], []
        for _ in range(4):
            offs.append(one_fit(False))
            ons.append(one_fit(True))
        last = (min(ons) / min(offs) - 1) * 100
        if last <= 2.0:
            return
    pytest.fail(f"tracing-enabled overhead {last:.2f}% > 2% "
                f"(disabled path must stay free; enabled must stay cheap)")


# ---------------------------------------------------------------------------
# chaos: injected faults must appear in the trace (CI chaos job, -k chaos)
# ---------------------------------------------------------------------------

def test_chaos_injected_fault_appears_as_trace_event():
    """Every FaultPlan rule that fires becomes an annotated ``chaos``
    instant in the faulted party's trace — collected over ``trace_sync``
    from the real spawned host process."""
    from repro.runtime.chaos import RECV, Delay, FaultPlan
    X, y = _data(n=200)
    Xg, Xh = X[:, :3], [X[:, 3:]]
    plans = {0: FaultPlan(rules=[Delay(tag="assign_sync", nth=1,
                                       direction=RECV, seconds=0.01)])}
    run = MultiHostRun(_fit_params(n_trees=1, max_depth=2, trace=True),
                       Xh, transport="socket", fault_plans=plans,
                       export_dir=tempfile.mkdtemp(), timeout=300.0)
    try:
        run.fit(Xg, y)
        events = run.collect_traces()[0]["events"]
        chaos = [e for e in events if e[2] == "chaos"]
        assert len(chaos) == 1
        ph, name, cat, ts, dur, tid, attrs = chaos[0]
        assert name == "fault_injected"
        assert attrs["rule"] == "Delay"
        assert attrs["tag"] == "assign_sync" and attrs["count"] == 1
    finally:
        run.close()
