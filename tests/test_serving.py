"""Serving subsystem: packed ensembles, round-batched bit protocol,
per-party export (DESIGN.md §9).

The load-bearing claim is *bit-identity*: the packed engine must reproduce
the legacy ``predict_tree`` loop exactly (routing is integer work; the
float accumulation replays the same per-tree order), for every objective
and cipher, from live models and from reloaded per-party halves, on one
device and on a forced multi-device mesh.
"""

import json
import os

import numpy as np
import pytest

import jax

from repro.core import LocalGBDT, SBTParams, VerticalBoosting
from repro.core.binning import apply_binning, bin_features
from repro.serving import (FederatedPredictor, PackedEnsemble, export_model,
                           load_ensemble, load_guest, load_host)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count>=2")


def _data(n=400, d=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d)
    y = (X @ w + 0.3 * rng.normal(0, 1, n) > 0).astype(np.float64)
    return X, y


def _multi_labels(X, seed=0):
    rng = np.random.default_rng(seed)
    s = X @ rng.normal(0, 1, X.shape[1])
    return ((s > np.quantile(s, 0.33)).astype(float)
            + (s > np.quantile(s, 0.66)).astype(float))


def _split(X):
    return X[:, :2], [X[:, 2:4], X[:, 4:]]


# ---------------------------------------------------------------------------
# bit-identity of the packed engine vs the legacy loop
# ---------------------------------------------------------------------------

def test_packed_bit_identical_binary_multihost():
    X, y = _data()
    Xg, Xh = _split(X)
    fed = VerticalBoosting(SBTParams(n_trees=4, max_depth=3,
                                     n_bins=16)).fit(Xg, y, Xh)
    Xn, _ = _data(n=237, seed=3)           # fresh rows, n % 8 != 0
    Xng, Xnh = _split(Xn)
    np.testing.assert_array_equal(
        fed.predict_score(Xng, Xnh),
        fed.predict_score(Xng, Xnh, packed=False))
    # train rows too, and through the local (zero-host) baseline
    np.testing.assert_array_equal(fed.predict_score(Xg, Xh),
                                  fed.predict_score(Xg, Xh, packed=False))
    loc = LocalGBDT(SBTParams(n_trees=3, max_depth=3, n_bins=16)).fit(X, y)
    np.testing.assert_array_equal(loc.predict_score(X),
                                  loc.predict_score(X, packed=False))


@pytest.mark.parametrize("objective", ["multiclass", "mo"])
def test_packed_bit_identical_multiclass_and_mo(objective):
    X, _ = _data(n=450)
    y = _multi_labels(X)
    m = VerticalBoosting(SBTParams(n_trees=3, max_depth=3,
                                   objective=objective, n_classes=3)).fit(
        X[:, :3], y, [X[:, 3:]])
    Xn, _ = _data(n=201, seed=5)
    np.testing.assert_array_equal(
        m.predict_score(Xn[:, :3], [Xn[:, 3:]]),
        m.predict_score(Xn[:, :3], [Xn[:, 3:]], packed=False))


@pytest.mark.parametrize("kw", [dict(tree_mode="mix"),
                                dict(tree_mode="layered", host_depth=2),
                                dict(goss=True, seed=1),
                                dict(sparse=True),
                                dict(cipher="affine", key_bits=256,
                                     precision=20)])
def test_packed_bit_identical_modes_and_ciphers(kw):
    """Mode/cipher coverage: trees with empty host tables (mix), guest-only
    depths (layered), GOSS row subsets, sparse binning, affine training."""
    X, y = _data(n=350, seed=2)
    m = VerticalBoosting(SBTParams(n_trees=4, max_depth=3, n_bins=16,
                                   **kw)).fit(X[:, :3], y, [X[:, 3:]])
    np.testing.assert_array_equal(
        m.predict_score(X[:, :3], [X[:, 3:]]),
        m.predict_score(X[:, :3], [X[:, 3:]], packed=False))


# ---------------------------------------------------------------------------
# wire protocol: exactly one round-trip per host per batch
# ---------------------------------------------------------------------------

def test_one_roundtrip_per_host_per_batch():
    X, y = _data()
    Xg, Xh = _split(X)
    fed = VerticalBoosting(SBTParams(n_trees=3, max_depth=3,
                                     n_bins=16)).fit(Xg, y, Xh)
    base_rt = fed.stats.n_predict_roundtrips
    ens = PackedEnsemble.from_model(fed)
    pred = FederatedPredictor(ens.guest, ens.hosts)   # fresh ledgers
    n = 203
    Xn, _ = _data(n=n, seed=7)
    Xng, Xnh = _split(Xn)
    pred.predict_score(Xng, Xnh)
    s = pred.channel.summary()
    # one predict_req + one predict_bits per host, per batch — regardless
    # of tree count, depth, or frontier shape
    assert s["predict_req"]["msgs"] == 2
    assert s["predict_bits"]["msgs"] == 2
    assert pred.stats.n_predict_roundtrips == 2
    assert pred.stats.n_predict_batches == 1
    # analytic payload: 1 bit per owned internal node per instance
    k_hosts = [int(k) for k in ens.guest.k_parties[1:]]
    assert s["predict_bits"]["bytes"] == sum(k * ((n + 7) // 8)
                                             for k in k_hosts)
    assert s["predict_req"]["bytes"] == 2 * n * 4
    pred.predict_score(Xng, Xnh)                      # second batch
    assert pred.channel.summary()["predict_bits"]["msgs"] == 4
    assert pred.stats.n_predict_roundtrips == 4
    # wrong party count must refuse loudly, not mis-route silently
    with pytest.raises(ValueError, match="host matrices"):
        pred.predict_score(Xng, Xnh[:1])
    with pytest.raises(ValueError, match="host matrices"):
        pred.predict_score_binned(np.zeros((8, 2), np.int32),
                                  [np.zeros((8, 2), np.int32)])
    # a guest half whose split slice disagrees with k_parties is corrupt
    import dataclasses
    bad = dataclasses.replace(
        ens.guest, guest=dataclasses.replace(
            ens.guest.guest, fid=ens.guest.guest.fid[:-1],
            bid=ens.guest.guest.bid[:-1]))
    with pytest.raises(ValueError, match="guest split table"):
        FederatedPredictor(bad, ens.hosts)
    # the model-attached engine tallies into the model's own ledgers
    assert fed.stats.n_predict_roundtrips == base_rt
    fed.predict_score(Xng, Xnh)
    assert fed.stats.n_predict_roundtrips == base_rt + 2
    assert "predict_bits" in fed.channel.summary()


# ---------------------------------------------------------------------------
# export -> import round-trip
# ---------------------------------------------------------------------------

def _assert_roundtrip(model, out_dir, Xg, Xh):
    export_model(model, out_dir)
    ens = load_ensemble(out_dir)
    pred = FederatedPredictor(ens.guest, ens.hosts)
    np.testing.assert_array_equal(
        pred.predict_score(Xg, Xh),
        model.predict_score(Xg, Xh, packed=False))
    return ens


@pytest.mark.parametrize("objective,cipher",
                         [("binary", "plain"), ("binary", "affine"),
                          ("multiclass", "plain"), ("mo", "affine")])
def test_export_import_roundtrip(tmp_path, objective, cipher):
    """Guest/host halves saved separately, reloaded, and served —
    bit-identical to predict_tree, for plain and affine-trained models."""
    X, yb = _data(n=350, seed=4)
    y = yb if objective == "binary" else _multi_labels(X, seed=4)
    kw = dict(cipher=cipher)
    if cipher == "affine":
        kw.update(key_bits=256, precision=20)
    if objective != "binary":
        kw.update(n_classes=3)
    m = VerticalBoosting(SBTParams(n_trees=3, max_depth=3, n_bins=16,
                                   objective=objective, **kw)).fit(
        X[:, :3], y, [X[:, 3:]])
    out = str(tmp_path / "model")
    ens = _assert_roundtrip(m, out, X[:, :3], [X[:, 3:]])
    # halves live in separate per-party dirs; the host dir carries ONLY
    # its split table + binning — no tree structure, no leaf weights
    assert sorted(os.listdir(out)) == ["guest", "host0"]
    with np.load(os.path.join(out, "host0", "arrays.npz")) as z:
        assert sorted(z.files) == ["bid", "fid", "thresholds"]
    assert ens.hosts[0].table.k == int(ens.guest.k_parties[1])


def test_export_is_atomic_and_overwrites(tmp_path):
    X, y = _data(n=250, seed=6)
    m = VerticalBoosting(SBTParams(n_trees=2, max_depth=2, n_bins=8)).fit(
        X[:, :3], y, [X[:, 3:]])
    out = str(tmp_path / "model")
    export_model(m, out)
    first = load_guest(os.path.join(out, "guest"))
    export_model(m, out)                    # overwrite publishes atomically
    again = load_guest(os.path.join(out, "guest"))
    np.testing.assert_array_equal(first.step, again.step)
    assert not os.path.exists(out + ".tmp-export")
    assert not os.path.exists(out + ".stale-export")


def test_corrupted_manifest_raises(tmp_path):
    X, y = _data(n=250, seed=6)
    m = VerticalBoosting(SBTParams(n_trees=2, max_depth=2, n_bins=8)).fit(
        X[:, :3], y, [X[:, 3:]])
    out = str(tmp_path / "model")
    export_model(m, out)
    gman = os.path.join(out, "guest", "manifest.json")
    # truncated JSON
    with open(gman) as f:
        good = f.read()
    with open(gman, "w") as f:
        f.write(good[: len(good) // 2])
    with pytest.raises(ValueError, match="corrupt"):
        load_guest(os.path.join(out, "guest"))
    # wrong role
    man = json.loads(good)
    man["role"] = "host"
    with open(gman, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match="role"):
        load_guest(os.path.join(out, "guest"))
    # shape mismatch between manifest and arrays
    man = json.loads(good)
    man["arrays"]["step"]["shape"] = [1, 2]
    with open(gman, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match="shape"):
        load_guest(os.path.join(out, "guest"))
    # missing array metadata
    man = json.loads(good)
    del man["arrays"]["roots"]
    with open(gman, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match="missing array"):
        load_guest(os.path.join(out, "guest"))
    # host manifest with bad format marker
    hman = os.path.join(out, "host0", "manifest.json")
    with open(hman) as f:
        h = json.load(f)
    h["format"] = "something-else"
    with open(hman, "w") as f:
        json.dump(h, f)
    with pytest.raises(ValueError, match="format"):
        load_host(os.path.join(out, "host0"))
    # dtype swap with identical shape must not mis-serve silently
    with open(gman, "w") as f:
        f.write(good)
    az = os.path.join(out, "guest", "arrays.npz")
    with np.load(az) as z:
        arrays = {k: z[k] for k in z.files}
    arrays["leaf_w"] = arrays["leaf_w"].astype(np.float32)
    np.savez_compressed(az, **arrays)
    with pytest.raises(ValueError, match="dtype"):
        load_guest(os.path.join(out, "guest"))
    # truncated npz surfaces as ValueError, not zipfile.BadZipFile
    with open(az, "rb") as f:
        raw = f.read()
    with open(az, "wb") as f:
        f.write(raw[: len(raw) // 2])
    with pytest.raises(ValueError, match="corrupt serving arrays"):
        load_guest(os.path.join(out, "guest"))


# ---------------------------------------------------------------------------
# no row-level training state on models / exports
# ---------------------------------------------------------------------------

def test_no_row_level_training_state(tmp_path):
    n_train = 389                           # prime-ish: can't alias a node
    X, y = _data(n=n_train, seed=8)         # or feature dimension
    m = VerticalBoosting(SBTParams(n_trees=3, max_depth=3, n_bins=16)).fit(
        X[:, :3], y, [X[:, 3:]])
    # the grower returns leaf_rows to the driver; trees never carry it
    assert all(not hasattr(t, "leaf_rows") for t in m.trees)
    out = str(tmp_path / "model")
    export_model(m, out)
    for party in sorted(os.listdir(out)):
        with np.load(os.path.join(out, party, "arrays.npz")) as z:
            for name in z.files:
                assert n_train not in z[name].shape, \
                    f"{party}/{name} has a training-row-sized axis"
    # packing a tree that somehow kept row state must refuse
    m.trees[0].leaf_rows = {0: np.arange(n_train)}
    with pytest.raises(AssertionError, match="row-level"):
        PackedEnsemble.from_model(m)


# ---------------------------------------------------------------------------
# device-resident threshold cache (binning satellite)
# ---------------------------------------------------------------------------

def test_thresholds_cached_on_device():
    X, _ = _data(n=300, seed=9)
    data = bin_features(X, 16)
    thr1 = data.device_thresholds()
    thr2 = data.device_thresholds()
    assert thr1 is thr2                     # uploaded once, reused
    assert isinstance(thr1, jax.Array)
    Xn, _ = _data(n=123, seed=10)
    b1 = apply_binning(Xn, data)
    b2 = apply_binning(Xn, data, use_pallas=False)
    np.testing.assert_array_equal(b1, b2)
    # fresh binning (no cache) agrees
    np.testing.assert_array_equal(
        b1, np.asarray(
            __import__("repro.kernels.binning", fromlist=["bucketize"])
            .bucketize(Xn, data.thresholds)).astype(np.int32))


# ---------------------------------------------------------------------------
# mesh-sharded serving (multi-device only)
# ---------------------------------------------------------------------------

@multi_device
def test_mesh_serving_bit_identical():
    """Acceptance: packed serving on the forced multi-device CPU mesh is
    bit-identical to single-device serving and to predict_tree, for binary
    and multiclass models (rows shard over "data"; no collective)."""
    from repro.launch.mesh import make_gbdt_mesh
    mesh = make_gbdt_mesh()
    X, y = _data(n=437, seed=11)            # non-divisible row count
    for objective in ("binary", "multiclass"):
        yy = y if objective == "binary" else _multi_labels(X, seed=11)
        kw = {} if objective == "binary" else dict(n_classes=3)
        m = VerticalBoosting(SBTParams(n_trees=3, max_depth=4, n_bins=16,
                                       objective=objective, mesh=mesh,
                                       **kw)).fit(X[:, :3], yy, [X[:, 3:]])
        legacy = m.predict_score(X[:, :3], [X[:, 3:]], packed=False)
        meshed = m.predict_score(X[:, :3], [X[:, 3:]])
        ens = PackedEnsemble.from_model(m)
        onedev = FederatedPredictor(ens.guest, ens.hosts).predict_score(
            X[:, :3], [X[:, 3:]])
        np.testing.assert_array_equal(meshed, legacy)
        np.testing.assert_array_equal(onedev, legacy)
