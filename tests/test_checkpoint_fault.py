"""Regression tests: checkpoint leaf-name collisions and ResilientLoop's
lost-final-save / restore-before-first-save paths."""

import os

import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.runtime.fault import ResilientLoop, StragglerPolicy


# ---------------------------------------------------------------------------
# checkpoint: path-join collisions
# ---------------------------------------------------------------------------

def test_leaf_name_collision_roundtrips(tmp_path):
    """``{"a__b": x}`` and ``{"a": {"b": y}}`` used to flatten to the SAME
    .npz name — the later leaf silently overwrote the earlier one and
    ``restore`` returned y for x.  Deterministic de-collision must round-
    trip both leaves exactly."""
    tree = {"a__b": np.arange(4, dtype=np.float32),
            "a": {"b": np.full(3, 7.5, np.float64)}}
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, tree)
    like = {"a__b": np.zeros(4, np.float32),
            "a": {"b": np.zeros(3, np.float64)}}
    out = ckpt.restore(d, 1, like)
    np.testing.assert_array_equal(np.asarray(out["a__b"]), tree["a__b"])
    np.testing.assert_array_equal(np.asarray(out["a"]["b"]), tree["a"]["b"])
    # two distinct files really exist (no silent overwrite)
    src = os.path.join(d, "step_00000001")
    npz = [f for f in os.listdir(src) if f.endswith(".npz")]
    assert len(npz) == 2


def test_leaf_name_suffix_cannot_collide_with_real_leaf(tmp_path):
    """The de-collision suffix must be a fixpoint: a genuine leaf named
    ``a__b#1`` must not collide with the suffixed rename of a colliding
    ``a__b`` pair."""
    tree = {"a": {"b": np.full(2, 1.0)}, "a__b": np.full(2, 2.0),
            "a__b#1": np.full(2, 3.0)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, tree)
    src = os.path.join(d, "step_00000001")
    assert len([f for f in os.listdir(src) if f.endswith(".npz")]) == 3
    out = ckpt.restore(d, 1, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]["b"]), tree["a"]["b"])
    np.testing.assert_array_equal(np.asarray(out["a__b"]), tree["a__b"])
    np.testing.assert_array_equal(np.asarray(out["a__b#1"]), tree["a__b#1"])


def test_leaf_names_stable_without_collisions(tmp_path):
    """Non-colliding checkpoints keep their historical names (format
    compatibility: no suffix unless needed)."""
    tree = {"w": np.ones(2), "b": np.zeros(2)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, tree)
    src = os.path.join(d, "step_00000003")
    assert sorted(f for f in os.listdir(src) if f.endswith(".npz")) == \
        ["b.npz", "w.npz"]
    out = ckpt.restore(d, 3, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


# ---------------------------------------------------------------------------
# ResilientLoop: final save + restore fallback
# ---------------------------------------------------------------------------

class _Store:
    """In-memory save/restore with call log."""

    def __init__(self):
        self.saved = {}
        self.save_calls = []

    def save(self, step, state):
        self.saved = {"step": step, "state": state}
        self.save_calls.append(step)

    def restore(self):
        if not self.saved:
            raise FileNotFoundError("no checkpoint on disk")
        return self.saved["step"], self.saved["state"]


def test_final_state_saved_when_n_steps_not_multiple_of_save_every():
    """7 steps with save_every=5 used to end with only step 5 on disk: a
    crash after run() returned replayed steps 6-7.  The loop must save on
    exit."""
    store = _Store()
    loop = ResilientLoop(step_fn=lambda s, b: s + 1, save_fn=store.save,
                         restore_fn=store.restore,
                         next_batch=lambda i: None, save_every=5)
    step, state = loop.run(0, 0, 7)
    assert (step, state) == (7, 7)
    assert store.save_calls == [5, 7]
    assert store.saved == {"step": 7, "state": 7}


def test_no_double_save_on_aligned_exit():
    store = _Store()
    loop = ResilientLoop(step_fn=lambda s, b: s + 1, save_fn=store.save,
                         restore_fn=store.restore,
                         next_batch=lambda i: None, save_every=5)
    loop.run(0, 0, 10)
    assert store.save_calls == [5, 10]


def test_zero_step_run_is_io_free():
    """Resuming a job already at n_steps must not rewrite (and gc) the
    existing checkpoint."""
    store = _Store()
    loop = ResilientLoop(step_fn=lambda s, b: s + 1, save_fn=store.save,
                         restore_fn=store.restore,
                         next_batch=lambda i: None, save_every=5)
    assert loop.run(42, 7, 7) == (7, 42)
    assert store.save_calls == []


def test_failure_before_first_save_replays_from_initial_state():
    """A transient failure at step 0 used to call restore_fn() with no
    checkpoint on disk and crash; it must fall back to the caller's
    (start_step, initial state) and replay."""
    store = _Store()
    boom = {"armed": True}

    def step_fn(state, batch):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("transient device error")
        return state + 1

    loop = ResilientLoop(step_fn=step_fn, save_fn=store.save,
                         restore_fn=store.restore,
                         next_batch=lambda i: None, save_every=100,
                         backoff=0.0)
    step, state = loop.run(0, 0, 3)
    assert (step, state) == (3, 3)
    assert loop.failures == 1
    assert store.saved["step"] == 3          # final save still happens


def test_failure_after_a_save_restores_from_checkpoint():
    store = _Store()
    fail_at = {"step": 6, "done": False}

    def step_fn(state, batch):
        if state == fail_at["step"] and not fail_at["done"]:
            fail_at["done"] = True
            raise RuntimeError("transient")
        return state + 1

    loop = ResilientLoop(step_fn=step_fn, save_fn=store.save,
                         restore_fn=store.restore,
                         next_batch=lambda i: None, save_every=5,
                         backoff=0.0)
    step, state = loop.run(0, 0, 8)
    assert (step, state) == (8, 8)
    assert store.save_calls[0] == 5 and store.save_calls[-1] == 8


def test_corrupt_checkpoint_error_surfaces():
    """Only a MISSING checkpoint falls back to the initial state; a
    present-but-unreadable one (corruption, I/O hiccup) must raise, not
    silently restart training from scratch."""
    store = _Store()

    def bad_restore():
        raise ValueError("corrupt checkpoint: bad magic")

    boom = {"armed": True}

    def step_fn(state, batch):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("transient")
        return state + 1

    loop = ResilientLoop(step_fn=step_fn, save_fn=store.save,
                         restore_fn=bad_restore,
                         next_batch=lambda i: None, save_every=100,
                         backoff=0.0)
    with pytest.raises(ValueError, match="corrupt checkpoint"):
        loop.run(0, 0, 3)


def test_persistent_failure_still_raises():
    store = _Store()

    def step_fn(state, batch):
        raise RuntimeError("hard fault")

    loop = ResilientLoop(step_fn=step_fn, save_fn=store.save,
                         restore_fn=store.restore,
                         next_batch=lambda i: None, save_every=5,
                         max_retries=2, backoff=0.0,
                         straggler=StragglerPolicy())
    with pytest.raises(RuntimeError, match="hard fault"):
        loop.run(0, 0, 3)
