"""Sharding rule table + model-variant (SP / remat policy) unit tests.

Specs are pure functions of (pytree, mesh-shape); a duck-typed fake mesh
lets these run without multi-device XLA."""

import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import LM
from repro.parallel.sharding import (batch_specs, cache_specs, gbdt_specs,
                                     opt_specs, param_specs)


class FakeMesh:
    def __init__(self, shape, names):
        self.devices = np.empty(shape, dtype=object)
        self.axis_names = names


MESH = FakeMesh((16, 16), ("data", "model"))
POD_MESH = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def _params(arch="qwen3_1_7b"):
    cfg = get_config(arch, smoke=False)
    return LM(cfg).abstract_init()


def test_param_specs_tp_rules():
    specs = param_specs(_params(), MESH)
    assert specs["embed"] == P("model", None)
    assert specs["lm_head"] == P(None, "model")
    # stacked layer axis gets a leading None
    assert specs["blocks"]["attn"]["wq"] == P(None, None, "model")
    assert specs["blocks"]["attn"]["wo"] == P(None, "model", None)
    assert specs["blocks"]["ffn"]["wi"] == P(None, None, "model")
    # norms replicated
    assert specs["blocks"]["ln1"] == P(None, None)


def test_param_specs_moe_ep():
    specs = param_specs(_params("deepseek_moe_16b"), MESH)
    assert specs["blocks"]["moe"]["wi"] == P(None, "model", None, None)
    assert specs["blocks"]["moe"]["router"] == P(None, None, None)


def test_param_specs_drop_nondivisible():
    # 10 heads * 256 hd = 2560 not divisible by 16 -> model dropped? 2560%16==0
    # use a fabricated leaf with odd dims via recurrentgemma lam (2560 % 16 = 0)
    specs = param_specs(_params("recurrentgemma_2b"), MESH)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)


def test_opt_specs_zero1_adds_data_axis():
    params = {"w": jax.ShapeDtypeStruct((1024, 512), jnp.float32)}
    specs = opt_specs(params, MESH, zero1=True)
    assert specs["w"][0] == "data" or specs["w"][0] == ("data",)


def test_batch_specs_replicate_tiny_batch():
    shapes = {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)}
    specs = batch_specs(shapes, MESH)
    assert specs["tokens"] == P(None, None)     # batch 1 can't shard
    shapes = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    assert batch_specs(shapes, MESH)["tokens"][0] == "data"


def test_cache_specs_split_kv():
    cache = {"k": jax.ShapeDtypeStruct((40, 128, 32768, 8, 128), jnp.bfloat16)}
    specs = cache_specs(cache, MESH)
    assert specs["k"] == P(None, "data", "model", None, None)
    # pod mesh folds pod into the data axes
    specs = cache_specs(cache, POD_MESH)
    assert specs["k"][1] == ("pod", "data")


@pytest.mark.parametrize("kw", [{"seq_shard": True},
                                {"remat_policy": "dots"},
                                {"seq_shard": True, "remat_policy": "dots"}])
def test_variant_configs_still_train(kw):
    cfg = dataclasses.replace(get_config("qwen3_1_7b", smoke=True),
                              remat=True, **kw)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.ones((2, 16), jnp.int32)
    loss, grads = jax.value_and_grad(model.loss)(
        params, {"tokens": toks, "labels": toks})
    assert bool(jnp.isfinite(loss))
    g = jax.tree.reduce(lambda a, x: a + float(jnp.abs(x).sum()), grads, 0.0)
    assert np.isfinite(g) and g > 0


def test_gbdt_rule_table():
    """GBDT frontier specs (DESIGN.md §5): instances over data, at-rest
    features over model, layer-histogram node axis over model."""
    specs = gbdt_specs(MESH)
    assert specs["bins"] == P("data", "model")
    assert specs["gh_cts"] == P("data", None, None)
    assert specs["node_slot"] == P("data")
    assert specs["layer_hist"] == P("model", None, None, None, None)
    assert specs["layer_counts"] == P("model", None, None)
    # multi-pod: "data" expands to ("pod", "data")
    pod = gbdt_specs(POD_MESH)
    assert pod["bins"] == P(("pod", "data"), "model")
    assert pod["layer_hist"][0] == "model"


def test_gbdt_sharding_trims_and_replicates():
    from repro.parallel.sharding import gbdt_sharding

    # gbdt_sharding builds a NamedSharding, which needs a real mesh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    flat2d = gbdt_sharding(mesh, "gh_cts", ndim=2)
    assert flat2d.spec == P("data", None)
    repl = gbdt_sharding(mesh, "bins", replicate=("model",))
    assert repl.spec == P("data", None)


def test_moe_sort_ranking_matches_semantics():
    """Sort-based slots: distinct slot per (expert, occupancy), caps hold."""
    from repro.models.ffn import moe, init_moe
    cfg = dataclasses.replace(get_config("deepseek_moe_16b", smoke=True),
                              dtype=jnp.float32,
                              capacity_factor=8.0)       # no drops
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model), jnp.float32)
    y = moe(p, cfg, x)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
    # gradient flows
    g = jax.grad(lambda xx: moe(p, cfg, xx).sum())(x)
    assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.abs(g).sum()) > 0
