"""Chaos suite (DESIGN.md §11): seeded fault injection against the
multi-host party runtime.

Every test drives REAL protocol traffic through a :class:`FaultPlan` —
dropped connections, mid-tree host kills, delayed/truncated frames,
wedged processes — and asserts the recovery invariants:

* training under faults completes BIT-IDENTICAL to the fault-free
  in-process oracle (tree signatures, scores, per-tag ledgers);
* a slow host is marked, never restarted; a wedged host is restarted;
* serving degrades to a typed :class:`PartyUnavailable` per batch and
  recovers after the party rejoins;
* ``close()`` escalates SIGTERM -> SIGKILL for a SIGTERM-ignoring zombie.

All plans are seeded and rules fire at exact (direction, tag, nth) or
(tree, layer) coordinates, so a failing run replays deterministically.
"""

import os
import socket as _socket
import struct
import tempfile
import time

import numpy as np
import pytest

from repro.core import PartyUnavailable, SBTParams, VerticalBoosting
from repro.runtime.chaos import (RECV, SEND, Delay, DropConn, FaultPlan,
                                 FaultyEndpoint, Kill, Truncate, Wedge)
from repro.runtime.fault import StragglerPolicy
from repro.runtime.transport import (KIND_CTRL, LoopbackEndpoint,
                                     MultiHostRun, SocketEndpoint,
                                     TransportError, encode_frame)


def _data(n=200, d=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d)
    y = (X @ w + 0.3 * rng.normal(0, 1, n) > 0).astype(np.float64)
    return X, y


def _signatures(model):
    return [t.signature() for t in model.trees]


def _dirs():
    base = tempfile.mkdtemp()
    return (os.path.join(base, "export"), os.path.join(base, "state"),
            os.path.join(base, "ckpt"))


# ---------------------------------------------------------------------------
# the acceptance scenario: delays + dropped connection + mid-tree crash
# ---------------------------------------------------------------------------

def test_socket_chaos_parity_bit_identical():
    """Seeded plan: a delayed enc_gh and a dropped connection on host0,
    host1 killed mid-tree (tree 1, layer 0).  The resilient socket run
    must complete bit-identically to the fault-free in-process oracle —
    same tree signatures, same train scores, same converged per-tag
    ledger — with the faults actually having fired."""
    X, y = _data(n=200)
    params = SBTParams(n_trees=3, max_depth=3, n_bins=8, cipher="plain",
                       seed=7)
    Xg = X[:, :2]
    Xh = [X[:, 2:4], X[:, 4:]]
    ref = VerticalBoosting(params).fit(Xg, y, [h.copy() for h in Xh])

    export_dir, state_dir, ckpt_dir = _dirs()
    plans = {
        0: FaultPlan(rules=[
            Delay(tag="enc_gh", nth=1, direction=RECV, seconds=0.05),
            DropConn(tag="assign_sync", nth=5, direction=RECV),
        ], seed=41),
        1: FaultPlan(rules=[
            Kill(tree=1, layer=0, direction=RECV),
        ], seed=42),
    }
    run = MultiHostRun(params, Xh, transport="socket",
                       export_dir=export_dir, state_dir=state_dir,
                       fault_plans=plans, timeout=120.0)
    try:
        model = run.fit(Xg, y, resilient=True, ckpt_dir=ckpt_dir,
                        save_every=1, max_retries=6, retry_backoff=0.05)
        # the faults fired: at least one crash-respawn and one re-dial
        assert run.restarts >= 1
        assert run.redials >= 1
        assert run.failures >= 1
        # bit-identity despite replays: GOSS/shuffle streams are keyed by
        # absolute tree index, so a replayed tree IS the original tree
        np.testing.assert_array_equal(model.train_score_, ref.train_score_)
        assert _signatures(model) == _signatures(ref)
        # converged ledger: replayed duplicates deduped by seq, aborted
        # attempts rolled back on both sides — the per-tag summary equals
        # the fault-free oracle's exactly
        assert run.channel.summary() == ref.channel.summary()
    finally:
        run.close()


def test_loopback_resilient_replay_truncated_frame():
    """Deterministic single-process variant: a truncated split_infos
    frame desyncs the stream mid-tree; the resilient loop resyncs and
    replays the round to the oracle fixed point."""
    X, y = _data(n=150, seed=3)
    params = SBTParams(n_trees=2, max_depth=2, n_bins=8, seed=5)
    Xg, Xh = X[:, :3], [X[:, 3:]]
    ref = VerticalBoosting(params).fit(Xg, y, [Xh[0].copy()])

    _, state_dir, ckpt_dir = _dirs()
    run = MultiHostRun(params, Xh, transport="loopback",
                       state_dir=state_dir)
    try:
        plan = FaultPlan(rules=[
            Truncate(tag="split_infos", nth=2, direction=RECV,
                     keep_fraction=0.5),
        ], seed=9)
        run.channel.peers["host0"] = FaultyEndpoint(
            run.channel.peers["host0"], plan)
        model = run.fit(Xg, y, resilient=True, ckpt_dir=ckpt_dir,
                        max_retries=4, retry_backoff=0.01)
        assert run.failures >= 1
        np.testing.assert_array_equal(model.train_score_, ref.train_score_)
        assert _signatures(model) == _signatures(ref)
        assert run.channel.summary() == ref.channel.summary()
    finally:
        run.close()


def test_fault_plan_replay_is_deterministic():
    """Two FaultyEndpoints under the same seeded plan inject the same
    faults at the same coordinates — chaos runs are replayable."""
    def drive(plan):
        a, b = LoopbackEndpoint.pair()
        fe = FaultyEndpoint(b, plan.fresh())
        for i in range(6):
            a.send_bytes(encode_frame(KIND_CTRL, "guest", "host0",
                                      "ping", 0, {"i": i}, seq=i))
        out = []
        for _ in range(6):
            try:
                out.append(len(fe.recv_bytes()))
            except TransportError as e:
                out.append(str(e))
        return out, list(fe.injected)

    plan = FaultPlan(rules=[
        Truncate(tag="ping", nth=2, direction=RECV, keep_fraction=0.3),
        DropConn(tag="ping", nth=5, direction=RECV),
    ], seed=123)
    r1, inj1 = drive(plan)
    r2, inj2 = drive(plan)
    assert r1 == r2
    assert inj1 == inj2 == [("Truncate", "ping", 2), ("DropConn", "ping", 5)]


# ---------------------------------------------------------------------------
# satellite: mid-frame timeout must poison (and close) the endpoint
# ---------------------------------------------------------------------------

def test_socket_recv_timeout_marks_endpoint_dead():
    """A recv timeout can fire after the length prefix (or part of the
    body) was consumed: the stream is mid-frame and the next recv would
    decode body bytes as a length prefix.  The endpoint must mark itself
    dead and close, so every later call fails fast instead of silently
    desyncing the protocol."""
    lst = _socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)
    cli = _socket.socket()
    cli.connect(lst.getsockname())
    srv, _ = lst.accept()
    ep = SocketEndpoint(srv)
    try:
        # length prefix promises 100 bytes; only 10 ever arrive
        cli.sendall(struct.pack("!I", 100) + b"x" * 10)
        t0 = time.monotonic()
        with pytest.raises(TransportError, match="timed out"):
            ep.recv_bytes(timeout=0.3)
        assert time.monotonic() - t0 < 5.0
        assert ep.dead
        # the poisoned endpoint fails fast on BOTH directions
        with pytest.raises(TransportError, match="dead"):
            ep.recv_bytes(timeout=0.3)
        with pytest.raises(TransportError, match="dead"):
            ep.send_bytes(b"frame")
        # and it really closed the socket: the peer sees EOF, not a hang
        cli.settimeout(2.0)
        assert cli.recv(1) == b""
    finally:
        for s in (cli, srv, lst):
            s.close()


def test_socket_recv_rejects_absurd_length_prefix():
    """A corrupt length prefix must not trigger a giant allocation or a
    wait-for-a-terabyte hang: refuse, die, close."""
    lst = _socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)
    cli = _socket.socket()
    cli.connect(lst.getsockname())
    srv, _ = lst.accept()
    ep = SocketEndpoint(srv)
    try:
        cli.sendall(struct.pack("!I", 0xFFFFFFFF))
        with pytest.raises(TransportError, match="exceeds"):
            ep.recv_bytes(timeout=2.0)
        assert ep.dead
    finally:
        for s in (cli, srv, lst):
            s.close()


# ---------------------------------------------------------------------------
# satellite: close() must escalate join -> SIGTERM -> SIGKILL
# ---------------------------------------------------------------------------

def test_close_escalates_to_sigkill_for_wedged_host():
    """A host that wedges AND ignores SIGTERM (the worst zombie) must
    still be reaped by close(): join times out, terminate() is ignored,
    kill() is not."""
    X, _ = _data(n=60, seed=2)
    params = SBTParams(n_trees=1, max_depth=2, n_bins=8)
    plans = {0: FaultPlan(rules=[
        Wedge(tag="hb", nth=1, direction=RECV, ignore_sigterm=True),
    ], seed=1)}
    run = MultiHostRun(params, [X[:, 3:]], transport="socket",
                       fault_plans=plans, timeout=60.0)
    p = run.procs[0]
    # trip the wedge: the host installs SIG_IGN and sleeps inside recv
    run.channel.control_send("host0", "hb", {"t": 0.0})
    time.sleep(1.0)
    assert p.is_alive()
    run.close(join_timeout=1.0)
    assert not p.is_alive()
    # SIGTERM was ignored, so only SIGKILL can have ended it
    assert p.exitcode == -9


# ---------------------------------------------------------------------------
# liveness: slow is marked, wedged is restarted
# ---------------------------------------------------------------------------

def test_straggler_marked_never_restarted():
    """A host whose split_infos round-trips blow past the trailing
    median is MARKED slow — restarting it would burn real progress for
    zero correctness gain — and training still matches the oracle."""
    X, y = _data(n=150, seed=4)
    params = SBTParams(n_trees=2, max_depth=2, n_bins=8, seed=11)
    Xg, Xh = X[:, :3], [X[:, 3:]]
    ref = VerticalBoosting(params).fit(Xg, y, [Xh[0].copy()])

    export_dir, state_dir, ckpt_dir = _dirs()
    plans = {0: FaultPlan(rules=[
        Delay(tag="split_infos", nth=2, direction=SEND, seconds=0.6),
    ], seed=21)}
    run = MultiHostRun(params, Xh, transport="socket", state_dir=state_dir,
                       fault_plans=plans, timeout=120.0)
    try:
        # pre-seeded baseline so one fat outlier is enough to classify
        pol = StragglerPolicy(factor=3.0, tolerance=1)
        pol.times.extend([0.02] * 10)
        run._straggler["host0"] = pol
        model = run.fit(Xg, y, resilient=True, ckpt_dir=ckpt_dir)
        assert "host0" in run.slow_hosts
        assert run.restarts == 0 and run.wedged_restarts == 0
        np.testing.assert_array_equal(model.train_score_, ref.train_score_)
        assert run.channel.summary() == ref.channel.summary()
    finally:
        run.close()


def test_wedged_host_restarted_by_liveness_supervisor():
    """A host that stops answering heartbeats entirely (wedged, not
    slow) is killed and respawned by the supervisor; the resilient loop
    replays the tree and the run still matches the oracle."""
    X, y = _data(n=120, seed=6)
    params = SBTParams(n_trees=2, max_depth=2, n_bins=8, seed=13)
    Xg, Xh = X[:, :3], [X[:, 3:]]
    ref = VerticalBoosting(params).fit(Xg, y, [Xh[0].copy()])

    export_dir, state_dir, ckpt_dir = _dirs()
    # wedge on the SECOND tree's enc_gh: the host goes silent mid-run
    plans = {0: FaultPlan(rules=[
        Wedge(tag="enc_gh", nth=2, direction=RECV, sleep_seconds=120.0),
    ], seed=31)}
    run = MultiHostRun(params, Xh, transport="socket", state_dir=state_dir,
                       fault_plans=plans, timeout=120.0,
                       liveness_interval=0.25, liveness_timeout=2.0)
    try:
        model = run.fit(Xg, y, resilient=True, ckpt_dir=ckpt_dir,
                        max_retries=5)
        assert run.wedged_restarts >= 1
        assert run.restarts >= 1          # the kill forced a respawn
        np.testing.assert_array_equal(model.train_score_, ref.train_score_)
        assert _signatures(model) == _signatures(ref)
        assert run.channel.summary() == ref.channel.summary()
    finally:
        run.close()


# ---------------------------------------------------------------------------
# serving: typed degradation per batch, recovery after rejoin
# ---------------------------------------------------------------------------

def test_serving_degrades_typed_and_recovers():
    """Killing one host mid-serving yields a typed PartyUnavailable for
    the batch — never a hang, never partial bits — while the healthy
    host's replies are still consumed (no stream poisoning).  The next
    batch heals the party and serves bit-identically."""
    X, y = _data(n=150, d=8, seed=8)
    params = SBTParams(n_trees=2, max_depth=2, n_bins=8, seed=17)
    Xg = X[:, :2]
    Xh = [X[:, 2:5], X[:, 5:]]
    ref = VerticalBoosting(params).fit(Xg, y, [h.copy() for h in Xh])

    export_dir, state_dir, _ = _dirs()
    run = MultiHostRun(params, Xh, transport="socket",
                       export_dir=export_dir, state_dir=state_dir,
                       timeout=60.0, serve_timeout=5.0)
    try:
        run.fit(Xg, y)
        run.serve()
        Xe, _ = _data(n=40, d=8, seed=9)
        eg, eh = Xe[:, :2], [Xe[:, 2:5], Xe[:, 5:]]
        s_ref = ref.predict_score(eg, eh)
        np.testing.assert_array_equal(run.predict_score(eg, eh), s_ref)

        run.procs[1].kill()
        run.procs[1].join(5)
        t0 = time.monotonic()
        with pytest.raises(PartyUnavailable) as ei:
            run.predict_score(eg, eh)
        assert ei.value.party == "host1"
        assert time.monotonic() - t0 < 30.0     # typed failure, not a hang
        # next batch: the degraded party is respawned, re-setup from its
        # export, and the batch serves bit-identically again
        np.testing.assert_array_equal(run.predict_score(eg, eh), s_ref)
        assert run.restarts >= 1
    finally:
        run.close()
