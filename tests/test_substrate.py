"""Substrate tests: checkpointing (atomic/elastic), fault-tolerant loop,
straggler policy, data pipeline, optimizer, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck
from repro.data import PrefetchLoader, SyntheticTokens, synthetic_tabular
from repro.optim import AdamWConfig, adamw_update, init_adamw
from repro.parallel.compression import compress_grads, decompress
from repro.runtime import ResilientLoop, StragglerError, StragglerPolicy


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16) * 3,
                  "d": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    ck.save(d, 5, t)
    assert ck.latest_step(d) == 5
    out = ck.restore(d, 5, jax.eval_shape(lambda: t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), t, out)


def test_checkpoint_gc_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    for s in [1, 2, 3, 4, 5]:
        ck.save(d, s, _tree())
    assert ck.latest_step(d) == 5
    kept = sorted(os.listdir(d))
    assert len(kept) == 3          # gc keeps 3


def test_checkpoint_async(tmp_path):
    d = str(tmp_path / "ck")
    fut = ck.save_async(d, 9, _tree())
    fut.result(timeout=30)
    assert ck.latest_step(d) == 9


def test_resilient_loop_recovers(tmp_path):
    d = str(tmp_path / "ck")
    calls = {"n": 0, "fail_at": 7}
    saved = {}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == calls["fail_at"]:
            raise RuntimeError("simulated device failure")
        return state + 1

    def save_fn(step, state):
        saved["last"] = (step, state)
        ck.save(d, step, {"s": jnp.asarray(state)})

    def restore_fn():
        last = ck.latest_step(d)
        if last is None:
            return 0, 0
        return last, int(np.asarray(
            ck.restore(d, last, {"s": jax.ShapeDtypeStruct((), jnp.int32)})["s"]))

    loop = ResilientLoop(step_fn, save_fn, restore_fn, lambda s: None,
                         save_every=2, backoff=0.01)
    step, state = loop.run(0, 0, 10)
    assert step == 10 and loop.failures == 1
    assert state == 10               # replayed steps after restore


def test_straggler_policy_trips():
    p = StragglerPolicy(factor=2.0, tolerance=3)
    for _ in range(20):
        p.observe(1.0)
    with pytest.raises(StragglerError):
        for _ in range(5):
            p.observe(10.0)


def test_data_determinism_and_prefetch():
    ds = SyntheticTokens(vocab=100, batch=2, seq=8, seed=3)
    b1, b2 = ds(5), ds(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    loader = PrefetchLoader(ds, depth=2)
    out = loader(0)
    assert out["tokens"].shape == (2, 8)
    loader.stop()


@pytest.mark.parametrize("quantized", [False, True])
def test_adamw_reduces_loss(quantized):
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, quantize_moments=quantized)
    w = {"w": jnp.asarray([2.0, -3.0])}
    state = init_adamw(w, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2)
    l0 = float(loss(w))
    for _ in range(60):
        g = jax.grad(loss)(w)
        w, state = adamw_update(w, g, state, cfg)
    assert float(loss(w)) < 0.05 * l0


def test_grad_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)}
    payload, err = compress_grads(g)
    rec = decompress(payload)
    # int8 quantization error is bounded by scale/2 per element
    scale = float(payload["a"][1])
    assert float(jnp.abs(rec["a"] - g["a"]).max()) <= scale
    # error feedback: accumulated error is carried into the next round
    payload2, err2 = compress_grads(g, err)
    rec2 = decompress(payload2)
    two_step = (np.asarray(rec["a"]) + np.asarray(rec2["a"])) / 2
    direct = np.asarray(g["a"])
    assert np.abs(two_step - direct).mean() < np.abs(
        np.asarray(rec["a"]) - direct).mean() + 1e-6


def test_synthetic_tabular_shapes():
    X, y = synthetic_tabular(100, 7, task="multi", n_classes=4, sparsity=0.5)
    assert X.shape == (100, 7) and set(np.unique(y)) <= {0, 1, 2, 3}
    assert (X == 0).mean() > 0.3
