"""Pallas kernel sweeps (interpret mode) against pure-jnp oracles."""

import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.he import get_cipher, limbs
from repro.kernels.binning import bucketize, bucketize_ref, fit_quantile_thresholds
from repro.kernels.histogram import ciphertext_histogram, hist_ref
from repro.kernels.modmul import decrypt_batch, encrypt_batch, modmul_fixed
from repro.kernels.modmul.ref import mul_fixed_ref
from repro.kernels.modmul.modmul import mul_fixed_pallas

HIST_SHAPES = [(64, 3, 8, 8), (300, 17, 33, 32), (257, 9, 130, 16),
               (1024, 8, 20, 32), (1, 1, 4, 4)]


@pytest.mark.parametrize("n_i,n_f,L,n_b", HIST_SHAPES)
def test_histogram_kernel_vs_ref(n_i, n_f, L, n_b):
    rng = np.random.default_rng(n_i * 31 + n_f)
    bins = rng.integers(0, n_b, (n_i, n_f)).astype(np.int32)
    bins[rng.random((n_i, n_f)) < 0.15] = -1
    cts = rng.integers(0, 256, (n_i, L)).astype(np.int32)
    out = ciphertext_histogram(bins, cts, n_b, use_pallas=True)
    ref = hist_ref(jnp.asarray(bins), jnp.asarray(cts), n_b)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_histogram_kernel_masked_all():
    bins = np.full((50, 4), -1, np.int32)
    cts = np.random.default_rng(0).integers(0, 256, (50, 8)).astype(np.int32)
    out = np.asarray(ciphertext_histogram(bins, cts, 8))
    assert (out == 0).all()


@pytest.mark.parametrize("n_i,n_f,n_b", [(100, 4, 8), (1000, 33, 32),
                                         (513, 7, 16), (2, 1, 4)])
@pytest.mark.parametrize("dist", ["normal", "uniform", "sparse"])
def test_binning_kernel_vs_ref(n_i, n_f, n_b, dist):
    rng = np.random.default_rng(n_i + n_b)
    if dist == "normal":
        v = rng.normal(0, 1, (n_i, n_f)).astype(np.float32)
    elif dist == "uniform":
        v = rng.uniform(-5, 5, (n_i, n_f)).astype(np.float32)
    else:
        v = rng.normal(0, 1, (n_i, n_f)).astype(np.float32)
        v[rng.random((n_i, n_f)) < 0.7] = 0.0
    thr = fit_quantile_thresholds(v, n_b)
    out = np.asarray(bucketize(v, thr, use_pallas=True))
    ref = np.asarray(bucketize_ref(jnp.asarray(v), jnp.asarray(thr)))
    np.testing.assert_array_equal(out, ref)
    assert out.min() >= 0 and out.max() <= n_b - 1


@pytest.mark.parametrize("bits", [64, 128, 256])
@pytest.mark.parametrize("batch", [1, 7, 100])
def test_modmul_kernel(bits, batch):
    rnd = random.Random(bits + batch)
    n_int = rnd.getrandbits(bits) | (1 << (bits - 1)) | 1
    bctx = limbs.barrett_precompute(n_int)
    Ln = bctx.Ln
    b_int = rnd.getrandbits(bits - 1)
    T = jnp.asarray(limbs.toeplitz(limbs.from_pyints([b_int], Ln)[0], Ln))
    vals = [rnd.getrandbits(bits - 1) % n_int for _ in range(batch)]
    x = jnp.asarray(limbs.from_pyints(vals, Ln))
    y = modmul_fixed(x, T, bctx)
    assert limbs.to_pyints(np.asarray(y)) == [(v * b_int) % n_int for v in vals]
    # raw mul kernel vs oracle
    y2 = mul_fixed_pallas(x, T)
    ref = mul_fixed_ref(x, T)
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(ref))


def test_kernelized_encrypt_decrypt_matches_jnp_path():
    aff = get_cipher("affine", key_bits=192, seed=9)
    rnd = random.Random(3)
    pts = [rnd.getrandbits(150) for _ in range(40)]
    pt = jnp.asarray(limbs.from_pyints(pts, aff.Ln))
    ct_kernel = encrypt_batch(aff, pt)
    ct_jnp = aff.encrypt_limbs(pt)
    np.testing.assert_array_equal(np.asarray(ct_kernel), np.asarray(ct_jnp))
    assert limbs.to_pyints(np.asarray(decrypt_batch(aff, ct_kernel))) == pts
