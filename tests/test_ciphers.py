"""Cipher suite contract tests: roundtrip, homomorphism, sub, mul_pow2."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.he import get_cipher


def _suite(name):
    if name == "plain":
        return get_cipher("plain", bits=256)
    if name == "affine":
        return get_cipher("affine", key_bits=256, seed=11)
    return get_cipher("paillier", key_bits=256, seed=11)


@pytest.mark.parametrize("name", ["plain", "affine", "paillier"])
def test_roundtrip_and_homomorphism(name):
    c = _suite(name)
    xs = [0, 1, 12345, 2 ** 100 + 7]
    ys = [5, 9, 2 ** 90, 3]
    mod = 2 ** c.plaintext_bits if name == "plain" else (
        c.n_int if name == "affine" else c.n)
    ca, cb = c.encrypt_ints(xs), c.encrypt_ints(ys)
    assert c.decrypt_to_ints(ca) == xs
    if c.backend == "limb":
        ca, cb = jnp.asarray(ca), jnp.asarray(cb)
    s = c.add(ca, cb)
    assert c.decrypt_to_ints(s) == [(x + y) % mod for x, y in zip(xs, ys)]
    d = c.sub(s, cb)
    assert c.decrypt_to_ints(d) == xs
    m = c.mul_pow2(ca, 13)
    assert c.decrypt_to_ints(m) == [(x << 13) % mod for x in xs]


def test_affine_lazy_reduce():
    c = _suite("affine")
    xs = [3, 5, 2 ** 128, 2 ** 200 + 1, 17]
    ct = jnp.asarray(c.encrypt_ints(xs))
    acc = jnp.pad(ct, ((0, 0), (0, c.hist_width - c.Ln))).sum(axis=0)
    out = c.decrypt_to_ints(c.reduce(acc[None]))
    assert out == [sum(xs) % c.n_int]


def test_paillier_is_randomized():
    c = _suite("paillier")
    a = c.encrypt_ints([42])[0]
    b = c.encrypt_ints([42])[0]
    assert a != b                      # semantic security: fresh randomness
    assert c.decrypt_to_ints(np.asarray([a, b], dtype=object)) == [42, 42]


def test_paillier_encrypt_from_generator():
    """Regression: len(list(xs)) consumed generator arguments, leaving an
    object array of None 'ciphertexts'."""
    c = _suite("paillier")
    xs = [5, 7, 2 ** 80 + 3]
    ct = c.encrypt_ints(x for x in xs)
    assert all(v is not None for v in ct)
    assert c.decrypt_to_ints(ct) == xs


def test_affine_encrypt_rejects_out_of_range():
    """Values >= n must raise like the Paillier backend does, not wrap
    silently and decrypt to garbage."""
    import jax.numpy as jnp

    from repro.core.he import limbs
    c = _suite("affine")
    bad = jnp.asarray(limbs.from_pyints([c.n_int], c.Ln))
    with pytest.raises(ValueError, match="out of range"):
        c.encrypt_limbs(bad)
    with pytest.raises(ValueError, match="out of range"):
        c.encrypt_ints([c.n_int + 5])
    # the kernelized path (the use_pallas production default) guards too
    from repro.kernels.modmul import encrypt_batch
    with pytest.raises(ValueError, match="out of range"):
        encrypt_batch(c, bad)
    # boundary: n - 1 still round-trips
    ok = c.encrypt_ints([c.n_int - 1])
    assert c.decrypt_to_ints(jnp.asarray(ok)) == [c.n_int - 1]
