"""Shared test config.

The suite jit-compiles hundreds of distinct programs (10 archs x variants x
cipher widths); on a small host the accumulated XLA executables can exhaust
memory late in the run.  Clearing JAX caches between modules bounds the
footprint without touching test semantics.
"""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
