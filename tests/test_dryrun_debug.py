"""Dry-run plumbing validation on an 8-device debug mesh (subprocess, so
the 512-device XLA flag never leaks into other tests)."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, out):
    env = dict(os.environ, DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--debug-mesh",
           "--out", out, "--force"] + args
    res = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT,
                         timeout=1200, env=env)
    assert res.returncode == 0, res.stderr[-3000:]
    with open(out) as f:
        return json.load(f)


@pytest.mark.slow
def test_debug_mesh_train_and_decode(tmp_path):
    out = str(tmp_path / "dry.json")
    results = _run(["--arch", "qwen3_1_7b", "--shape", "train_4k",
                    "--mesh", "both"], out)
    for mesh in ["single", "multi"]:
        r = results[f"qwen3_1_7b|train_4k|{mesh}"]
        assert r["status"] == "ok"
        assert r["flops_per_chip"] > 0
        assert r["collective_bytes_per_chip"] > 0
        assert r["bottleneck"].endswith("_s")


@pytest.mark.slow
def test_debug_mesh_ssm_long_context(tmp_path):
    out = str(tmp_path / "dry2.json")
    results = _run(["--arch", "mamba2_130m", "--shape", "long_500k",
                    "--mesh", "single"], out)
    r = results["mamba2_130m|long_500k|single"]
    assert r["status"] == "ok"          # constant-state decode at 524k


@pytest.mark.slow
def test_debug_mesh_skips_quadratic_long_context(tmp_path):
    out = str(tmp_path / "dry3.json")
    results = _run(["--arch", "command_r_35b", "--shape", "long_500k",
                    "--mesh", "single"], out)
    r = results["command_r_35b|long_500k|single"]
    assert r["status"] == "skip"
