"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; plus decode-vs-train consistency for the
recurrent families (fp32 exactness of the serve path)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import LM

rng = np.random.default_rng(0)


def _batch(cfg, B=2, S=16):
    if cfg.ssm_chunk:
        S = max(S, cfg.ssm_chunk)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.enc_dec:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32)
    return batch, tokens


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batch, tokens = _batch(cfg)
    logits = model.forward(params, batch)
    assert logits.shape == tokens.shape + (cfg.vocab,)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = jax.tree.reduce(
        lambda a, x: a + jnp.sum(jnp.abs(x.astype(jnp.float32))), grads, 0.0)
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batch, tokens = _batch(cfg)
    enc_out = model.encode(params, batch["enc_embeds"]) if cfg.enc_dec else None
    cache = model.init_cache(2, 32)
    logits, cache2 = model.decode_step(params, tokens[:, :1],
                                       jnp.zeros(2, jnp.int32), cache,
                                       enc_out=enc_out)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache structure is preserved
    jax.tree.map(lambda a, b: None if a.shape == b.shape else 1 / 0,
                 cache, cache2)


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "mamba2_130m",
                                  "recurrentgemma_2b", "whisper_large_v3",
                                  "deepseek_moe_16b"])
def test_decode_matches_train_fp32(arch):
    """Sequential decode must reproduce the training forward exactly.

    MoE: capacity_factor is raised so no token drops -- train-time GShard
    dropping is batch-dependent and legitimately differs from decode."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype=jnp.float32)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = LM(cfg)
    params = model.init(jax.random.key(1))
    S = cfg.ssm_chunk or 12
    batch, tokens = _batch(cfg, B=1, S=S)
    lt = model.forward(params, batch)
    enc_out = model.encode(params, batch["enc_embeds"]) if cfg.enc_dec else None
    cache = model.init_cache(1, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, tokens[:, t:t + 1],
                                      jnp.full((1,), t, jnp.int32), cache,
                                      enc_out=enc_out)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert float(jnp.abs(dec - lt).max()) < 1e-4


def test_hybrid_ring_buffer_window():
    """Windowed decode beyond the window must keep attending (ring buffer)."""
    cfg = dataclasses.replace(get_config("recurrentgemma_2b", smoke=True),
                              dtype=jnp.float32, window=4)
    model = LM(cfg)
    params = model.init(jax.random.key(3))
    cache = model.init_cache(1, 4)      # ring = window
    tok = jnp.asarray([[5]], jnp.int32)
    for t in range(10):                 # run far past the window
        lg, cache = model.decode_step(params, tok,
                                      jnp.full((1,), t, jnp.int32), cache)
        assert bool(jnp.all(jnp.isfinite(lg)))


def test_param_counts_match_published_scale():
    expect = {"deepseek_moe_16b": (14e9, 20e9),
              "qwen3_1_7b": (1.4e9, 2.4e9),
              "stablelm_12b": (10e9, 14e9),
              "command_r_35b": (30e9, 40e9),
              "qwen2_vl_72b": (65e9, 80e9),
              "mamba2_130m": (0.10e9, 0.22e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo < n < hi, f"{arch}: {n / 1e9:.2f}B outside [{lo},{hi}]"
