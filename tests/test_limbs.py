"""Property tests for the radix-2**8 biguint limb substrate (vs python ints)."""

import random

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.he import limbs

BIG = st.integers(min_value=0, max_value=(1 << 200) - 1)


@settings(max_examples=60, deadline=None)
@given(st.lists(BIG, min_size=1, max_size=8))
def test_roundtrip(xs):
    assert limbs.to_pyints(limbs.from_pyints(xs, 32)) == xs


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(BIG, BIG), min_size=1, max_size=6))
def test_add_sub_compare(pairs):
    xs = [a for a, _ in pairs]
    ys = [b for _, b in pairs]
    a = jnp.asarray(limbs.from_pyints(xs, 32))
    b = jnp.asarray(limbs.from_pyints(ys, 32))
    assert limbs.to_pyints(limbs.add(a, b)) == [x + y for x, y in zip(xs, ys)]
    hi = [max(x, y) for x, y in zip(xs, ys)]
    lo = [min(x, y) for x, y in zip(xs, ys)]
    d = limbs.sub(jnp.asarray(limbs.from_pyints(hi, 32)),
                  jnp.asarray(limbs.from_pyints(lo, 32)))
    assert limbs.to_pyints(d) == [x - y for x, y in zip(hi, lo)]
    cmp = np.asarray(limbs.compare(a, b))
    assert list(cmp) == [(x > y) - (x < y) for x, y in zip(xs, ys)]


@settings(max_examples=30, deadline=None)
@given(st.lists(BIG, min_size=1, max_size=5),
       st.integers(min_value=0, max_value=64))
def test_shifts_and_mask(xs, k):
    a = jnp.asarray(limbs.from_pyints(xs, 32))
    sl = limbs.shift_left_bits(a, k, 41)
    assert limbs.to_pyints(sl) == [(x << k) % (1 << 328) for x in xs]
    sr = limbs.shift_right_bits(a, k)
    assert limbs.to_pyints(sr) == [x >> k for x in xs]
    mk = limbs.mask_bits(a, k)
    assert limbs.to_pyints(mk) == [x & ((1 << k) - 1) for x in xs]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(BIG, BIG), min_size=1, max_size=4))
def test_mul(pairs):
    xs = [a for a, _ in pairs]
    ys = [b for _, b in pairs]
    m = limbs.mul(jnp.asarray(limbs.from_pyints(xs, 26)),
                  jnp.asarray(limbs.from_pyints(ys, 26)))
    assert limbs.to_pyints(m) == [x * y for x, y in zip(xs, ys)]


@pytest.mark.parametrize("bits", [64, 128, 256])
def test_barrett_reduce(bits):
    rnd = random.Random(bits)
    n_int = rnd.getrandbits(bits) | (1 << (bits - 1)) | 1
    ctx = limbs.barrett_precompute(n_int)
    Ln = ctx.Ln
    vals = [rnd.getrandbits(2 * bits - 1) for _ in range(40)]
    vals += [0, 1, n_int - 1, n_int, n_int + 1, 2 * n_int, n_int * n_int - 1]
    v = jnp.asarray(limbs.from_pyints(vals, 2 * Ln))
    r = limbs.barrett_reduce(v, ctx)
    assert limbs.to_pyints(r) == [x % n_int for x in vals]


def test_mod_mul_fixed():
    rnd = random.Random(7)
    n_int = rnd.getrandbits(256) | (1 << 255) | 1
    ctx = limbs.barrett_precompute(n_int)
    b_int = rnd.getrandbits(255)
    T = jnp.asarray(limbs.toeplitz(limbs.from_pyints([b_int], ctx.Ln)[0], ctx.Ln))
    vals = [rnd.getrandbits(255) % n_int for _ in range(25)]
    out = limbs.mod_mul_fixed(jnp.asarray(limbs.from_pyints(vals, ctx.Ln)), T, ctx)
    assert limbs.to_pyints(out) == [(v * b_int) % n_int for v in vals]
