"""End-to-end SecureBoost+ behaviour: losslessness, optimizations, modes."""

import numpy as np
import pytest

from repro.core import LocalGBDT, SBTParams, VerticalBoosting


def _data(n=400, d=6, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d)
    y = (X @ w + 0.3 * rng.normal(0, 1, n) > 0).astype(np.float64)
    return X, y


def _auc(p, y):
    pos, neg = p[y == 1], p[y == 0]
    return float((pos[:, None] > neg[None, :]).mean()
                 + 0.5 * (pos[:, None] == neg[None, :]).mean())


def test_federated_plain_bit_identical_to_local():
    """Paper Table 3 'lossless' claim, strengthened to bit-exactness."""
    X, y = _data()
    loc = LocalGBDT(SBTParams(n_trees=4, max_depth=3, n_bins=16)).fit(X, y)
    fed = VerticalBoosting(SBTParams(n_trees=4, max_depth=3, n_bins=16,
                                     cipher="plain")).fit(X[:, :3], y, [X[:, 3:]])
    np.testing.assert_array_equal(fed.predict_proba(X[:, :3], [X[:, 3:]]),
                                  loc.predict_proba(X))


def test_affine_cipher_matches_local():
    X, y = _data(n=300)
    loc = LocalGBDT(SBTParams(n_trees=3, max_depth=3, n_bins=16)).fit(X, y)
    fed = VerticalBoosting(SBTParams(n_trees=3, max_depth=3, n_bins=16,
                                     cipher="affine", key_bits=256,
                                     precision=20)).fit(X[:, :3], y, [X[:, 3:]])
    p1 = fed.predict_proba(X[:, :3], [X[:, 3:]])
    p2 = loc.predict_proba(X)
    assert np.abs(p1 - p2).max() < 1e-6


def test_paillier_oracle_one_tree():
    X, y = _data(n=120)
    fed = VerticalBoosting(SBTParams(n_trees=1, max_depth=2, n_bins=8,
                                     cipher="paillier", key_bits=256,
                                     precision=16)).fit(X[:, :3], y, [X[:, 3:]])
    assert _auc(fed.predict_proba(X[:, :3], [X[:, 3:]]), y) > 0.6
    # the synchronous python-int oracle has no in-flight work to overlap
    assert fed.stats.layer_overlap == []


def test_multihost():
    X, y = _data()
    fed = VerticalBoosting(SBTParams(n_trees=3, max_depth=3, n_bins=16)).fit(
        X[:, :2], y, [X[:, 2:4], X[:, 4:]])
    loc = LocalGBDT(SBTParams(n_trees=3, max_depth=3, n_bins=16)).fit(X, y)
    np.testing.assert_array_equal(
        fed.predict_proba(X[:, :2], [X[:, 2:4], X[:, 4:]]),
        loc.predict_proba(X))


def test_optimizations_cut_cipher_costs():
    """Packing halves encryptions; compression divides decryptions (eq 14-16)."""
    X, y = _data()
    base = SBTParams(n_trees=2, max_depth=3, n_bins=16, cipher="plain")
    leg = VerticalBoosting(
        SBTParams(**{**base.__dict__, "packing": False,
                     "histogram_subtraction": False, "compression": False})
    ).fit(X[:, :3], y, [X[:, 3:]])
    opt = VerticalBoosting(base).fit(X[:, :3], y, [X[:, 3:]])
    assert opt.stats.n_encrypt * 2 == leg.stats.n_encrypt
    assert opt.stats.n_decrypt * 4 < leg.stats.n_decrypt
    assert opt.stats.n_hom_add < leg.stats.n_hom_add
    # and identical predictions (optimizations are lossless)
    np.testing.assert_array_equal(
        leg.predict_proba(X[:, :3], [X[:, 3:]]),
        opt.predict_proba(X[:, :3], [X[:, 3:]]))


def test_goss_federated_bit_identical_to_local():
    """Regression: host histograms must use the GOSS-selected rows (host
    bins were once indexed by selection position instead of row id).

    min_leaf/min_gain exclude degenerate tiny nodes where two features give
    EXACTLY equal gain: ties tie-break differently between local (global
    fid order) and federated (host sids are shuffled for privacy), which is
    inherent to the protocol, not a bug."""
    X, y = _data(n=500)
    base = SBTParams(n_trees=4, max_depth=3, n_bins=16, goss=True, seed=1,
                     min_leaf=10, min_gain=1e-3)
    loc = LocalGBDT(base).fit(X, y)
    fed = VerticalBoosting(base).fit(X[:, :3], y, [X[:, 3:]])
    np.testing.assert_array_equal(fed.predict_proba(X[:, :3], [X[:, 3:]]),
                                  loc.predict_proba(X))


def test_goss_zero_other_rate_is_top_only():
    """Regression: ``other_rate=0`` used to force one rest sample with a
    (1 - top_rate)/1e-12 ~ 1e12x amplification weight, silently corrupting
    every g/h sum.  Top-only selection must return exactly the top set
    with unit weights."""
    from repro.core.goss import goss_sample
    rng = np.random.default_rng(0)
    g = rng.normal(0, 1, 50)
    idx, w = goss_sample(g, top_rate=0.2, other_rate=0.0,
                         rng=np.random.default_rng(1))
    assert len(idx) == 10                       # top 20% of 50, nothing else
    top = np.argsort(-np.abs(g), kind="stable")[:10]
    np.testing.assert_array_equal(np.sort(idx), np.sort(top))
    np.testing.assert_array_equal(w, np.ones(10))
    # weighted selection sums equal the plain top-set sums (no blow-up)
    assert np.isclose((g[idx] * w).sum(), g[top].sum())
    # and training with other_rate=0 stays sane end to end
    X, y = _data(n=200)
    m = VerticalBoosting(SBTParams(n_trees=2, max_depth=3, goss=True,
                                   top_rate=0.5, other_rate=0.0)).fit(
        X[:, :3], y, [X[:, 3:]])
    assert np.isfinite(m.train_score_).all()
    assert _auc(m.predict_proba(X[:, :3], [X[:, 3:]]), y) > 0.6


def test_goss_close_to_full():
    X, y = _data(n=800)
    full = VerticalBoosting(SBTParams(n_trees=8, max_depth=3)).fit(
        X[:, :3], y, [X[:, 3:]])
    goss = VerticalBoosting(SBTParams(n_trees=8, max_depth=3, goss=True,
                                      top_rate=0.3, other_rate=0.2)).fit(
        X[:, :3], y, [X[:, 3:]])
    a_full = _auc(full.predict_proba(X[:, :3], [X[:, 3:]]), y)
    a_goss = _auc(goss.predict_proba(X[:, :3], [X[:, 3:]]), y)
    assert a_goss > a_full - 0.05


def test_sparse_parity():
    X, y = _data()
    rng = np.random.default_rng(1)
    Xs = X.copy(); Xs[rng.random(X.shape) < 0.6] = 0.0
    cfg = dict(n_trees=3, max_depth=3, n_bins=16)
    sp = VerticalBoosting(SBTParams(**cfg, sparse=True)).fit(
        Xs[:, :3], y, [Xs[:, 3:]])
    ns = VerticalBoosting(SBTParams(**cfg, sparse=False)).fit(
        Xs[:, :3], y, [Xs[:, 3:]])
    np.testing.assert_array_equal(
        sp.predict_proba(Xs[:, :3], [Xs[:, 3:]]),
        ns.predict_proba(Xs[:, :3], [Xs[:, 3:]]))


@pytest.mark.parametrize("mode,kw", [("mix", {}),
                                     ("layered", {"host_depth": 2})])
def test_modes_train_and_skip_federation(mode, kw):
    X, y = _data()
    m = VerticalBoosting(SBTParams(n_trees=6, max_depth=3, tree_mode=mode,
                                   **kw)).fit(X[:, :3], y, [X[:, 3:]])
    assert _auc(m.predict_proba(X[:, :3], [X[:, 3:]]), y) > 0.8
    if mode == "mix":
        # guest-local trees skip encryption entirely: fewer encrypts than
        # one-per-instance-per-tree
        assert m.stats.n_encrypt < 6 * len(y)


def test_multiclass_and_mo():
    rng = np.random.default_rng(0)
    X, _ = _data(n=500)
    w = rng.normal(0, 1, X.shape[1])
    s = X @ w
    y = ((s > np.quantile(s, 0.33)).astype(float)
         + (s > np.quantile(s, 0.66)).astype(float))
    mc = VerticalBoosting(SBTParams(n_trees=3, max_depth=3,
                                    objective="multiclass", n_classes=3)).fit(
        X[:, :3], y, [X[:, 3:]])
    mo = VerticalBoosting(SBTParams(n_trees=3, max_depth=3, objective="mo",
                                    n_classes=3)).fit(X[:, :3], y, [X[:, 3:]])
    acc_mc = (mc.predict_proba(X[:, :3], [X[:, 3:]]).argmax(1) == y).mean()
    acc_mo = (mo.predict_proba(X[:, :3], [X[:, 3:]]).argmax(1) == y).mean()
    assert acc_mc > 0.6 and acc_mo > 0.6
    assert len(mo.trees) == 3 and len(mc.trees) == 9   # MO: 1 tree per round


def test_multiclass_gradients_computed_once_per_round():
    """Regression: g/h were recomputed after each class's score update
    inside a round, so class c+1 trees trained on scores already moved by
    class c — the paper's default multiclass setting computes g/h ONCE per
    round from round-start scores."""
    from repro.core.loss import SoftmaxLoss
    rng = np.random.default_rng(0)
    X, _ = _data(n=300)
    s = X @ rng.normal(0, 1, X.shape[1])
    y = ((s > np.quantile(s, 0.33)).astype(float)
         + (s > np.quantile(s, 0.66)).astype(float))
    seen_scores = []
    orig = SoftmaxLoss.grad_hess

    def spy(self, yy, score):
        seen_scores.append(np.array(score, copy=True))
        return orig(self, yy, score)

    SoftmaxLoss.grad_hess = spy
    try:
        VerticalBoosting(SBTParams(n_trees=2, max_depth=2, n_bins=8,
                                   objective="multiclass", n_classes=3)).fit(
            X[:, :3], y, [X[:, 3:]])
    finally:
        SoftmaxLoss.grad_hess = orig
    # once per ROUND, not once per (round, class)
    assert len(seen_scores) == 2
    # round-start pin: the first call sees the untouched init scores
    assert np.ptp(seen_scores[0], axis=0).max() == 0


def test_refit_replaces_model():
    """Regression: a second fit() used to APPEND n_trees more trees whose
    splits were then decoded against the new fit's binning thresholds —
    a silently doubled, silently wrong ensemble."""
    X1, y1 = _data(n=200, seed=3)
    X2, y2 = _data(n=250, seed=4)
    m = VerticalBoosting(SBTParams(n_trees=2, max_depth=2, n_bins=8))
    m.fit(X1[:, :3], y1, [X1[:, 3:]])
    m.fit(X2[:, :3], y2, [X2[:, 3:]])
    fresh = VerticalBoosting(SBTParams(n_trees=2, max_depth=2, n_bins=8))
    fresh.fit(X2[:, :3], y2, [X2[:, 3:]])
    assert len(m.trees) == 2
    np.testing.assert_array_equal(m.predict_proba(X2[:, :3], [X2[:, 3:]]),
                                  fresh.predict_proba(X2[:, :3], [X2[:, 3:]]))
    assert m.channel.summary() == fresh.channel.summary()


def test_channel_accounting_nonzero_and_structured():
    X, y = _data(n=200)
    fed = VerticalBoosting(SBTParams(n_trees=2, max_depth=2)).fit(
        X[:, :3], y, [X[:, 3:]])
    s = fed.channel.summary()
    assert {"enc_gh", "split_infos"} <= set(s)
    assert s["enc_gh"]["bytes"] > 0 and s["split_infos"]["bytes"] > 0
