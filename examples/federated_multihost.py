"""One party per OS process: train and serve over a real socket transport.

The guest runs here; each host party is spawned as its own process holding
ONLY its own feature columns.  Every cross-party byte crosses a
length-prefixed localhost TCP frame: the per-layer ``assign_sync`` ->
``split_infos`` -> batched-decrypt rounds during training, and the
one-``predict_bits``-round-trip-per-host serving protocol afterwards —
served from per-party exports each process reloads from disk.

The run is checked bit-identical to the in-process Channel simulation,
with the identical per-tag wire-byte ledger; the report contrasts the
analytic ledger with the bytes the socket actually moved.

    PYTHONPATH=src python examples/federated_multihost.py [--loopback]
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.core import SBTParams, VerticalBoosting
from repro.runtime.transport import MultiHostRun


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--loopback", action="store_true",
                    help="in-memory transport (same framing, no processes)")
    ap.add_argument("--rows", type=int, default=2000)
    args = ap.parse_args()
    transport = "loopback" if args.loopback else "socket"

    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (args.rows, 10)).astype(np.float32)
    y = (X @ np.ones(10) + 0.3 * rng.normal(0, 1, args.rows) > 0).astype(
        np.float64)
    Xg, Xh = X[:, :4], X[:, 4:]
    params = SBTParams(n_trees=4, max_depth=3, n_bins=16, cipher="affine",
                       key_bits=256, precision=20, seed=1)

    print("in-process oracle...")
    ref = VerticalBoosting(params).fit(Xg, y, [Xh])

    print(f"multi-host run ({transport}): guest + 1 host process...")
    with MultiHostRun(params, [Xh], transport=transport,
                      export_dir=tempfile.mkdtemp()) as run:
        model = run.fit(Xg, y)
        print("  train bit-identical:",
              bool(np.array_equal(model.train_score_, ref.train_score_)))
        print("  per-tag ledger identical:",
              run.channel.summary() == ref.channel.summary())
        print(f"  control round-trip: {run.ping() * 1e3:.2f} ms")

        run.serve()                      # per-party exports, reloaded
        score = run.predict_score(Xg, staged=True)   # training rows
        print("  serve bit-identical:",
              bool(np.array_equal(score, ref.predict_score(Xg, [Xh]))))

        ledger = run.channel.total_bytes
        sock = run.channel.total_tx_bytes + run.channel.total_rx_bytes
        print(f"  ledger (protocol-fidelity): {ledger} B; "
              f"socket (framed): {sock} B ({sock / ledger:.2f}x)")
        host = run.host_stats()[0]
        print(f"  host-side HE work (its own process): "
              f"hom_add={host['stats']['n_hom_add']}, "
              f"hist_launches={host['stats']['n_hist_launches']}")


if __name__ == "__main__":
    main()
