"""Batched serving demo: prefill a batch of prompts token-by-token into the
KV cache, then greedy-decode continuations -- the serve_step the decode
dry-run cells lower, at smoke scale.

    PYTHONPATH=src python examples/serve_demo.py [--arch qwen3_1_7b]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    B = args.batch
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt_len)),
                          jnp.int32)

    step = jax.jit(model.decode_step)
    cache = model.init_cache(B, args.prompt_len + args.gen)

    # prefill by stepping the prompt (cache warmup)
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, cache = step(params, prompts[:, t: t + 1],
                             jnp.full((B,), t, jnp.int32), cache)
    t_prefill = time.time() - t0

    # greedy decode
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((B,), args.prompt_len + i, jnp.int32)
        logits, cache = step(params, tok, pos, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B}")
    print(f"prefill {args.prompt_len} tok: {t_prefill:.2f}s; "
          f"decode {args.gen} tok: {t_dec:.2f}s "
          f"({B * args.gen / max(t_dec, 1e-9):.1f} tok/s batched)")
    print("generated token ids (first sequence):",
          np.asarray(gen[0])[:16].tolist())


if __name__ == "__main__":
    main()
