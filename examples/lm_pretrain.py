"""End-to-end driver: pretrain a ~100M-param dense LM for a few hundred
steps on synthetic data, with checkpointing + fault-tolerant loop.

    PYTHONPATH=src python examples/lm_pretrain.py [--steps 300] [--tiny]
"""

import argparse
import sys

sys.path.insert(0, "src")

import dataclasses

import jax

from repro.configs import get_config
from repro.launch.train import train
from repro.models.common import ModelConfig

# ~100M params: 2*V*D + L*(4*D*hd*H/...): see ModelConfig.n_params
CFG_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=10, d_model=640, n_heads=10,
    n_kv_heads=5, d_ff=2560, vocab=32000, qk_norm=True, remat=False,
    dtype=jax.numpy.float32,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true",
                    help="16M-param config for quick validation")
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = CFG_100M if not args.tiny else dataclasses.replace(
        CFG_100M, n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=1024, vocab=8000, name="lm-16m")
    print(f"{cfg.name}: {cfg.n_params() / 1e6:.0f}M params")

    # route through the shared trainer by registering the config inline
    import repro.configs as configs
    mod_name = "examples_lm"
    import types
    mod = types.ModuleType(mod_name)
    mod.full_config = lambda: cfg
    mod.smoke_config = lambda: cfg
    sys.modules[f"repro.configs.{mod_name}"] = mod

    losses = train(mod_name, smoke=True, steps=args.steps, batch=8, seq=256,
                   ckpt_dir=args.ckpt, lr=6e-4, save_every=100)
    first, last = losses[0], sum(losses[-10:]) / min(10, len(losses))
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.1 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
