"""Give-credit-style run (paper §7.1): larger binary task, three parties
(guest + 2 hosts), GOSS + sparse optimization + cipher compressing on, and
a comparison against the local plaintext baseline (Table 3 role).

    PYTHONPATH=src python examples/federated_credit.py
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import LocalGBDT, SBTParams, VerticalBoosting
from repro.data import synthetic_tabular


def auc(p, y):
    pos, neg = p[y == 1], p[y == 0]
    return float((pos[:, None] > neg[None, :]).mean())


X, y = synthetic_tabular(n=15000, d=12, seed=1, sparsity=0.4)
Xg, Xh1, Xh2 = X[:, :4], X[:, 4:8], X[:, 8:]

base = SBTParams(n_trees=8, max_depth=4, n_bins=32, goss=True, sparse=True,
                 cipher="plain", seed=1)

t0 = time.time()
local = LocalGBDT(base).fit(X, y)
t_local = time.time() - t0

t0 = time.time()
fed = VerticalBoosting(base).fit(Xg, y, [Xh1, Xh2])
t_fed = time.time() - t0

a_local = auc(local.predict_proba(X), y)
a_fed = auc(fed.predict_proba(Xg, [Xh1, Xh2]), y)
print(f"local  : auc={a_local:.4f}  ({t_local:.1f}s)")
print(f"federated (2 hosts): auc={a_fed:.4f}  ({t_fed:.1f}s)")
print(f"lossless delta: {a_fed - a_local:+.5f}")
print(f"per-tree seconds: {np.mean(fed.stats.tree_seconds):.2f}")
print("comm:", {k: f"{v['bytes'] / 1e6:.2f}MB"
                for k, v in fed.channel.summary().items()})
