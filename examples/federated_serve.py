"""Train -> export per party -> serve from reloaded halves.

The serving lifecycle end to end: a vertical federated model is trained
with the affine cipher, each party's half is exported to its own directory
(guest: structure + leaf weights + its splits; host: its splits + binning
only), the halves are reloaded with no training objects in sight, and a
batch is served through the round-batched bit protocol — ONE wire
round-trip per host per batch — then checked bit-identical against the
legacy per-node loop.

    PYTHONPATH=src python examples/federated_serve.py [--out DIR]
"""

import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import SBTParams, VerticalBoosting
from repro.serving import FederatedPredictor, export_model, load_ensemble


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="export directory (default: a temp dir)")
    ap.add_argument("--rows", type=int, default=20000,
                    help="serving batch size")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (3000, 10)).astype(np.float32)
    y = (X @ np.ones(10) + 0.3 * rng.normal(0, 1, 3000) > 0).astype(
        np.float64)
    Xg, Xh = X[:, :4], X[:, 4:]

    print("training (affine cipher, 2 parties)...")
    model = VerticalBoosting(SBTParams(n_trees=6, max_depth=4, n_bins=16,
                                       cipher="affine", key_bits=256,
                                       precision=20, seed=1))
    model.fit(Xg, y, [Xh])

    out = args.out or os.path.join(tempfile.mkdtemp(), "model")
    export_model(model, out)
    print(f"exported per-party halves to {out}: {sorted(os.listdir(out))}")

    # a serving process would load ONLY its own half; the simulation loads
    # all of them and wires them through one predictor + byte ledger
    ens = load_ensemble(out)
    pred = FederatedPredictor(ens.guest, ens.hosts)

    n = args.rows
    Xs = rng.normal(0, 1, (n, 10)).astype(np.float32)
    pred.predict_score(Xs[:, :4], [Xs[:, 4:]])      # compile
    t0 = time.time()
    score = pred.predict_score(Xs[:, :4], [Xs[:, 4:]])
    dt = time.time() - t0

    legacy = model.predict_score(Xs[:, :4], [Xs[:, 4:]], packed=False)
    ch = pred.channel.summary()
    batches = pred.stats.n_predict_batches
    wire = sum(v["bytes"] for v in ch.values()) / batches / n
    print(f"served {n} rows in {dt * 1e3:.1f} ms "
          f"({n / dt:.0f} rows/s from reloaded halves)")
    print(f"bit-identical to the legacy loop: "
          f"{bool(np.array_equal(score, legacy))}")
    print(f"wire: {wire:.1f} bytes/instance, "
          f"{pred.stats.n_predict_roundtrips // batches} round-trip(s) "
          f"per host per batch")
    print("ledger:", {k: v["bytes"] for k, v in ch.items()})


if __name__ == "__main__":
    main()
