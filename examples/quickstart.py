"""Quickstart: vertical federated GBDT in ~20 lines.

A guest (holds labels + 5 features) and one host (5 features) jointly train
a SecureBoost+ model; the host never sees labels or gradients (they arrive
homomorphically encrypted), the guest never sees host feature values.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import SBTParams, VerticalBoosting
from repro.data import synthetic_tabular

X, y = synthetic_tabular(n=4000, d=10, seed=0)
X_guest, X_host = X[:, :5], X[:, 5:]

params = SBTParams(
    n_trees=5, max_depth=4, n_bins=32,
    cipher="affine", key_bits=1024,     # the TPU-path cipher; try "paillier"
    goss=True,                          # gradient-based one-side sampling
)
model = VerticalBoosting(params).fit(X_guest, y, [X_host])

p = model.predict_proba(X_guest, [X_host])
acc = ((p > 0.5) == y).mean()
pos, neg = p[y == 1], p[y == 0]
auc = (pos[:, None] > neg[None, :]).mean()
print(f"train acc={acc:.3f}  auc={auc:.3f}")
print("HE ops:", {k: v for k, v in model.stats.as_dict().items()
                  if k.startswith("n_")})
print("comm bytes by message type:",
      {k: v["bytes"] for k, v in model.channel.summary().items()})
