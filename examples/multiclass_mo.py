"""SecureBoost-MO (paper §5.3): multi-output trees vs per-class trees.

One MO tree per boosting round replaces k per-class trees; g/h vectors are
packed across classes into ceil(k/eta_c) ciphertexts (Algorithm 7).

    PYTHONPATH=src python examples/multiclass_mo.py
"""

import sys
import time

sys.path.insert(0, "src")

from repro.core import SBTParams, VerticalBoosting
from repro.data import synthetic_tabular

k = 7
X, y = synthetic_tabular(n=5000, d=20, seed=2, task="multi", n_classes=k)
Xg, Xh = X[:, :10], X[:, 10:]

for objective in ["multiclass", "mo"]:
    params = SBTParams(n_trees=4, max_depth=4, n_bins=32, objective=objective,
                       n_classes=k, cipher="affine", key_bits=1024,
                       precision=24, seed=2)
    t0 = time.time()
    m = VerticalBoosting(params).fit(Xg, y, [Xh])
    dt = time.time() - t0
    acc = (m.predict_proba(Xg, [Xh]).argmax(1) == y).mean()
    print(f"{objective:10s}: trees={len(m.trees):2d}  acc={acc:.3f}  "
          f"time={dt:.1f}s  decrypts={m.stats.n_decrypt}")
