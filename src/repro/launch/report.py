"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSON.

    PYTHONPATH=src python -m repro.launch.report experiments/dryrun_opt.json
"""

from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ["B", "KB", "MB", "GB", "TB"]:
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(results: dict) -> str:
    lines = ["| arch | shape | mesh | status | compile s | HLO GFLOP/chip | "
             "coll GB/chip | peak mem/chip |",
             "|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        r = results[key]
        if r.get("status") == "ok":
            mem = r.get("memory", {}) or {}
            peak = mem.get("peak_bytes") or mem.get("temp_bytes")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r.get('compile_s', 0)} | "
                f"{r.get('flops_per_chip', 0) / 1e9:.1f} | "
                f"{r.get('collective_bytes_per_chip', 0) / 1e9:.2f} | "
                f"{fmt_bytes(peak)} |")
        elif r.get("status") == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skip (by design) | - | - | - | - |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | - | - | - | - |")
    return "\n".join(lines)


def roofline_table(results: dict) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | MODEL/HLO flops | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    rows = [r for r in results.values()
            if r.get("mesh") == "single" and "acc_compute_s" in r]
    rows.sort(key=lambda r: -(r["acc_roofline_fraction"]))
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['acc_compute_s']:.4f} | "
            f"{r['acc_memory_s']:.4f} | {r['acc_collective_s']:.4f} | "
            f"{r['acc_bottleneck'][:-2]} | {r['acc_useful_flop_ratio']:.3f} | "
            f"{100 * r['acc_roofline_fraction']:.2f}% |")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun.json"
    with open(path) as f:
        results = json.load(f)
    which = sys.argv[2] if len(sys.argv) > 2 else "both"
    if which in ("both", "dryrun"):
        print("### Dry-run matrix\n")
        print(dryrun_table(results))
    if which in ("both", "roofline"):
        print("\n### Roofline (single-pod, loop-exact terms)\n")
        print(roofline_table(results))


if __name__ == "__main__":
    main()
