import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware: the sharding rules are coherent
(no partitioning errors), the program fits (memory_analysis), and yields the
FLOP/byte/collective numbers the roofline (§Roofline) reads.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out experiments/dryrun.json
    DRYRUN_DEVICES=8 ... --debug-mesh     (CI-sized validation)

Results are written incrementally; finished cells are skipped on re-run.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config
from ..models import LM
from ..models.common import set_mesh
from ..optim import AdamWConfig, adamw_update, init_adamw
from ..parallel.sharding import (batch_specs, cache_specs, opt_specs,
                                 param_specs)
from .mesh import make_debug_mesh, make_production_mesh
from .roofline import (collective_bytes, cost_analysis_dict, model_flops,
                       roofline_terms)
from .specs import SHAPES, abstract_params, cell_supported, input_specs


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def scaled_config(cfg, k: int):
    """Config with k 'layer units' (superblocks for hybrid, enc+dec pairs
    for enc-dec), python-unrolled so cost_analysis counts every layer."""
    import dataclasses
    if cfg.family == "hybrid":
        tail = cfg.n_layers % 3
        return dataclasses.replace(cfg, n_layers=3 * k + tail,
                                   scan_unroll=True)
    if cfg.enc_dec:
        return dataclasses.replace(cfg, n_layers=k, n_enc_layers=k,
                                   scan_unroll=True)
    return dataclasses.replace(cfg, n_layers=k, scan_unroll=True)


def layer_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // 3
    return cfg.n_layers


def lower_cell(arch: str, shape: str, mesh, opt_cfg=None, cfg=None):
    cfg = cfg or get_config(arch)
    model = LM(cfg)
    set_mesh(mesh)
    kind = SHAPES[shape]["kind"]
    params_abs = abstract_params(cfg)
    pshard = _named(mesh, param_specs(params_abs, mesh))
    ins = input_specs(cfg, shape)

    if kind == "train":
        ocfg = opt_cfg or AdamWConfig()
        opt_abs = jax.eval_shape(lambda p: init_adamw(p, ocfg), params_abs)
        oshard = _named(mesh, opt_specs(params_abs, mesh))
        oshard = jax.tree.map(
            lambda a, s: s, opt_abs,
            {"m": oshard, "v": oshard,
             "step": jax.sharding.NamedSharding(
                 mesh, jax.sharding.PartitionSpec())})
        bshard = _named(mesh, batch_specs(ins["batch"], mesh))

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt_state = adamw_update(params, grads, opt_state, ocfg)
            return params, opt_state, loss

        jitted = jax.jit(train_step,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None))
        with mesh:
            lowered = jitted.lower(params_abs, opt_abs, ins["batch"])
    elif kind == "prefill":
        bshard = _named(mesh, batch_specs(ins["batch"], mesh))
        jitted = jax.jit(lambda p, b: model.prefill(p, b),
                         in_shardings=(pshard, bshard))
        with mesh:
            lowered = jitted.lower(params_abs, ins["batch"])
    else:  # decode
        cshard = _named(mesh, cache_specs(ins["cache"], mesh))
        small = batch_specs({"tokens": ins["tokens"], "pos": ins["pos"]},
                            mesh)
        tshard = jax.sharding.NamedSharding(mesh, small["tokens"])
        pos_shard = jax.sharding.NamedSharding(mesh, small["pos"])
        args = [params_abs, ins["tokens"], ins["pos"], ins["cache"]]
        in_sh = [pshard, tshard, pos_shard, cshard]
        if "enc_out" in ins:
            fn = lambda p, t, pos, c, e: model.decode_step(p, t, pos, c,
                                                           enc_out=e)
            args.append(ins["enc_out"])
            espec = batch_specs({"e": ins["enc_out"]}, mesh)["e"]
            in_sh.append(jax.sharding.NamedSharding(mesh, espec))
        else:
            fn = lambda p, t, pos, c: model.decode_step(p, t, pos, c)
        jitted = jax.jit(fn, in_shardings=tuple(in_sh))
        with mesh:
            lowered = jitted.lower(*args)
    return cfg, lowered


def run_cell(arch: str, shape: str, mesh, mesh_name: str,
             quantized_opt: bool = False) -> dict:
    t0 = time.time()
    cfg, lowered = lower_cell(
        arch, shape, mesh,
        AdamWConfig(quantize_moments=quantized_opt) if quantized_opt else None)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    chips = mesh.devices.size
    ca = cost_analysis_dict(compiled)
    flops_pd = float(ca.get("flops", 0.0))
    bytes_pd = float(ca.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        mem_info = {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    kind = SHAPES[shape]["kind"]
    tokens = (SHAPES[shape]["seq"] * SHAPES[shape]["batch"]
              if kind in ("train", "prefill") else SHAPES[shape]["batch"])
    mf_pd = model_flops(cfg, kind, tokens, chips)
    terms = roofline_terms(flops_pd, bytes_pd, coll["total"])
    useful = mf_pd / flops_pd if flops_pd else 0.0

    return {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_chip": flops_pd, "bytes_per_chip": bytes_pd,
        "collective_bytes_per_chip": coll["total"],
        "collective_by_op": coll["by_op"],
        "memory": mem_info,
        "model_flops_per_chip": mf_pd,
        "useful_flop_ratio": round(useful, 4),
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in terms.items()},
    }


def _measure(arch, shape, mesh, cfg):
    _, lowered = lower_cell(arch, shape, mesh, cfg=cfg)
    compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(coll["total"]))


def run_cell_accurate(arch: str, shape: str, mesh, mesh_name: str) -> dict:
    """Loop-exact roofline terms: measure fully-unrolled k=1 and k=2 layer
    units, extrapolate linearly to the full depth.  Exact for homogeneous
    stacks (flops(k) = outside + k*per_layer); avoids XLA cost_analysis's
    count-while-bodies-once behaviour."""
    cfg_full = get_config(arch)
    k_full = layer_units(cfg_full)
    t0 = time.time()
    f1, b1, c1 = _measure(arch, shape, mesh, scaled_config(cfg_full, 1))
    f2, b2, c2 = _measure(arch, shape, mesh, scaled_config(cfg_full, 2))
    dt = time.time() - t0
    flops = f1 + (k_full - 1) * (f2 - f1)
    byts = b1 + (k_full - 1) * (b2 - b1)
    coll = c1 + (k_full - 1) * (c2 - c1)

    chips = mesh.devices.size
    kind = SHAPES[shape]["kind"]
    tokens = (SHAPES[shape]["seq"] * SHAPES[shape]["batch"]
              if kind in ("train", "prefill") else SHAPES[shape]["batch"])
    mf_pd = model_flops(cfg_full, kind, tokens, chips)
    terms = roofline_terms(flops, byts, coll)
    ideal = mf_pd / 197e12
    return {
        "acc_flops_per_chip": flops, "acc_bytes_per_chip": byts,
        "acc_collective_bytes_per_chip": coll,
        "acc_compute_s": round(terms["compute_s"], 6),
        "acc_memory_s": round(terms["memory_s"], 6),
        "acc_collective_s": round(terms["collective_s"], 6),
        "acc_bottleneck": terms["bottleneck"],
        "acc_useful_flop_ratio": round(mf_pd / flops, 4) if flops else 0.0,
        "acc_roofline_fraction": round(ideal / terms["bound_s"], 4)
        if terms["bound_s"] else 0.0,
        "acc_measure_s": round(dt, 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--debug-mesh", action="store_true",
                    help="8-device mesh (set DRYRUN_DEVICES=8)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quantized-opt", action="store_true")
    ap.add_argument("--accurate", action="store_true",
                    help="add loop-exact extrapolated roofline terms")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    # always load: --force re-measures requested cells but never discards
    # other cells' records
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for multi in meshes:
        mesh = (make_debug_mesh(multi_pod=multi) if args.debug_mesh
                else make_production_mesh(multi_pod=multi))
        mesh_name = "multi" if multi else "single"
        for arch in archs:
            cfg = get_config(arch)
            for shape in shapes:
                key = f"{arch}|{shape}|{mesh_name}"
                if args.accurate:
                    ok, why = cell_supported(cfg, shape)
                    if not ok or results.get(key, {}).get("status") != "ok":
                        continue
                    if "acc_compute_s" in results[key] and not args.force:
                        print(f"[cached-acc] {key}")
                        continue
                    print(f"[acc]    {key} ...", flush=True)
                    try:
                        results[key].update(
                            run_cell_accurate(arch, shape, mesh, mesh_name))
                        r = results[key]
                        print(f"  acc: compute {r['acc_compute_s']:.4f}s "
                              f"memory {r['acc_memory_s']:.4f}s "
                              f"collective {r['acc_collective_s']:.4f}s "
                              f"roofline {100 * r['acc_roofline_fraction']:.1f}%"
                              , flush=True)
                    except Exception as e:    # noqa: BLE001
                        print(f"  acc-ERROR {type(e).__name__}: {e}",
                              flush=True)
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
                    continue
                if key in results and results[key].get("status") in (
                        "ok", "skip") and not args.force:
                    print(f"[cached] {key}")
                    continue
                ok, why = cell_supported(cfg, shape)
                if not ok:
                    results[key] = {"arch": arch, "shape": shape,
                                    "mesh": mesh_name, "status": "skip",
                                    "reason": why}
                    print(f"[skip]   {key}: {why}")
                else:
                    print(f"[run]    {key} ...", flush=True)
                    try:
                        results[key] = run_cell(
                            arch, shape, mesh, mesh_name,
                            quantized_opt=args.quantized_opt)
                        r = results[key]
                        print(f"  ok: compile {r['compile_s']}s  "
                              f"compute {r['compute_s']:.4f}s  "
                              f"memory {r['memory_s']:.4f}s  "
                              f"collective {r['collective_s']:.4f}s  "
                              f"bound={r['bottleneck']}", flush=True)
                    except Exception as e:  # noqa: BLE001
                        results[key] = {
                            "arch": arch, "shape": shape, "mesh": mesh_name,
                            "status": "error", "error": f"{type(e).__name__}: {e}",
                            "trace": traceback.format_exc()[-2000:]}
                        print(f"  ERROR {type(e).__name__}: {e}", flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skip")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped-by-design, {n_err} errors")


if __name__ == "__main__":
    main()
