"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
initialization.  Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) -- "pod" carries
DP (or pipeline stages) across the slower inter-pod links.  The axis layout
scales to N pods by changing the leading dim only.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_gbdt_mesh(model: int | None = None):
    """(data, model) mesh over all local devices for the GBDT frontier
    engine (DESIGN.md §5/§7): instances shard over "data", the layer
    histogram's node axis over "model".  ``model`` caps the node-shard
    count (default 2 when the device count allows, so both collectives are
    exercised); instances take the remaining factor.  Returns None on a
    single device — the engine then uses the unsharded dispatch."""
    n = len(jax.devices())
    if n < 2:
        return None
    if model is None:
        model = 2 if n % 2 == 0 else 1
    model = max(1, min(model, n))
    while n % model:
        model -= 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CI-sized validation of the same code paths (8 devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
