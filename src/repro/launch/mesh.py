"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
initialization.  Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) -- "pod" carries
DP (or pipeline stages) across the slower inter-pod links.  The axis layout
scales to N pods by changing the leading dim only.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CI-sized validation of the same code paths (8 devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
