"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No device allocation: everything here is abstract.  Shapes follow the
assigned table:

    train_4k     seq 4096,    global batch 256   (train_step)
    prefill_32k  seq 32768,   global batch 32    (prefill forward)
    decode_32k   KV 32768,    global batch 128   (one-token serve_step)
    long_500k    KV 524288,   global batch 1     (sub-quadratic archs only)

[audio]/[vlm] frontends are stubs: encoder frame / patch embeddings arrive
precomputed.  Whisper decode carries a 4096-frame encoder memory alongside
the 32k self-attn cache (documented deviation: Whisper's real frame cap is
1500; the cell exercises the mechanical shape).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models import LM, ModelConfig

SHAPES = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}

SUBQUADRATIC = {"hybrid", "ssm"}
WHISPER_DECODE_ENC_LEN = 4096


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and cfg.family not in SUBQUADRATIC:
        return False, ("full-attention arch: 524k decode is quadratic by "
                       "construction -- skipped by design (DESIGN.md §4)")
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """Abstract inputs for the cell; keys depend on the cell kind."""
    info = SHAPES[shape]
    S, B = info["seq"], info["batch"]
    kind = info["kind"]
    model = LM(cfg)
    if kind in ("train", "prefill"):
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        if cfg.enc_dec:
            batch["enc_embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}
    # decode: cache sized to the cell's KV length (ring = window for hybrid)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    out = {"tokens": sds((B, 1), jnp.int32),
           "pos": sds((B,), jnp.int32),
           "cache": cache}
    if cfg.enc_dec:
        out["enc_out"] = sds((B, WHISPER_DECODE_ENC_LEN, cfg.d_model),
                             cfg.dtype)
    return out


def abstract_params(cfg: ModelConfig):
    return LM(cfg).abstract_init()


def token_count(shape: str) -> int:
    info = SHAPES[shape]
    return info["seq"] * info["batch"]
