"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e-class constants
fixed by the brief):

    compute    = HLO_FLOPs_per_chip / 197e12      (bf16 peak per chip)
    memory     = HLO_bytes_per_chip / 819e9       (HBM bandwidth)
    collective = collective_bytes_per_chip / 50e9 (ICI link bandwidth)

FLOPs/bytes come from ``compiled.cost_analysis()`` (the post-SPMD module is
the per-chip program).  Collective bytes are NOT in cost_analysis: we parse
the optimized HLO, summing the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, and weight
ops inside while-loop bodies (scan-over-layers) by the loop trip count
(recovered from the largest integer constant in the loop's condition
computation -- exact for lax.scan).
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: older
    releases return a per-device list of dicts, newer ones a single dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[ (-]")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * b
    return total


def _split_computations(hlo: str) -> dict:
    comps = {}
    name = None
    buf = []
    assign = re.compile(r"%?[\w.\-]+\s*=")          # op lines: "%x = ..."
    header = re.compile(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and not assign.match(s):
            m = header.match(s)
            if m:
                if name is not None:
                    comps[name] = buf
                name = m.group(1)
                buf = []
                continue
        if s == "}":
            if name is not None:
                comps[name] = buf
                name = None
                buf = []
        elif name is not None:
            buf.append(s)
    if name is not None:
        comps[name] = buf
    return comps


def collective_bytes(hlo: str) -> dict:
    """Per-chip bytes moved by collectives, weighted by loop trip counts.

    Returns {"total": int, "by_op": {op: bytes}, "n_sites": int}.
    """
    comps = _split_computations(hlo)

    # trip counts: while ops name (condition, body) computations
    trip_of_body: dict = {}
    called_whiles: dict = {}          # comp -> list[(body, trips)]
    for cname, lines in comps.items():
        for ln in lines:
            m = _WHILE_RE.search(ln)
            if m:
                cond, body = m.group(1), m.group(2)
                consts = [int(x) for x in
                          _CONST_RE.findall("\n".join(comps.get(cond, [])))]
                trips = max(consts) if consts else 1
                trip_of_body[body] = trips
                called_whiles.setdefault(cname, []).append((body, trips))

    by_op: dict = {c: 0 for c in _COLLECTIVES}
    n_sites = 0

    def comp_bytes(cname, seen):
        nonlocal n_sites
        if cname in seen:
            return {c: 0 for c in _COLLECTIVES}
        seen = seen | {cname}
        acc = {c: 0 for c in _COLLECTIVES}
        for ln in comps.get(cname, []):
            m = _OP_RE.search(ln)
            if m:
                op = m.group(1)
                sig = ln.split("=", 1)[0] + "=" + ln.split("=", 1)[1]
                lhs = ln.split(" = ", 1)
                size = _shape_bytes(lhs[1] if len(lhs) > 1 else sig)
                # result signature only: take bytes up to the op name
                head = (lhs[1] if len(lhs) > 1 else sig).split(m.group(1))[0]
                size = _shape_bytes(head) or size
                acc[op] += size
                n_sites += 1
        for body, trips in called_whiles.get(cname, []):
            sub = comp_bytes(body, seen)
            for k, v in sub.items():
                acc[k] += v * trips
        return acc

    # find the entry computation: the one containing the final root or the
    # first one defined with ENTRY; fall back to summing top-level comps
    entry = None
    for ln in hlo.splitlines():
        m = re.match(r"ENTRY\s+%?([\w.\-]+)", ln.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # approximate: every computation once, whiles weighted
        bodies = set(trip_of_body)
        total = {c: 0 for c in _COLLECTIVES}
        for cname in comps:
            if cname in bodies:
                continue
            sub = comp_bytes(cname, set())
            for k, v in sub.items():
                total[k] += v
        by_op = total
    else:
        by_op = comp_bytes(entry, set())
    return {"total": sum(by_op.values()), "by_op": by_op, "n_sites": n_sites}


def roofline_terms(flops_pd: float, bytes_pd: float, coll_pd: float) -> dict:
    compute = flops_pd / PEAK_FLOPS
    memory = bytes_pd / HBM_BW
    coll = coll_pd / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]
                              if k.endswith("_s") else -1)
    terms["bound_s"] = max(compute, memory, coll)
    return terms


def model_flops(cfg, shape_kind: str, tokens: int, chips: int) -> float:
    """Analytic useful FLOPs per chip: 6ND train, 2ND prefill/decode
    (N = active params for MoE)."""
    n = cfg.n_active_params()
    mult = 6 if shape_kind == "train" else 2
    return mult * n * tokens / chips
