"""End-to-end training driver (runnable at laptop scale, pjit-able at pod
scale): model + AdamW + checkpoint/restore + fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt_lib
from ..configs import get_config
from ..data import SyntheticTokens
from ..models import LM
from ..optim import AdamWConfig, adamw_update, init_adamw
from ..runtime import ResilientLoop, StragglerPolicy


def make_train_step(model: LM, ocfg: AdamWConfig):
    @jax.jit
    def step(state, batch):
        params, opt_state = state

        def loss_fn(p):
            return model.loss(p, batch)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adamw_update(params, grads, opt_state, ocfg)
        return (params, opt_state), loss
    return step


def train(arch: str, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 128, ckpt_dir: str | None = None, lr: float = 3e-4,
          save_every: int = 20, log_every: int = 10,
          quantize_moments: bool = False, seed: int = 0):
    cfg = get_config(arch, smoke=smoke)
    if cfg.ssm_chunk:
        seq = max(seq, cfg.ssm_chunk)
        seq -= seq % cfg.ssm_chunk
    model = LM(cfg)
    ocfg = AdamWConfig(lr=lr, quantize_moments=quantize_moments)
    data = SyntheticTokens(cfg.vocab, batch, seq, seed)

    params = model.init(jax.random.key(seed))
    opt_state = init_adamw(params, ocfg)
    start = 0
    if ckpt_dir and (last := ckpt_lib.latest_step(ckpt_dir)) is not None:
        print(f"restoring step {last} from {ckpt_dir}")
        params, opt_state = ckpt_lib.restore(
            ckpt_dir, last, (params, opt_state))
        start = last

    step_fn = make_train_step(model, ocfg)
    losses = []

    def wrapped_step(state, b):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.enc_dec:
            b["enc_embeds"] = jax.random.normal(
                jax.random.key(len(losses)), (batch, seq, cfg.d_model),
                jnp.float32)
        state, loss = step_fn(state, b)
        losses.append(float(loss))
        if len(losses) % log_every == 0:
            print(f"step {start + len(losses):5d}  loss {losses[-1]:.4f}")
        return state

    def save_fn(s, state):
        if ckpt_dir:
            ckpt_lib.save(ckpt_dir, s, state)

    def restore_fn():
        if ckpt_dir and (last := ckpt_lib.latest_step(ckpt_dir)) is not None:
            return last, ckpt_lib.restore(ckpt_dir, last, (params, opt_state))
        return 0, (params, opt_state)

    loop = ResilientLoop(wrapped_step, save_fn, restore_fn, data,
                         save_every=save_every,
                         straggler=StragglerPolicy(factor=10.0))
    t0 = time.time()
    _, (params, opt_state) = loop.run((params, opt_state), start, steps)
    dt = time.time() - t0
    print(f"{steps - start} steps in {dt:.1f}s "
          f"({(steps - start) * batch * seq / max(dt, 1e-9):.0f} tok/s); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--quantize-moments", action="store_true")
    args = ap.parse_args()
    train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
          seq=args.seq, ckpt_dir=args.ckpt, lr=args.lr,
          quantize_moments=args.quantize_moments)


if __name__ == "__main__":
    main()
