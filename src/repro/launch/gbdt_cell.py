import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Paper-technique dry-run cell: SecureBoost+ ciphertext histogram building
on the production mesh.

Mapping (DESIGN.md §3/§5): instances shard over "data", features (= the
party boundary) over "model"; the encrypted-GH broadcast and split-info
gather are the only cross-party collectives.  One tree layer (16 nodes,
depth 4) over a GOSS-sampled 2^18-instance batch, 2000 features, 32 bins,
1024-bit affine ciphertexts (W = 132 radix-2^8 limbs incl. lazy headroom).

Three formulations, measured identically to the LM cells:

  dense    one-hot einsum (what a naive XLA port does)
  scatter  vmapped scatter-add (lazy limb sums; no one-hot materialized)
  + the Pallas kernel (kernels/histogram) is the TPU execution path whose
    per-tile cost the scatter variant's terms bound from above.

    PYTHONPATH=src python -m repro.launch.gbdt_cell [--variant scatter]
"""

import argparse
import functools
import json

import jax
import jax.numpy as jnp

from ..launch.mesh import make_production_mesh
from ..launch.roofline import (collective_bytes, cost_analysis_dict,
                                roofline_terms)
from jax.sharding import NamedSharding, PartitionSpec as P

N, F, NB_BINS, NODES, W = 2 ** 18, 2000, 32, 16, 132
F_BLOCK = 100
NB = NODES * NB_BINS


def hist_dense(bins, cts, node_of):
    """One-hot einsum over feature blocks (naive formulation)."""
    ids = node_of[:, None] * NB_BINS + bins          # (N, F) flat (node, bin)

    def block(carry, fb):
        oh = jax.nn.one_hot(fb, NB, dtype=jnp.float32)        # (N, Fb, NB)
        h = jnp.einsum("ifb,iw->fbw", oh, cts.astype(jnp.float32))
        return carry, h.astype(jnp.int32)

    blocks = ids.reshape(N, F // F_BLOCK, F_BLOCK).transpose(1, 0, 2)
    _, out = jax.lax.scan(block, 0, blocks)
    return out.reshape(F, NB, W)


def hist_scatter(bins, cts, node_of):
    """Scatter-add (lazy limb sums): O(N*F*W) updates, no one-hot."""
    ids = node_of[:, None] * NB_BINS + bins          # (N, F)

    def one_feature(idv):
        return jnp.zeros((NB, W), jnp.int32).at[idv].add(cts)

    def block(carry, fb):                            # fb: (N, F_BLOCK)
        return carry, jax.vmap(one_feature, in_axes=1)(fb)

    blocks = ids.reshape(N, F // F_BLOCK, F_BLOCK).transpose(1, 0, 2)
    _, out = jax.lax.scan(block, 0, blocks)
    return out.reshape(F, NB, W)


def lower_cell(mesh, variant: str):
    from ..parallel.sharding import gbdt_sharding

    fn = {"dense": hist_dense, "scatter": hist_scatter,
          "scatter_rs": hist_scatter}[variant]
    bins = jax.ShapeDtypeStruct((N, F), jnp.int32)
    cts = jax.ShapeDtypeStruct((N, W), jnp.int32)
    node_of = jax.ShapeDtypeStruct((N,), jnp.int32)
    d = ("pod", "data") if "pod" in mesh.axis_names else "data"
    # input layouts come from the GBDT rule table (DESIGN.md §5)
    in_sh = (gbdt_sharding(mesh, "bins"),            # (instance, feature)
             gbdt_sharding(mesh, "gh_cts", ndim=2),  # flattened limb batch
             gbdt_sharding(mesh, "node_slot"))
    if variant == "scatter_rs":
        # bins axis of the histogram sharded over data: the cross-instance
        # reduction becomes a reduce-scatter instead of all-reduce+slice;
        # downstream cumsum/compress run on (model, data)-sharded slabs.
        out_sh = NamedSharding(mesh, P("model", d, None))
    else:
        out_sh = NamedSharding(mesh, P("model", None, None))
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    with mesh:
        return jitted.lower(bins, cts, node_of)


def run(variant: str, multi_pod: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered = lower_cell(mesh, variant)
    compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    terms = roofline_terms(float(ca.get("flops", 0)),
                           float(ca.get("bytes accessed", 0)),
                           coll["total"])
    # useful work: one lazy limb-add per (instance, feature, limb)
    useful_adds = N * F * W / mesh.devices.size
    return {
        "cell": f"secureboost_hist|{variant}|{'multi' if multi_pod else 'single'}",
        "flops_per_chip": float(ca.get("flops", 0)),
        "bytes_per_chip": float(ca.get("bytes accessed", 0)),
        "collective_bytes_per_chip": coll["total"],
        "useful_adds_per_chip": useful_adds,
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in terms.items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/gbdt_cell.json")
    args = ap.parse_args()
    variants = (["dense", "scatter", "scatter_rs"]
                if args.variant == "all" else [args.variant])
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    for v in variants:
        r = run(v, args.multi_pod)
        results[r["cell"]] = r
        print(f"{r['cell']}: compute {r['compute_s']:.4f}s "
              f"memory {r['memory_s']:.4f}s collective {r['collective_s']:.4f}s "
              f"bound={r['bottleneck']}", flush=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
