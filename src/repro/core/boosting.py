"""SecureBoost+ boosting driver (paper §3-§6) and the local baseline.

``VerticalBoosting`` orchestrates guest + hosts over a byte-counted channel:

  objective   "binary" (one tree/round), "multiclass" (one tree per class
              per round -- the paper's *default* multi-class setting), or
              "mo" (SecureBoost-MO: one multi-output tree per round)
  tree_mode   "default" | "mix" | "layered"  (paper §5.1-5.2)
  cipher      "plain" | "affine" | "paillier"
  packing / histogram_subtraction / compression / goss / sparse  -- ablations

``LocalGBDT`` is the plaintext single-party baseline (the XGBoost role in
the paper's tables): identical binning, gains, and leaf weights, so the
federated model with the plain cipher is bit-identical to it (tested).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from . import encoding, mo_encoding
from ..data.pipeline import RowBlocks
from .binning import BinnedData, bin_features, bin_features_stream
from .goss import goss_sample
from .he import get_cipher
from .histogram import CipherHistogram
from .loss import LogLoss, SoftmaxLoss
from ..obs import trace as obs_trace
from ..obs.trace import NULL_TRACER, Tracer
from .party import Channel, Stats
from .tree import (GUEST, FederatedTree, HostRuntime, MOCodec, NoPackCodec,
                   PackedCodec, TreeContext, _EncryptPump, _encrypt_all,
                   grow_forest, grow_tree, predict_tree)


@dataclasses.dataclass
class SBTParams:
    n_trees: int = 10
    max_depth: int = 5
    learning_rate: float = 0.3
    lam: float = 1.0
    n_bins: int = 32
    min_leaf: int = 1
    min_gain: float = 1e-6
    objective: str = "binary"          # binary | multiclass | mo
    n_classes: int = 2
    cipher: str = "plain"              # plain | affine | paillier
    key_bits: int = 1024
    precision: int = encoding.DEFAULT_PRECISION
    goss: bool = False
    top_rate: float = 0.2
    other_rate: float = 0.1
    packing: bool = True
    histogram_subtraction: bool = True
    compression: bool = True
    sparse: bool = False
    tree_mode: str = "default"         # default | mix | layered
    guest_depth: int = 2               # layered mode
    host_depth: int = 3
    trees_per_party: int = 1           # mix mode
    use_pallas: bool = True
    pipeline: bool = False             # pipelined boosting (DESIGN.md §12):
                                       # encrypt+broadcast of the next
                                       # tree's enc_gh overlaps the current
                                       # tree's growth; bit-identical to
                                       # sequential for forest_size=1
    forest_size: int = 1               # round-forest width (FedGBF-style):
                                       # k bagged shallow trees per round
                                       # share ONE enc_gh round-trip;
                                       # binary objective only
    forest_subsample: float = 0.8      # per-member bag fraction of the
                                       # (GOSS-)selected rows
    seed: int = 0
    mesh: object = None                # optional (data, model) jax Mesh: the
                                       # frontier engine shards instances
                                       # over "data" and the layer histogram
                                       # node axis over "model" (DESIGN §5/§7)
    row_block: int = 0                 # out-of-core row-block size (§13):
                                       # > 0 streams every O(rows) training
                                       # stage (encrypt->ship, frontier
                                       # accumulation, guest histograms) in
                                       # blocks of this many rows whenever a
                                       # batch exceeds it; 0 keeps the
                                       # monolithic fast path.  Bit-identical
                                       # either way (limb backends only)
    trace: bool = False                # structured tracing (DESIGN.md §14):
                                       # record span/instant events into a
                                       # bounded per-party ring buffer.
                                       # Protocol- and model-neutral: only
                                       # observation, never control flow


def cipher_kwargs(params: SBTParams) -> dict:
    """Cipher-construction kwargs from run params — the SINGLE definition
    shared by the guest driver and the multi-host PartyProcess, so the
    two sides can never silently diverge on key parameters."""
    if params.cipher == "plain":
        return {"bits": max(params.key_bits, 256)}
    return {"key_bits": params.key_bits, "seed": params.seed}


class VerticalBoosting:
    def __init__(self, params: SBTParams):
        self.params = params
        self.trees: list[FederatedTree] = []
        self.tree_class: list[int] = []   # multiclass: class of each tree
        self.channel = Channel()
        self.stats = Stats()
        self.tracer = NULL_TRACER
        self.init_score = None
        self._loss = None
        self._predictor = None            # cached packed serving engine
        self._predictor_n_trees = -1
        # multi-host mode (runtime/transport.py): handles to host parties
        # living in their own OS processes.  When set, ``fit`` is called
        # with X_hosts=[] — host features never enter this process — and
        # every cross-party message flows through the transport channel.
        self.remote_hosts: list | None = None

    # ------------------------------------------------------------------
    # fit = begin_fit + boost_round per round + finish_fit.  The split
    # exists for the fault-tolerant runtime (runtime/transport.py): each
    # round is a resume boundary — ``boost_round`` is transactional
    # (state is only committed once the whole round succeeded), so a
    # faulted round can be replayed bit-identically from the boundary
    # after ``rollback_to_round``.
    def fit(self, X_guest: np.ndarray, y: np.ndarray,
            X_hosts: list[np.ndarray]):
        score = self.begin_fit(X_guest, y, X_hosts)
        for t in range(self.params.n_trees):
            score = self.boost_round(t, score)
        return self.finish_fit(score)

    def begin_fit(self, X_guest: np.ndarray, y: np.ndarray,
                  X_hosts: list[np.ndarray]) -> np.ndarray:
        """Reset model state, bin features, init the loss/cipher; returns
        the initial score vector (the round-0 boundary state)."""
        p = self.params
        # a refit is a fresh model: without these resets a second fit()
        # appended n_trees more trees whose (fid, bid) splits were decoded
        # against the NEW fit's binning thresholds — silently wrong
        # scores — and stats/ledger accumulated across fits
        if p.forest_size > 1 and p.objective != "binary":
            raise ValueError(
                "forest_size > 1 (round-forests) requires objective="
                "'binary': multiclass rounds already batch one tree per "
                "class and MO packs classes into slots")
        self.trees = []
        self.tree_class = []
        self.stats = Stats()
        self.channel.reset_accounting()
        # guest tracer: params.trace makes a fresh per-fit buffer; an
        # enabled process-default tracer (benchmark harness --trace) is
        # inherited so benches need no plumbing; else the null tracer
        # keeps every emission site one-bool-test cheap
        if p.trace:
            self.tracer = Tracer("guest")
        elif obs_trace.current().enabled:
            self.tracer = obs_trace.current()
        else:
            self.tracer = NULL_TRACER
        self.channel.tracer = self.tracer
        self._predictor = None            # stale after refit
        self._predictor_n_trees = -1
        self.guest_data = self._bin(X_guest)
        self.host_data = [self._bin(Xh) for Xh in X_hosts]
        y = np.asarray(y, np.float64)
        self._y = y
        n = len(y)

        if p.objective == "binary":
            self._loss = LogLoss()
            self.init_score = self._loss.init_score(y)
            score = np.full(n, self.init_score)
        else:
            self._loss = SoftmaxLoss(p.n_classes)
            self.init_score = self._loss.init_score(y)
            score = np.tile(self.init_score, (n, 1))

        self.cipher = get_cipher(p.cipher, **self._cipher_kwargs())
        self._n_parties = 1 + (len(self.remote_hosts)
                               if self.remote_hosts is not None
                               else len(X_hosts))
        return score

    def _bin(self, X) -> BinnedData:
        """Bin one party's features.  A pre-binned ``BinnedData`` passes
        through; a chunked ``RowBlocks`` source takes the out-of-core
        two-pass sketch path (§13); an in-memory array takes the
        monolithic exact-quantile fit."""
        p = self.params
        if isinstance(X, BinnedData):
            return X
        if isinstance(X, RowBlocks):
            return bin_features_stream(X, p.n_bins, sparse=p.sparse,
                                       use_pallas=p.use_pallas)
        return bin_features(X, p.n_bins, sparse=p.sparse,
                            use_pallas=p.use_pallas)

    @property
    def trees_per_round(self) -> int:
        """Trees one ``boost_round`` appends (the resume-boundary unit)."""
        if self.params.objective == "multiclass":
            return self.params.n_classes
        return max(1, self.params.forest_size)

    def boost_round(self, t: int, score: np.ndarray) -> np.ndarray:
        """Grow round ``t``'s tree(s) and return the updated score.

        Transactional against ``score`` and the model: the input score is
        never mutated and trees are appended only after every tree of the
        round finished — a mid-round fault leaves both exactly at the
        round boundary, and the randomness streams (GOSS, host shuffles)
        are keyed by the ABSOLUTE tree index, so a replay regrows
        bit-identical trees."""
        p = self.params
        if len(self.trees) != t * self.trees_per_round:
            raise RuntimeError(
                f"boost_round({t}) expects {t * self.trees_per_round} "
                f"trees, model has {len(self.trees)} — rollback_to_round "
                f"first")
        score = np.array(score, np.float64, copy=True)
        y = self._y
        t0 = time.perf_counter()
        grown = []
        if p.objective == "multiclass":
            # g/h are computed ONCE per round for all classes (the
            # paper's default multiclass setting): recomputing inside
            # the class loop trained class c+1 on scores already
            # updated by class c's tree this round
            g, h = self._loss.grad_hess(y, score)
            mix_party = self._mix_party(t, self._n_parties)
            ctxs, scheds = [], []
            for c in range(p.n_classes):
                ctx, sched = self._tree_ctx(
                    self.cipher, g[:, c], h[:, c], t, mix_party=mix_party,
                    tree_idx=t * p.n_classes + c)
                ctxs.append(ctx)
                scheds.append(sched)
            # cross-class prefetch (DESIGN.md §12): all class g/h of the
            # round are known up front, so class c+1's enc_gh encrypts and
            # ships on a pump thread WHILE class c grows.  One pump in
            # flight at a time: class c's broadcast always completes
            # before c+1's dispatches, keeping wire order sequential (and
            # the protocol bit-identical — only wall-clock overlap moves).
            pump = None
            for c in range(p.n_classes):
                ctx = ctxs[c]
                if pump is not None:
                    pump.join()
                    pump = None
                if p.pipeline:
                    if not ctx.enc_shipped and \
                            self._sched_has_host(scheds[c], len(ctx.hosts)):
                        _encrypt_all(ctx, ctx.g[ctx.sel_rows],
                                     ctx.h[ctx.sel_rows])
                    if c + 1 < p.n_classes and self._sched_has_host(
                            scheds[c + 1], len(ctxs[c + 1].hosts)):
                        nxt = ctxs[c + 1]
                        pump = _EncryptPump(nxt, nxt.g[nxt.sel_rows],
                                            nxt.h[nxt.sel_rows])
                with self.tracer.span("class", round=t, cls=c,
                                      tree=ctx.tree_idx):
                    tree, leaf_rows = grow_tree(ctx, scheds[c])
                grown.append((tree, c, leaf_rows))
            if pump is not None:      # defensive: last class never pumps
                pump.join()
        elif p.forest_size > 1:
            g, h = self._loss.grad_hess(y, score)
            grown.extend(self._grow_forest(self.cipher, g, h, t))
        else:
            g, h = self._loss.grad_hess(y, score)
            tree, leaf_rows = self._grow(
                self.cipher, g, h, t,
                mix_party=self._mix_party(t, self._n_parties),
                tree_idx=t)
            grown.append((tree, -1, leaf_rows))
        for tree, cls, leaf_rows in grown:
            self.trees.append(tree)
            self.tree_class.append(cls)
            self._apply(score, tree, leaf_rows, cls=cls)
        dt = time.perf_counter() - t0
        self.stats.tree_seconds.append(dt)
        self.tracer.complete("round", int(t0 * 1e9), int(dt * 1e9), round=t)
        return score

    def rollback_to_round(self, t: int) -> None:
        """Truncate the model to the round-``t`` boundary (replay)."""
        keep = t * self.trees_per_round
        del self.trees[keep:]
        del self.tree_class[keep:]
        del self.stats.tree_seconds[t:]
        self._predictor = None
        self._predictor_n_trees = -1

    def finish_fit(self, score: np.ndarray):
        self.train_score_ = score
        return self

    def _cipher_kwargs(self):
        return cipher_kwargs(self.params)

    def _mix_party(self, t: int, n_parties: int):
        if self.params.tree_mode != "mix":
            return None
        cycle = t // max(1, self.params.trees_per_party)
        return cycle % n_parties        # 0 = guest, 1.. = host id + 1

    # ------------------------------------------------------------------
    def _make_hosts(self, cipher) -> list:
        if self.remote_hosts is not None:
            return self.remote_hosts    # one party per process (transport)
        p = self.params
        engines = [CipherHistogram(cipher, p.n_bins, sparse=p.sparse,
                                   use_pallas=p.use_pallas,
                                   stats=self.stats, mesh=p.mesh,
                                   tracer=self.tracer)
                   for _ in self.host_data]
        return [HostRuntime(hid=i, data=d, engine=e)
                for i, (d, e) in enumerate(zip(self.host_data, engines))]

    def _sched_has_host(self, sched, n_hosts: int) -> bool:
        if n_hosts == 0:
            return False
        if sched is None:
            return True
        return any(sched(d)[1] for d in range(self.params.max_depth))

    def _tree_ctx(self, cipher, g, h, t: int, mix_party=None,
                  tree_idx: int | None = None) -> tuple:
        """Build one tree's (TreeContext, schedule) without growing it —
        the pipelined driver needs the context early so the next tree's
        enc_gh can encrypt + ship while the current tree still splits."""
        p = self.params
        n = g.shape[0]
        # the ABSOLUTE index of the tree being grown.  Passed explicitly
        # by boost_round because the round commits its trees only at the
        # end (transactional replay), so len(self.trees) lags mid-round.
        if tree_idx is None:
            tree_idx = len(self.trees)
        if p.goss:
            # dedicated per-tree stream keyed by the GLOBAL tree counter:
            # host split-info shuffling must not perturb GOSS sampling (or
            # federated != local under GOSS), and a per-round key would
            # hand every class tree of a multiclass round the identical
            # subsample of the rest set
            goss_rng = np.random.default_rng((p.seed, tree_idx, 17))
            sel, w = goss_sample(g, p.top_rate, p.other_rate, goss_rng)
            g = g.copy(); h = h.copy()
            if g.ndim == 1:
                g[sel] *= w; h[sel] *= w
            else:
                g[sel] *= w[:, None]; h[sel] *= w[:, None]
        else:
            sel = np.arange(n)

        codec = self._make_codec(cipher, g[sel], h[sel])
        hosts = self._make_hosts(cipher)
        ctx = TreeContext(params=p, cipher=cipher, codec=codec,
                          channel=self.channel, stats=self.stats,
                          guest_data=self.guest_data, g=g, h=h, sel_rows=sel,
                          hosts=hosts, tree_idx=tree_idx)
        return ctx, self._schedule(mix_party, len(hosts))

    def _grow(self, cipher, g, h, t: int, mix_party=None,
              tree_idx: int | None = None) -> tuple:
        ctx, schedule = self._tree_ctx(cipher, g, h, t, mix_party=mix_party,
                                       tree_idx=tree_idx)
        return grow_tree(ctx, schedule)

    def _grow_forest(self, cipher, g, h, t: int) -> list:
        """One round-forest (FedGBF-style): ``forest_size`` bagged member
        trees sharing ONE enc_gh round-trip (``core/tree.py grow_forest``).
        Leaf weights grow with learning_rate / k so the round's additive
        update averages the members instead of k-times overshooting.
        Returns ``[(tree, -1, leaf_rows), ...]``."""
        p = self.params
        k = p.forest_size
        base = t * k                    # absolute index of the first member
        n = g.shape[0]
        if p.goss:
            # ONE GOSS pass per round, keyed by the round's base index:
            # members share the encrypted batch, so they must share the
            # selection it was built from — bags re-subsample within it
            goss_rng = np.random.default_rng((p.seed, base, 17))
            sel, w = goss_sample(g, p.top_rate, p.other_rate, goss_rng)
            g = g.copy(); h = h.copy()
            g[sel] *= w; h[sel] *= w
        else:
            sel = np.arange(n)
        bag_rng = np.random.default_rng((p.seed, base, 29))
        if p.forest_subsample >= 1.0:
            bags = [np.arange(len(sel)) for _ in range(k)]
        else:
            size = max(1, int(round(p.forest_subsample * len(sel))))
            bags = [np.sort(bag_rng.choice(len(sel), size, replace=False))
                    for _ in range(k)]

        codec = self._make_codec(cipher, g[sel], h[sel])
        hosts = self._make_hosts(cipher)
        fp = dataclasses.replace(p, learning_rate=p.learning_rate / k)
        ctx = TreeContext(params=fp, cipher=cipher, codec=codec,
                          channel=self.channel, stats=self.stats,
                          guest_data=self.guest_data, g=g, h=h, sel_rows=sel,
                          hosts=hosts, tree_idx=base, forest_k=k)
        schedule = self._schedule(self._mix_party(t, self._n_parties),
                                  len(hosts))
        members = grow_forest(ctx, bags, schedule)
        return [(tree, -1, leaf_rows) for tree, leaf_rows in members]

    def _schedule(self, mix_party, n_hosts: int):
        p = self.params
        if p.tree_mode == "mix" and mix_party is not None:
            if mix_party == 0:
                return lambda d: (True, [])
            return lambda d: (False, [mix_party - 1])
        if p.tree_mode == "layered":
            return lambda d: ((False, list(range(n_hosts)))
                              if d < p.host_depth else (True, []))
        return None

    def _make_codec(self, cipher, g, h):
        p = self.params
        if p.objective == "mo":
            plan = mo_encoding.plan_mo_packing(g, h, len(g),
                                               cipher.plaintext_bits,
                                               p.precision)
            return MOCodec(plan)
        if p.packing:
            plan = encoding.plan_packing(g, h, len(g), cipher.plaintext_bits,
                                         p.precision)
            return PackedCodec(plan)
        return NoPackCodec.plan(g, p.precision)

    # ------------------------------------------------------------------
    def _apply(self, score, tree: FederatedTree, leaf_rows: dict,
               cls: int = -1):
        """Training score update from the grower's train-side row->leaf
        map; ``leaf_rows`` never lives on the tree (serving/export must
        see no row-level state)."""
        for nd in tree.nodes:
            if nd.left == -1 and nd.weight is not None:
                rows = leaf_rows[nd.nid]
                if cls >= 0:
                    score[rows, cls] += nd.weight
                else:
                    score[rows] += nd.weight

    def _serving_predictor(self):
        """Cached packed serving engine over this model's trees, wired to
        the model's wire/stat ledgers (rebuilt if trees changed)."""
        from ..serving import FederatedPredictor, PackedEnsemble
        if self._predictor is None \
                or self._predictor_n_trees != len(self.trees):
            ens = PackedEnsemble.from_model(self)
            self._predictor = FederatedPredictor(
                ens.guest, ens.hosts, channel=self.channel,
                stats=self.stats, mesh=self.params.mesh,
                use_pallas=self.params.use_pallas)
            self._predictor_n_trees = len(self.trees)
        return self._predictor

    def predict_score(self, X_guest, X_hosts,
                      packed: bool = True) -> np.ndarray:
        """Raw ensemble scores.  ``packed=True`` (default) serves through
        the packed engine — bit-identical to the legacy loop, one wire
        round-trip per host per batch, counted under the ``predict_*``
        tags.  ``packed=False`` keeps the per-node ``predict_tree`` loop
        as the slow oracle (tests, benchmarks)."""
        if packed and self.trees:
            return self._serving_predictor().predict_score(X_guest, X_hosts)
        if self.remote_hosts is not None:
            raise ValueError(
                "host split tables live in remote processes: the "
                "predict_tree oracle cannot run here — serve through "
                "MultiHostRun.predict_score (per-party exports)")
        from .binning import apply_binning
        p = self.params
        gb = apply_binning(X_guest, self.guest_data, p.use_pallas)
        hb = [apply_binning(X, d, p.use_pallas)
              for X, d in zip(X_hosts, self.host_data)]
        n = gb.shape[0]
        if p.objective == "binary":
            score = np.full(n, self.init_score)
        else:
            score = np.tile(self.init_score, (n, 1))
        for tree, cls in zip(self.trees, self.tree_class):
            out = predict_tree(tree, gb, hb)
            if cls >= 0:
                score[:, cls] += out
            else:
                score += out
        return score

    def predict_proba(self, X_guest, X_hosts,
                      packed: bool = True) -> np.ndarray:
        from .loss import sigmoid, softmax
        s = self.predict_score(X_guest, X_hosts, packed=packed)
        return sigmoid(s) if self.params.objective == "binary" else softmax(s)


# ---------------------------------------------------------------------------
# the local plaintext baseline ("XGBoost" role in the paper's tables)
# ---------------------------------------------------------------------------

class LocalGBDT(VerticalBoosting):
    """Single-party plaintext GBDT with identical binning/gain/weights.

    Implemented as federated training with zero hosts and the plain cipher:
    the protocol collapses to local histogram split finding, which makes the
    parity claim ('lossless', paper Table 3) checkable in one code path.
    """

    def __init__(self, params: SBTParams):
        params = dataclasses.replace(params, cipher="plain", packing=True,
                                     compression=False, tree_mode="default")
        super().__init__(params)

    def fit(self, X: np.ndarray, y: np.ndarray):   # type: ignore[override]
        return super().fit(X, y, [])

    def predict_score(self, X, packed: bool = True) -> np.ndarray:  # type: ignore[override]
        return super().predict_score(X, [], packed=packed)

    def predict_proba(self, X, packed: bool = True) -> np.ndarray:  # type: ignore[override]
        from .loss import sigmoid, softmax
        s = self.predict_score(X, packed=packed)
        return sigmoid(s) if self.params.objective == "binary" else softmax(s)
