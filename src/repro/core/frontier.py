"""Device-resident, mesh-shardable frontier engine (DESIGN.md §7).

PR 1 collapsed per-node kernel launches into one layer-batched launch but
kept the layer state host-side: bins were re-masked and ciphertexts
re-padded every layer, parent histograms travelled through plain dicts with
``np.asarray``/``jnp.asarray`` conversions at each use, and the whole
pipeline was pinned to one device.  This module makes the layer state
device-resident for the lifetime of a tree:

* :class:`FrontierState` — a registered pytree holding the host's
  sparse-masked bin matrix (masked once), the width-padded ciphertext limb
  batch (padded once), and the cache of canonical parent histograms — all
  device arrays that persist across layers.
* :class:`CipherFrontier` — the per-(tree, host) manager: builds the state,
  assembles the per-layer ``node_slot`` vector, invokes the engine's layer
  accumulation (single-device or ``shard_map``-sharded over a
  (data, model) mesh — see ``kernels/histogram/ops.py``), and owns
  histogram-cache insertion and eviction.  It also tallies intra-party
  collective bytes into ``Stats``/``Channel``, kept separate from
  cross-party wire bytes.
* :class:`GuestFrontier` — the plaintext guest mirror (numpy float64; the
  guest never enters the cipher domain for its own features).

The Paillier oracle backend (python-int object arrays) flows through
:class:`CipherFrontier` too, with object-array state instead of device
arrays — the protocol shape is identical, only the arithmetic substrate
differs.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .binning import BinnedData


@dataclasses.dataclass
class FrontierState:
    """Device-resident per-(tree, host) layer state (a registered pytree).

    ``bins``: (n, n_f) int32, sparse cells already masked to -1.
    ``cts``:  (n, n_slots, width) int32 limbs, padded to the cipher's
              histogram width once per tree.
    ``hists``: {nid: canonical (n_f, n_b, n_slots, L) histogram} — parent
              histograms cached for subtraction, as device arrays.
    """
    bins: object
    cts: object
    hists: dict

    def tree_flatten(self):
        keys = tuple(sorted(self.hists))
        return ((self.bins, self.cts,
                 tuple(self.hists[k] for k in keys)), keys)

    @classmethod
    def tree_unflatten(cls, keys, leaves):
        bins, cts, hs = leaves
        return cls(bins=bins, cts=cts, hists=dict(zip(keys, hs)))


def _register():
    import jax
    jax.tree_util.register_pytree_node(
        FrontierState,
        lambda s: s.tree_flatten(),
        FrontierState.tree_unflatten)


_register()


class CtsBlocks:
    """Host-compact encrypted-GH batch for the out-of-core path (§13).

    Canonical radix-2^8 ciphertext limbs fit in uint8, so the full batch
    lives host-side at 1/4 the device int32 footprint and is re-uploaded to
    the device one fixed-size row block at a time by the streamed dispatch.
    Blocks arrive independently (chunked ``enc_gh`` frames, or the guest's
    own chunked encrypt loop); ``set_block`` is idempotent so a replayed
    frame sequence reassembles the identical batch.
    """

    def __init__(self, n_rows: int, n_slots: int, limbs: int, block: int):
        self.cts = np.zeros((n_rows, n_slots, limbs), np.uint8)
        self.block = int(block)
        self.n_rows = int(n_rows)
        self._have: set = set()

    @property
    def n_blocks(self) -> int:
        return -(-self.n_rows // self.block)

    @property
    def complete(self) -> bool:
        return len(self._have) == self.n_blocks

    def set_block(self, b: int, arr: np.ndarray) -> None:
        start = b * self.block
        self.cts[start: start + arr.shape[0]] = arr
        self._have.add(int(b))


class CipherFrontier:
    """Frontier manager for one (tree, host) pair on the cipher engine.

    Construction happens once per tree, right after the encrypted-GH
    broadcast: the selected-row view and the ciphertext batch move to the
    device (sharded per the GBDT rule table when the engine has a
    multi-device mesh) and stay there; per layer only the small
    ``node_slot`` vector crosses the host boundary.

    When the ciphertexts arrive as a :class:`CtsBlocks` the frontier runs
    in *stream* mode instead (DESIGN.md §13): nothing O(rows) is placed on
    device — bins stay at their compact host dtype, ciphertexts stay uint8
    host-side — and the engine accumulates each layer over fixed-size row
    blocks via :meth:`iter_stream_blocks`, so peak device memory is
    O(block · nodes).
    """

    def __init__(self, engine, data: BinnedData, cts, channel=None,
                 party: str = ""):
        self.engine = engine
        cipher = engine.cipher
        self.limb = cipher.backend == "limb"
        self.sparse = engine.sparse and data.zero_mask is not None
        self.data = data
        self.channel = channel
        self.party = party
        self.counts: dict = {}          # nid -> (n_f, n_b) int64, plaintext
        self.n_cts_placements = 0       # host->device placements of cts the
                                        # frontier had to perform itself (0
                                        # when ciphertexts arrive born-
                                        # sharded at histogram width, §8)
        self.stream_blocks = cts if isinstance(cts, CtsBlocks) else None
        if self.stream_blocks is not None:
            # out-of-core mode: no O(rows) device state, no full masked
            # int32 host mirror — blocks are cast/masked on the fly
            self.bins_np = data.bins
            self._n_rows_dev = data.bins.shape[0]
            self.state = FrontierState(bins=None, cts=None, hists={})
            self.cts_flat = None
            self.cts_obj = None
            return

        bins_np = data.bins.astype(np.int32)
        if self.sparse:
            bins_np = np.where(data.zero_mask, -1, bins_np)
        self.bins_np = bins_np          # host mirror for plaintext counts

        self._n_rows_dev = bins_np.shape[0]
        if self.limb:
            import jax
            import jax.numpy as jnp
            width = cipher.hist_width
            mesh = getattr(engine, "mesh", None)
            multi = mesh is not None and mesh.devices.size > 1
            n = bins_np.shape[0]
            pad = 0
            if multi:
                from ..parallel.sharding import data_pad, gbdt_sharding
                # pad the instance axis so it divides the data-axis extent
                # (device_put of a sharded layout requires divisibility; pad
                # rows carry bins = -1 / cts = 0 and never receive a slot)
                pad = data_pad(mesh, n)
            self._n_rows_dev = n + pad
            born = (isinstance(cts, jax.Array) and cts.ndim == 3
                    and cts.shape[0] == n + pad and cts.shape[-1] == width)
            if born and multi:
                born = cts.sharding.is_equivalent_to(
                    gbdt_sharding(mesh, "gh_cts"), cts.ndim)
            if born:
                # ciphertexts were born at histogram width with their
                # at-rest sharding (_encrypt_all, DESIGN.md §8): adopt the
                # buffers as-is — zero re-placements after encryption
                cts_wide = cts
            else:
                self.n_cts_placements += 1
                cts_j = jnp.asarray(cts)
                cts_wide = jnp.pad(cts_j, ((0, pad), (0, 0),
                                           (0, width - cts_j.shape[-1])))
                if multi:
                    cts_wide = jax.device_put(
                        cts_wide, gbdt_sharding(mesh, "gh_cts"))
            bins_dev = jnp.asarray(bins_np)
            if multi:
                if pad:
                    bins_dev = jnp.pad(bins_dev, ((0, pad), (0, 0)),
                                       constant_values=-1)
                # features replicate over "model" inside one party's
                # dispatch: every node shard needs every local feature
                bins_dev = jax.device_put(
                    bins_dev, gbdt_sharding(mesh, "bins",
                                            replicate=("model",)))
            self.state = FrontierState(bins=bins_dev, cts=cts_wide, hists={})
            # flattened (n, slots*width) view for the kernel dispatch,
            # materialized once per tree (sharding preserved: axis 0 = data)
            self.cts_flat = cts_wide.reshape(cts_wide.shape[0], -1)
            self.cts_obj = None
            stats = getattr(engine, "stats", None)
            if stats is not None:
                # monolithic mode keeps the whole int32 batch device-resident
                stats.peak_cts_bytes = max(stats.peak_cts_bytes,
                                           int(cts_wide.size) * 4)
        else:
            self.state = FrontierState(bins=None, cts=None, hists={})
            self.cts_flat = None
            self.cts_obj = np.asarray(cts, dtype=object)

    # -- cache ----------------------------------------------------------
    def __contains__(self, nid) -> bool:
        return nid in self.state.hists

    def hist(self, nid):
        return self.state.hists[nid]

    def count(self, nid):
        return self.counts[nid]

    def store(self, nid, hist, cnt) -> None:
        self.state.hists[nid] = hist
        self.counts[nid] = cnt

    def evict(self, nids) -> None:
        for nid in nids:
            self.state.hists.pop(nid, None)
            self.counts.pop(nid, None)

    def evict_except(self, keep) -> int:
        """Drop every cached histogram whose nid is not in ``keep`` (the
        subtract-parents scheduled for the next layer): nodes that became
        leaves must not pin device memory for the tree's remainder.
        Returns the cache size after eviction."""
        self.evict([nid for nid in list(self.state.hists) if nid not in keep])
        return len(self.state.hists)

    # -- out-of-core block iteration (DESIGN.md §13) --------------------
    def iter_stream_blocks(self, node_slot, with_cts: bool = True):
        """Yield ``(bins_blk, slot_blk, cts_wide_blk)`` fixed-size row
        blocks for the streamed layer dispatch: bins cast to int32 and
        sparse-masked on the fly, ciphertext limbs widened uint8 -> int32
        at the cipher's histogram width.  The last block is padded to the
        full block size with bins = -1 / slot = -1 / cts = 0 (clean
        masking, one compiled launch shape).  ``node_slot`` may be the 2-D
        member-slot matrix of a round-forest layer."""
        sb = self.stream_blocks
        block = sb.block
        n = self.data.n_instances
        node_slot = np.asarray(node_slot, np.int32)
        width = self.engine.cipher.hist_width
        n_slots = sb.cts.shape[1]
        for start in range(0, n, block):
            stop = min(start + block, n)
            r = stop - start
            bins_blk = np.full((block, self.data.n_features), -1, np.int32)
            bins_blk[:r] = self.data.bins[start:stop]
            if self.sparse:
                zm = self.data.zero_mask[start:stop]
                bins_blk[:r] = np.where(zm, -1, bins_blk[:r])
            slot_blk = np.full((block,) + node_slot.shape[1:], -1, np.int32)
            slot_blk[:r] = node_slot[start:stop]
            cts_blk = None
            if with_cts:
                cts_blk = np.zeros((block, n_slots, width), np.int32)
                cts_blk[:r, :, : sb.cts.shape[2]] = sb.cts[start:stop]
                stats = getattr(self.engine, "stats", None)
                if stats is not None:
                    stats.peak_cts_bytes = max(stats.peak_cts_bytes,
                                               cts_blk.nbytes)
            yield bins_blk, slot_blk, cts_blk

    # -- per-layer ------------------------------------------------------
    def layer_slots(self, node_rows: dict, direct: list) -> np.ndarray:
        """(n,) int32 direct-slot assignment aligned with the device bins
        (including mesh padding rows): row -> index into ``direct`` (-1 =
        row not in any direct-mode frontier node this layer)."""
        node_slot = np.full(self._n_rows_dev, -1, np.int32)
        for k, nid in enumerate(direct):
            node_slot[node_rows[nid]] = k
        return node_slot

    def layer_slots_forest(self, node_rows: dict, direct: list, k: int,
                           stride: int):
        """Member-batched slot assignment for one round-forest layer.

        ``direct`` holds global node ids ``gid = member * stride + nid``; a
        row can sit in at most one direct node *per member tree*, so the
        assignment is a (n, k) matrix of member-local slots.  Returns
        ``(slot_mat, member_local, n_local)`` where ``member_local`` maps
        each gid to ``(member, local_slot)`` (local slots are assigned in
        ``direct`` order within each member) and ``n_local`` is the widest
        member's direct count — the kernel's shared node extent.
        """
        slot_mat = np.full((self._n_rows_dev, k), -1, np.int32)
        member_local: dict = {}
        counts = [0] * k
        for gid in direct:
            m = int(gid) // stride
            member_local[gid] = (m, counts[m])
            slot_mat[node_rows[gid], m] = counts[m]
            counts[m] += 1
        return slot_mat, member_local, max(counts) if counts else 0

    def layer_histograms(self, node_rows: dict, direct: list,
                         subtract: list, forest: int = 0) -> dict:
        """All frontier histograms of one layer; caches the results for the
        next layer's subtraction.  Returns {nid: (hist, counts)}.

        ``forest > 0`` selects the round-forest dispatch: node ids in
        ``direct``/``subtract`` are global gids and the layer launch batches
        over (member tree, node)."""
        out = self.engine.layer_histograms(self, node_rows, direct, subtract,
                                           forest=forest)
        for nid, (h, c) in out.items():
            self.store(nid, h, c)
        return out

    # -- accounting -----------------------------------------------------
    def collective(self, kind: str, nbytes: int) -> None:
        """Tally an intra-party device collective (psum of lazy limb sums):
        separate ledger from cross-party wire bytes."""
        stats = getattr(self.engine, "stats", None)
        if stats is not None:
            stats.coll_bytes += int(nbytes)
            stats.n_collectives += 1
        if self.channel is not None:
            self.channel.collective(self.party, kind, nbytes)


class FrontierBuffer:
    """Dual-buffer holder for pipelined training (DESIGN.md §12).

    A pipelined guest ships tree t+1's ``enc_gh`` while tree t is still
    splitting.  The receiving party builds the next tree's
    :class:`CipherFrontier` (and whatever runtime wraps it) *eagerly* on
    arrival — ciphertexts land device-resident, encrypt/wire time hidden
    behind tree t's compute — but must not disturb the active tree's state.
    This buffer keeps the active entry and the staged next entry separate;
    ``activate`` swaps the staged entry in at the first protocol message
    that references the new tree.  Thread-safe under the broker reader
    thread: staging and activation touch disjoint slots.
    """

    def __init__(self):
        self.key = None          # active tree id
        self.value = None        # active frontier-bearing runtime
        self._staged: dict = {}  # tree id -> staged runtime

    def stage(self, key, value) -> None:
        self._staged[key] = value

    def staged(self, key) -> bool:
        return key in self._staged

    def peek(self, key):
        """The staged entry for ``key`` WITHOUT activating it — chunked
        ``enc_gh`` blocks (§13) keep assembling into a staged runtime
        while the previous tree is still active."""
        return self._staged[key]

    def activate(self, key):
        """Promote the staged entry for ``key`` to active and return it."""
        self.value = self._staged.pop(key)
        self.key = key
        return self.value

    def set_active(self, key, value) -> None:
        self.key = key
        self.value = value

    def clear(self) -> None:
        self.key = None
        self.value = None
        self._staged.clear()


class GuestFrontier:
    """Plaintext guest-side frontier state: per-tree histogram cache for
    the guest's own features (numpy; no cipher domain)."""

    def __init__(self, engine, data: BinnedData, g, h):
        self.engine = engine
        self.data = data
        self.g = g
        self.h = h
        self.cache: dict = {}

    def __contains__(self, nid) -> bool:
        return nid in self.cache

    def evict(self, nids) -> None:
        for nid in nids:
            self.cache.pop(nid, None)

    def evict_except(self, keep) -> int:
        """See :meth:`CipherFrontier.evict_except`."""
        self.evict([nid for nid in list(self.cache) if nid not in keep])
        return len(self.cache)

    def layer_histograms(self, node_rows: dict, direct: list,
                         subtract: list) -> dict:
        hists = self.engine.layer_histograms(self.data, self.g, self.h,
                                             node_rows, direct, subtract,
                                             self.cache)
        self.cache.update(hists)
        return hists

    def cumsum(self, hist):
        return self.engine.cumsum(hist)
