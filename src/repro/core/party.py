"""Party roles and the communication channel (simulation with accounting).

The protocol runs in-process, but every cross-party transfer goes through
:class:`Channel.send`, which records (src, dst, tag, wire_bytes, n_msgs).
Wire bytes are counted at *protocol* fidelity, not storage fidelity: a
ciphertext costs ceil(modulus_bits/8) bytes (2x for Paillier, which lives in
Z_{n^2}), regardless of our int32-per-limb in-memory layout.  The ledger is
what the cost-model benchmark (paper eqs 10/16) reads.

HE-operation counters (encrypt / decrypt / hom-add / hom-scalar-mul) live in
:class:`Stats` and are incremented at call sites with exact analytic counts,
mirroring the paper's cost accounting (eqs 8-9 / 14-15).
"""

from __future__ import annotations

import collections
import dataclasses


def ct_wire_bytes(cipher) -> int:
    """Bytes one ciphertext occupies on the wire."""
    if cipher.backend == "limb":
        return cipher.Ln            # radix-2**8: one byte per limb
    return 2 * ((cipher.n.bit_length() + 7) // 8)   # Paillier: Z_{n^2}


@dataclasses.dataclass
class Stats:
    n_encrypt: int = 0
    n_decrypt: int = 0
    n_hom_add: int = 0          # ciphertext-ciphertext additions
    n_hom_scalar: int = 0       # scalar/shift multiplications (compress)
    n_split_infos: int = 0      # split-info stats produced (pre-compress)
    n_packages: int = 0         # ciphertexts actually decrypted/transferred
    n_hist_launches: int = 0    # histogram accumulation kernel launches
    n_split_roundtrips: int = 0  # guest<->host split_infos exchanges
    tree_seconds: list = dataclasses.field(default_factory=list)

    def as_dict(self):
        d = dataclasses.asdict(self)
        d["tree_seconds"] = list(self.tree_seconds)
        return d


class Channel:
    def __init__(self):
        self.ledger = []
        self.totals = collections.Counter()
        self.msgs = collections.Counter()

    def send(self, src: str, dst: str, tag: str, payload, nbytes: int):
        self.ledger.append((src, dst, tag, int(nbytes)))
        self.totals[tag] += int(nbytes)
        self.msgs[tag] += 1
        return payload

    @property
    def total_bytes(self) -> int:
        return sum(self.totals.values())

    def summary(self) -> dict:
        return {tag: {"bytes": self.totals[tag], "msgs": self.msgs[tag]}
                for tag in sorted(self.totals)}
