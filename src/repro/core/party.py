"""Party roles and the communication channel (simulation with accounting).

The protocol runs in-process, but every cross-party transfer goes through
:class:`Channel.send`, which records (src, dst, tag, wire_bytes, n_msgs).
Wire bytes are counted at *protocol* fidelity, not storage fidelity: a
ciphertext costs ceil(modulus_bits/8) bytes (2x for Paillier, which lives in
Z_{n^2}), regardless of our int32-per-limb in-memory layout.  The ledger is
what the cost-model benchmark (paper eqs 10/16) reads.

HE-operation counters (encrypt / decrypt / hom-add / hom-scalar-mul) live in
:class:`Stats` and are incremented at call sites with exact analytic counts,
mirroring the paper's cost accounting (eqs 8-9 / 14-15).
"""

from __future__ import annotations

import collections
import dataclasses
import math
import threading

from ..obs.metrics import MetricsRegistry
from ..obs.trace import NULL_TRACER


class PartyUnavailable(RuntimeError):
    """A remote party failed to answer within its serving deadline.

    Raised per *batch* by the serving path (never a hang, never a
    partial-bits answer): the caller may retry the batch once the party
    reconnects.  Defined here — not in ``runtime/`` — because the serving
    engine must be able to raise/catch it without importing the transport
    layer (``serving`` has no runtime dependency)."""

    def __init__(self, party: str, reason: str = ""):
        self.party = party
        super().__init__(f"party {party} unavailable"
                         + (f": {reason}" if reason else ""))


def ct_wire_bytes(cipher) -> int:
    """Bytes one ciphertext occupies on the wire."""
    if cipher.backend == "limb":
        return cipher.Ln            # radix-2**8: one byte per limb
    return 2 * ((cipher.n.bit_length() + 7) // 8)   # Paillier: Z_{n^2}


@dataclasses.dataclass
class Stats:
    n_encrypt: int = 0
    n_decrypt: int = 0
    n_hom_add: int = 0          # ciphertext-ciphertext additions
    n_hom_scalar: int = 0       # scalar/shift multiplications (compress)
    n_split_infos: int = 0      # split-info stats produced (pre-compress)
    n_packages: int = 0         # ciphertexts actually decrypted/transferred
    n_hist_launches: int = 0    # histogram accumulation kernel launches
    n_split_roundtrips: int = 0  # guest<->host split_infos exchanges
    n_collectives: int = 0      # intra-party device collectives (psum)
    coll_bytes: int = 0         # analytic bytes moved by those collectives
    n_cts_placements: int = 0   # host->device ciphertext re-placements the
                                # frontier performed (0 = born sharded, §8)
    peak_hist_cache: int = 0    # max cached parent hists after any eviction
    peak_frontier: int = 0      # max frontier width (layer node count)
    peak_cts_bytes: int = 0     # max device-resident ciphertext-batch bytes:
                                # O(rows) monolithic, O(block) streamed
    peak_block_bytes: int = 0   # max device bytes uploaded per histogram
                                # launch (bins + slots + cts operands)
    n_predict_batches: int = 0  # serving-engine batches served
    n_predict_roundtrips: int = 0   # host predict_bits exchanges: exactly
                                    # ONE per (host, batch) in the
                                    # round-batched serving protocol
    # Timing instruments (formerly float/list dataclass fields) live in a
    # MetricsRegistry created per instance in __post_init__ and are
    # reattached as generated properties below, so every existing call
    # site (`stats.encrypt_seconds += dt`, `stats.tree_seconds.append`,
    # `del stats.tree_seconds[t:]`) keeps its exact behavior.  They are
    # NOT dataclass fields: the registry holds locks, which neither
    # `dataclasses.asdict` (deepcopy) nor pickling would survive.
    #
    # _TIMERS (counter-backed floats):
    #   encrypt_seconds     guest encrypt wall time (blocked once/tree)
    #   prefetch_seconds    encrypt+ship wall time hidden behind other
    #                       useful work by the pipelined prefetch pump
    #   guest_hist_seconds  guest plaintext candidate time overlapped
    #                       with in-flight host cipher work
    #   host_dispatch_seconds  async launch of the host pipeline
    #   host_wait_seconds   blocking decrypt+decode tail
    #   predict_seconds     serving engine wall time (bins->score)
    # _SERIES (list-backed):
    #   tree_seconds        per-tree wall time
    #   layer_overlap       per layer: guest-window / candidate-phase
    #                       seconds (UPPER bound on true concurrency: the
    #                       host pipeline may drain before the window ends)
    #   wire_overlap        per tree: fraction of the encrypt+ship window
    #                       that ran concurrently with other work
    _TIMERS = ("encrypt_seconds", "prefetch_seconds", "guest_hist_seconds",
               "host_dispatch_seconds", "host_wait_seconds",
               "predict_seconds")
    _SERIES = ("tree_seconds", "layer_overlap", "wire_overlap")

    def __post_init__(self):
        # plain instance attributes, invisible to dataclasses.asdict
        self.metrics = MetricsRegistry()
        self.unmerged: dict = {}
        for name in self._TIMERS:
            self.metrics.counter(name)
        for name in self._SERIES:
            self.metrics.series(name)

    def as_dict(self):
        d = dataclasses.asdict(self)
        for name in self._TIMERS:
            d[name] = self.metrics.counter(name).value
        for name in self._SERIES:
            d[name] = list(self.metrics.series(name).data)
        return d

    # gauge fields are maxima, not counters: merging across parties must
    # take the max or a 2-host run would report 3x the real peak
    _GAUGES = ("peak_hist_cache", "peak_frontier", "peak_cts_bytes",
               "peak_block_bytes")

    def merge_counts(self, other: dict) -> None:
        """Fold another party's ``as_dict()`` into this one: numeric
        counters add, gauges max, per-tree/per-layer lists concatenate.
        Under the multi-host runtime each process tallies its own side of
        the work; merging reconstructs the single shared-Stats view of an
        in-process run (``MultiHostRun.merged_stats``).

        Version-skew safe: a key this build does not know (a newer peer's
        counter) lands in :attr:`unmerged` — numerics add, lists concat —
        instead of being silently dropped, so a rolling upgrade never
        loses accounting."""
        for key, val in other.items():
            cur = getattr(self, key, None)
            if isinstance(cur, list):
                cur.extend(val)
            elif isinstance(cur, (int, float)) and not isinstance(cur, bool):
                merged = max(cur, val) if key in self._GAUGES else cur + val
                setattr(self, key, type(cur)(merged))
            else:
                prev = self.unmerged.get(key)
                if isinstance(prev, list) and isinstance(val, list):
                    self.unmerged[key] = prev + list(val)
                elif (isinstance(prev, (int, float))
                        and isinstance(val, (int, float))
                        and not isinstance(prev, bool)
                        and not isinstance(val, bool)):
                    self.unmerged[key] = prev + val
                else:
                    self.unmerged[key] = (list(val) if isinstance(val, list)
                                          else val)

    @property
    def overlap_fraction(self) -> float:
        """Mean per-layer fraction of candidate wall time spent in the
        guest's plaintext-histogram window while the host cipher pipeline
        was dispatched (upper bound on true concurrency, see above).

        Plain-cipher runs record no cipher work (``encrypt_seconds == 0``)
        and may log degenerate per-layer entries; non-finite entries are
        dropped and an empty list clamps to 0.0 so the property never
        returns NaN or raises ZeroDivisionError."""
        vals = [v for v in self.layer_overlap if math.isfinite(v)]
        if not vals:
            return 0.0
        return float(sum(vals)) / len(vals)

    @property
    def wire_overlap_frac(self) -> float:
        """Fraction of total encrypt+ship wall time hidden behind other
        work by the pipelined prefetch pump (PR 3's ``overlap_fraction``
        analogue for the wire).  Clamped to [0, 1]; 0.0 when no encrypt
        time was recorded at all (plain runs), never NaN."""
        denom = float(self.encrypt_seconds)
        if not math.isfinite(denom) or denom <= 0.0:
            return 0.0
        frac = float(self.prefetch_seconds) / denom
        return max(0.0, min(1.0, frac))


def _timer_property(name: str) -> property:
    def fget(self):
        return self.metrics.counter(name).value

    def fset(self, v):           # += and merge_counts setattr both land here
        self.metrics.counter(name).set(float(v))

    return property(fget, fset)


def _series_property(name: str) -> property:
    def fget(self):              # the LIVE list: append/extend/del work
        return self.metrics.series(name).data

    def fset(self, v):
        data = self.metrics.series(name).data
        data[:] = list(v)

    return property(fget, fset)


for _name in Stats._TIMERS:
    setattr(Stats, _name, _timer_property(_name))
for _name in Stats._SERIES:
    setattr(Stats, _name, _series_property(_name))
del _name


class Channel:
    """Cross-party wire ledger plus a *separate* intra-party collective
    ledger: device collectives (the frontier engine's lazy-limb psum over
    the "data" mesh axis, DESIGN.md §7) never cross a party boundary, so
    they must not inflate the protocol's wire-byte accounting — but they
    are real interconnect traffic worth reporting for the scaling story.

    Every ``send``/``recv`` tag must be a registered wire tag
    (``analysis/schema.py``, statically checked by
    ``python -m repro.analysis``); the transport layer additionally
    validates payload shapes at ship time when conformance mode is on
    (``analysis.schema.set_conformance`` / ``REPRO_WIRE_CONFORMANCE=1``).
    ``send`` payloads are a declared taint sink: anything secret
    (plaintext g/h, labels, private-key material) must pass a
    ``@declassifies`` sanitizer before reaching one."""

    def __init__(self):
        self.ledger = []
        self.totals = collections.Counter()
        self.msgs = collections.Counter()
        self.coll_ledger = []
        self.coll_totals = collections.Counter()
        self.coll_msgs = collections.Counter()
        # the pipelined encrypt pump (core/tree.py) records its enc_gh
        # send from a worker thread while the training thread records the
        # layer protocol: Counter += is read-modify-write, so ledger
        # mutation takes this lock (uncontended in sequential runs)
        self._lock = threading.Lock()
        # per-channel tracer: every party owns its own Channel, so wire
        # events attribute correctly even in single-process loopback mode
        self.tracer = NULL_TRACER

    def send(self, src: str, dst: str, tag: str, payload, nbytes: int):
        with self._lock:
            self.ledger.append((src, dst, tag, int(nbytes)))
            self.totals[tag] += int(nbytes)
            self.msgs[tag] += 1
        if self.tracer.enabled:
            # the audited category: one instant per ledger append, with
            # the exact nbytes the ledger recorded — per party, wire-event
            # byte sums MUST equal the converged per-tag ledger totals
            self.tracer.instant(tag, cat="wire", src=src, dst=dst,
                                tag=tag, nbytes=int(nbytes))
        return payload

    def collective(self, party: str, kind: str, nbytes: int) -> None:
        """Record an intra-party device collective (analytic byte count)."""
        with self._lock:
            self.coll_ledger.append((party, kind, int(nbytes)))
            self.coll_totals[kind] += int(nbytes)
            self.coll_msgs[kind] += 1

    def snapshot(self) -> dict:
        """Accounting state at a resume boundary (tree/round edge).

        Together with :meth:`restore` this is what lets a faulted run
        replay a boosting round and still end with a ledger identical to
        the fault-free oracle: the aborted attempt's entries are rolled
        back, the replay records them fresh (duplicates counted once)."""
        return {"n_ledger": len(self.ledger),
                "totals": self.totals.copy(),
                "msgs": self.msgs.copy(),
                "n_coll": len(self.coll_ledger),
                "coll_totals": self.coll_totals.copy(),
                "coll_msgs": self.coll_msgs.copy()}

    def restore(self, snap: dict) -> None:
        """Roll the accounting back to a :meth:`snapshot`."""
        del self.ledger[snap["n_ledger"]:]
        self.totals = snap["totals"].copy()
        self.msgs = snap["msgs"].copy()
        del self.coll_ledger[snap["n_coll"]:]
        self.coll_totals = snap["coll_totals"].copy()
        self.coll_msgs = snap["coll_msgs"].copy()

    def reset_accounting(self) -> None:
        """Zero every ledger/counter.  A long-lived channel (the
        multi-host transport) spans model lifetimes; per-fit accounting
        needs a clean slate or refits double-count."""
        self.ledger.clear()
        self.totals.clear()
        self.msgs.clear()
        self.coll_ledger.clear()
        self.coll_totals.clear()
        self.coll_msgs.clear()

    @property
    def total_bytes(self) -> int:
        return sum(self.totals.values())

    @property
    def total_collective_bytes(self) -> int:
        return sum(self.coll_totals.values())

    def summary(self) -> dict:
        return {tag: {"bytes": self.totals[tag], "msgs": self.msgs[tag]}
                for tag in sorted(self.totals)}

    def collective_summary(self) -> dict:
        return {kind: {"bytes": self.coll_totals[kind],
                       "msgs": self.coll_msgs[kind]}
                for kind in sorted(self.coll_totals)}
