"""Multi-class GH packing for SecureBoost-MO (paper §5.3, Algorithms 7 & 8).

For an l-class task, per-instance gradient/hessian *vectors* are packed
``eta_c = floor(iota / b_gh)`` classes per ciphertext, needing
``n_k = ceil(l / eta_c)`` ciphertexts per instance (eqs 21-22).  Within a
ciphertext, earlier classes occupy more significant slots (Algorithm 7 shifts
left before each append); recovery therefore reads slots LSB-first and
reverses (the paper's Algorithm 8 leaves this implicit).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import encoding
from .he import limbs


@dataclasses.dataclass(frozen=True)
class MOPackingPlan:
    base: encoding.PackingPlan     # shared b_g / b_h / r / g_off across classes
    n_classes: int

    @property
    def eta_c(self) -> int:
        """Classes per ciphertext (eq 21)."""
        return max(1, self.base.plaintext_bits // self.base.b_gh)

    @property
    def n_k(self) -> int:
        """Ciphertexts per instance (eq 22)."""
        return -(-self.n_classes // self.eta_c)

    def slots_in_ct(self, ct_idx: int) -> int:
        used = min(self.n_classes - ct_idx * self.eta_c, self.eta_c)
        return used

    @property
    def limb_width(self) -> int:
        return limbs.num_limbs_for_bits(self.eta_c * self.base.b_gh)


def plan_mo_packing(G: np.ndarray, H: np.ndarray, n_capacity: int,
                    plaintext_bits: int,
                    r: int = encoding.DEFAULT_PRECISION) -> MOPackingPlan:
    """G, H: (n, l) per-class gradients/hessians."""
    base = encoding.plan_packing(np.asarray(G).ravel(), np.asarray(H).ravel(),
                                 n_capacity, plaintext_bits, r)
    return MOPackingPlan(base=base, n_classes=int(np.asarray(G).shape[1]))


def pack_gh_mo(G: np.ndarray, H: np.ndarray, plan: MOPackingPlan) -> np.ndarray:
    """(n, l) G/H -> (n, n_k, Lp) plaintext limbs (Algorithm 7)."""
    n, l = np.asarray(G).shape
    base = plan.base
    gh = encoding.pack_gh(np.asarray(G).ravel(), np.asarray(H).ravel(),
                          base).reshape(n, l, -1)        # (n, l, Lgh)
    Lp = plan.limb_width
    out = np.zeros((n, plan.n_k, Lp), dtype=np.int64)
    for j in range(l):
        ct_idx, slot = divmod(j, plan.eta_c)
        # Algorithm 7: e <<= b_gh then e += gh_j, so the FIRST class of a
        # ciphertext ends up most significant.  With `used` slots in this
        # ciphertext, class at slot s sits at bit offset (used-1-s)*b_gh.
        used = plan.slots_in_ct(ct_idx)
        off = (used - 1 - slot) * base.b_gh
        shifted = _np_shift_left_bits(gh[:, j, :], off, Lp)
        out[:, ct_idx, :] += shifted
    while np.any(out > limbs.LIMB_MASK):
        carry = out >> limbs.RADIX_BITS
        out &= limbs.LIMB_MASK
        out[..., 1:] += carry[..., :-1]
    return out.astype(np.int32)


def unpack_gh_mo_ints(xs, plan: MOPackingPlan, sample_count: int) -> tuple:
    """Recover per-class (sum g, sum h) from a list of n_k decrypted ints
    (Algorithm 8, with explicit slot-order handling)."""
    base = plan.base
    gs, hs = [], []
    for ct_idx, e in enumerate(xs):
        e = int(e)
        used = plan.slots_in_ct(ct_idx)
        slot_vals = []
        for _ in range(used):
            slot_vals.append(e & ((1 << base.b_gh) - 1))
            e >>= base.b_gh
        for gh in reversed(slot_vals):     # restore class order
            g, h = encoding.unpack_gh_int(gh, base, sample_count)
            gs.append(g)
            hs.append(h)
    return (np.asarray(gs[: plan.n_classes], np.float64),
            np.asarray(hs[: plan.n_classes], np.float64))


def _np_shift_left_bits(a: np.ndarray, k: int, out_L: int) -> np.ndarray:
    """Non-negative limb shift-left by k bits into int64 limbs (lazy carry)."""
    limb_shift, bit_shift = divmod(k, limbs.RADIX_BITS)
    L = a.shape[-1]
    x = np.zeros(a.shape[:-1] + (out_L,), dtype=np.int64)
    take = min(L, out_L - limb_shift)
    if take > 0:
        x[..., limb_shift:limb_shift + take] = a[..., :take]
    if bit_shift:
        x <<= bit_shift        # values < 2**16: caller carry-fixes
    return x
