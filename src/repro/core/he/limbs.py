"""Vectorized big-unsigned-integer arithmetic over a trailing limb axis.

This is the numeric substrate for the TPU port of SecureBoost+'s ciphertext
arithmetic.  A big integer is a little-endian vector of radix-2**8 limbs
stored as int32 (canonical form: every limb in [0, 256)).  All operations are
batched over arbitrary leading axes and are jit/pallas friendly:

  * radix 2**8 keeps every intermediate product/sum far below 2**31, so
    schoolbook multiplication lowers to an exact int32 (or fp32) matmul on
    the MXU, and histogram accumulation can defer carries ("lazy carry").
  * multiplication by a *fixed* constant (encryption key, Barrett mu, the
    modulus, 2**b_gh for cipher compressing) is a matmul with the constant's
    Toeplitz limb matrix -- see :func:`toeplitz` / :func:`mul_fixed`.

Host-side helpers (``from_pyints`` / ``to_pyints``) convert to python ints for
tests and key generation.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

RADIX_BITS = 8
RADIX = 1 << RADIX_BITS
LIMB_MASK = RADIX - 1


def num_limbs_for_bits(bits: int) -> int:
    return -(-bits // RADIX_BITS)


# ---------------------------------------------------------------------------
# host-side conversion helpers (numpy / python ints)
# ---------------------------------------------------------------------------

def from_pyints(xs, L: int) -> np.ndarray:
    """Pack an iterable of non-negative python ints into (len(xs), L) limbs."""
    out = np.zeros((len(xs), L), dtype=np.int32)
    for i, x in enumerate(xs):
        if x < 0:
            raise ValueError("limbs are unsigned; got negative value")
        j = 0
        while x and j < L:
            out[i, j] = x & LIMB_MASK
            x >>= RADIX_BITS
            j += 1
        if x:
            raise ValueError(f"value does not fit in {L} limbs")
    return out


def to_pyints(arr) -> list:
    """Inverse of :func:`from_pyints`; accepts any (..., L) canonical array."""
    a = np.asarray(arr, dtype=object)
    flat = a.reshape(-1, a.shape[-1])
    out = []
    for row in flat:
        x = 0
        for j in range(len(row) - 1, -1, -1):
            x = (x << RADIX_BITS) | int(row[j])
        out.append(x)
    return out


def to_pyint(arr) -> int:
    (x,) = to_pyints(np.asarray(arr).reshape(1, -1))
    return x


# ---------------------------------------------------------------------------
# carries / borrows
# ---------------------------------------------------------------------------

def _shift_up(x):
    """Move limb i to position i+1 (drop the overflowing top limb)."""
    pad = [(0, 0)] * (x.ndim - 1) + [(1, 0)]
    return jnp.pad(x, pad)[..., :-1]


@jax.jit
def carry_fix(x):
    """Propagate carries until canonical.  Limbs may be mixed-sign as long
    as the represented value is >= 0 (lazy histogram subtraction produces
    ``parent - child`` limb vectors before canonicalization): for int32
    two's complement, ``v == RADIX * (v >> RADIX_BITS) + (v & LIMB_MASK)``
    holds for negative limbs too (arithmetic shift + non-negative masked
    digit), so the same signed-digit normalization converges.

    Overflow past the last limb is dropped (arithmetic mod RADIX**L); size
    limb counts so this never happens in practice.  Jitted at module level
    so eager protocol code pays tracing once per shape, not per call.
    """
    def cond(v):
        return jnp.any((v > LIMB_MASK) | (v < 0))

    def body(v):
        return (v & LIMB_MASK) + _shift_up(v >> RADIX_BITS)

    return jax.lax.while_loop(cond, body, x)


@jax.jit
def borrow_fix(x):
    """Resolve negative limbs (borrow propagation).  Result must be >= 0."""
    def cond(v):
        return jnp.any(v < 0)

    def body(v):
        neg = (v < 0).astype(v.dtype)
        return v + neg * RADIX - _shift_up(neg)

    return jax.lax.while_loop(cond, body, x)


# ---------------------------------------------------------------------------
# basic arithmetic (canonical inputs unless noted)
# ---------------------------------------------------------------------------

def pad_limbs(x, width: int):
    """Zero-pad the trailing limb axis up to ``width`` (no-op if wider)."""
    L = x.shape[-1]
    if L >= width:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, width - L)])


def add(a, b):
    return carry_fix(a + b)


def sub(a, b):
    """a - b, assuming a >= b elementwise as big integers."""
    return borrow_fix(a - b)


def compare(a, b):
    """Elementwise big-int compare: returns -1 / 0 / +1 over leading axes."""
    d = jnp.sign(a - b)          # per-limb sign
    nz = d != 0
    # index of most significant nonzero limb
    L = a.shape[-1]
    rev = jnp.flip(nz, axis=-1)
    first = jnp.argmax(rev, axis=-1)          # 0 if none
    idx = L - 1 - first
    any_nz = jnp.any(nz, axis=-1)
    picked = jnp.take_along_axis(d, idx[..., None], axis=-1)[..., 0]
    return jnp.where(any_nz, picked, 0)


def geq(a, b):
    return compare(a, b) >= 0


def cond_sub(a, n):
    """a mod n given a < 2n (single conditional subtract)."""
    take = geq(a, n)[..., None]
    return jnp.where(take, sub(a, jnp.broadcast_to(n, a.shape)), a)


def is_zero(a):
    return jnp.all(a == 0, axis=-1)


# ---------------------------------------------------------------------------
# shifts and masks (static shift amounts)
# ---------------------------------------------------------------------------

def shift_left_bits(a, k: int, out_L: int | None = None):
    limb_shift, bit_shift = divmod(k, RADIX_BITS)
    L = a.shape[-1]
    out_L = out_L if out_L is not None else L + limb_shift + 1
    pad = [(0, 0)] * (a.ndim - 1) + [(limb_shift, max(0, out_L - L - limb_shift))]
    x = jnp.pad(a, pad)[..., :out_L]
    if bit_shift:
        x = carry_fix(x << bit_shift)
    return x


def shift_right_bits(a, k: int):
    limb_shift, bit_shift = divmod(k, RADIX_BITS)
    L = a.shape[-1]
    pad = [(0, 0)] * (a.ndim - 1) + [(0, limb_shift)]
    x = jnp.pad(a, pad)[..., limb_shift:]
    if bit_shift:
        nxt = jnp.pad(x, [(0, 0)] * (a.ndim - 1) + [(0, 1)])[..., 1:]
        x = (x >> bit_shift) | ((nxt << (RADIX_BITS - bit_shift)) & LIMB_MASK)
    return x


def shift_right_limbs(a, k: int):
    pad = [(0, 0)] * (a.ndim - 1) + [(0, k)]
    return jnp.pad(a, pad)[..., k:]


def mask_bits(a, nbits: int):
    """a mod 2**nbits (keeps the limb count)."""
    full, part = divmod(nbits, RADIX_BITS)
    L = a.shape[-1]
    idx = jnp.arange(L)
    keep = (idx < full).astype(a.dtype)
    out = a * keep
    if part and full < L:
        out = out.at[..., full].set(a[..., full] & ((1 << part) - 1))
    return out


# ---------------------------------------------------------------------------
# multiplication
# ---------------------------------------------------------------------------

def toeplitz(b_limbs: np.ndarray, La: int) -> np.ndarray:
    """(La, La+Lb) matrix T with T[i, i+j] = b[j]; then a @ T == a*b limbs."""
    b = np.asarray(b_limbs, dtype=np.int32).reshape(-1)
    Lb = b.shape[0]
    T = np.zeros((La, La + Lb), dtype=np.int32)
    for i in range(La):
        T[i, i:i + Lb] = b
    return T


def mul_fixed(a, T):
    """Multiply canonical a (..., La) by the fixed big int behind Toeplitz T."""
    y = jnp.einsum("...i,ij->...j", a, T.astype(jnp.int32))
    return carry_fix(y)


def mul(a, b):
    """Generic batched schoolbook multiply: (..., La) x (..., Lb) -> (..., La+Lb)."""
    La, Lb = a.shape[-1], b.shape[-1]
    batch = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1])
    af = jnp.broadcast_to(a, batch + (La,)).reshape(-1, La)
    bf = jnp.broadcast_to(b, batch + (Lb,)).reshape(-1, Lb)

    def one(x, y):
        # convolve lowers via fp32; exact since coeffs < 2**24 for radix 2**8
        return jnp.convolve(x.astype(jnp.float32), y.astype(jnp.float32))

    out = jax.vmap(one)(af, bf).astype(jnp.int32)   # (N, La+Lb-1)
    out = jnp.pad(out, ((0, 0), (0, 1)))
    return carry_fix(out.reshape(batch + (La + Lb,)))


# ---------------------------------------------------------------------------
# Barrett reduction by a fixed modulus
# ---------------------------------------------------------------------------

class BarrettCtx(NamedTuple):
    """Precomputed tables for reduction mod a fixed n (Ln limbs).

    Valid for inputs x < RADIX**(2*Ln).  T_mu / T_n are Toeplitz matrices of
    mu = floor(RADIX**(2Ln) / n) and n, sized for the operand widths used in
    :func:`barrett_reduce`.
    """
    n: jnp.ndarray          # (Ln,) canonical limbs of the modulus
    T_mu: jnp.ndarray       # (Ln+2, 2Ln+3) toeplitz of mu (mu has <= Ln+1 limbs)
    T_n: jnp.ndarray        # (Ln+2, 2Ln+3) toeplitz of n
    Ln: int


def barrett_precompute(n_int: int, Ln: int | None = None) -> BarrettCtx:
    if Ln is None:
        Ln = num_limbs_for_bits(n_int.bit_length())
    mu = (RADIX ** (2 * Ln)) // n_int
    mu_l = from_pyints([mu], Ln + 1)[0]
    n_l = from_pyints([n_int], Ln)[0]
    T_mu = toeplitz(mu_l, Ln + 2)           # q1 has <= Ln+1 limbs; pad to Ln+2
    T_n = toeplitz(np.pad(n_l, (0, 1)), Ln + 2)
    return BarrettCtx(
        n=jnp.asarray(n_l), T_mu=jnp.asarray(T_mu), T_n=jnp.asarray(T_n), Ln=Ln
    )


def barrett_reduce(x, ctx: BarrettCtx):
    """x mod n for canonical x with x < RADIX**(2*Ln).  Returns (..., Ln)."""
    Ln = ctx.Ln
    L = x.shape[-1]
    if L < 2 * Ln:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, 2 * Ln - L)])
    elif L > 2 * Ln:
        raise ValueError(f"operand too wide for Barrett: {L} > {2 * Ln}")
    q1 = shift_right_limbs(x, Ln - 1)[..., : Ln + 2]      # floor(x / b^(Ln-1))
    q2 = mul_fixed(q1, ctx.T_mu)                           # q1 * mu
    q3 = shift_right_limbs(q2, Ln + 1)[..., : Ln + 2]      # floor(q2 / b^(Ln+1))
    # r = (x - q3*n) mod b^(Ln+1); classic Barrett guarantees 0 <= r < 3n.
    r1 = mask_bits(x[..., : Ln + 2], (Ln + 1) * RADIX_BITS)
    q3n = mask_bits(mul_fixed(q3, ctx.T_n)[..., : Ln + 2],
                    (Ln + 1) * RADIX_BITS)
    # compute t = r1 + b^(Ln+1) - q3n  (always >= 0), then drop the top limb
    # to realize the mod-b^(Ln+1) wrap.
    t = r1 - q3n
    t = t.at[..., Ln + 1].add(1)
    t = borrow_fix(t)
    r = t.at[..., Ln + 1].set(0)
    n_wide = jnp.pad(ctx.n, (0, 2))
    r = cond_sub(r, n_wide)
    r = cond_sub(r, n_wide)
    return r[..., :Ln]


def mod_mul_fixed(a, T_b, ctx: BarrettCtx):
    """(a * b) mod n for canonical a < n and fixed b < n (Toeplitz T_b)."""
    prod = mul_fixed(a, T_b)[..., : 2 * ctx.Ln]
    return barrett_reduce(prod, ctx)
