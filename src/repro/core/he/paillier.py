"""Real Paillier cryptosystem over python ints (correctness/security oracle).

Reproduces the paper's Paillier column functionally: additively homomorphic,
semantically secure, with homomorphic add = modmul in Z_{n^2} and scalar
multiply = modexp.  This backend is deliberately NOT JAX-traceable -- per
DESIGN.md §3, Paillier's modexp-per-op does not map onto the MXU; it exists
to validate the protocol bit-for-bit and to measure the Paillier cost column
of the paper's experiments.

Ciphertext batches are numpy object arrays of python ints.
"""

from __future__ import annotations

import math
import random as _random

import numpy as np

from ...analysis.registry import declassifies

_SMALL_PRIMES = [3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59,
                 61, 67, 71, 73, 79, 83, 89, 97]


def _is_probable_prime(n: int, rng: _random.Random, rounds: int = 24) -> bool:
    if n < 2:
        return False
    for p in _SMALL_PRIMES:
        if n % p == 0:
            return n == p
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for _ in range(rounds):
        a = rng.randrange(2, n - 1)
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = (x * x) % n
            if x == n - 1:
                break
        else:
            return False
    return True


def _random_prime(bits: int, rng: _random.Random) -> int:
    while True:
        p = rng.getrandbits(bits) | (1 << (bits - 1)) | 1
        if _is_probable_prime(p, rng):
            return p


class PaillierCipher:
    backend = "pyobj"
    name = "paillier"

    def __init__(self, n: int, p: int, q: int, seed: int | None = None):
        self.n = n
        self.n2 = n * n
        self.g = n + 1
        self._lam = (p - 1) * (q - 1) // math.gcd(p - 1, q - 1)
        self._mu = pow(self._l_func(pow(self.g, self._lam, self.n2)), -1, n)
        self.plaintext_bits = n.bit_length() - 1
        self._rng = _random.Random(seed)

    @classmethod
    def keygen(cls, key_bits: int = 512, seed: int | None = None) -> "PaillierCipher":
        rng = _random.Random(seed)
        while True:
            p = _random_prime(key_bits // 2, rng)
            q = _random_prime(key_bits // 2, rng)
            if p != q and math.gcd(p * q, (p - 1) * (q - 1)) == 1:
                return cls(p * q, p, q, seed=seed)

    def _l_func(self, x: int) -> int:
        return (x - 1) // self.n

    # -- guest ---------------------------------------------------------
    @declassifies("Paillier encryption: semantically secure ciphertexts")
    def encrypt_ints(self, xs) -> np.ndarray:
        # materialize once: len(list(xs)) on a generator would exhaust it,
        # leaving the enumerate below a None-filled object array
        xs = list(xs)
        out = np.empty(len(xs), dtype=object)
        for i, m in enumerate(xs):
            if not 0 <= m < self.n:
                raise ValueError("plaintext out of range")
            r = self._rng.randrange(1, self.n)
            while math.gcd(r, self.n) != 1:
                r = self._rng.randrange(1, self.n)
            out[i] = (pow(self.g, m, self.n2) * pow(r, self.n, self.n2)) % self.n2
        return out

    def decrypt_to_ints(self, ct) -> list:
        return [
            (self._l_func(pow(int(c), self._lam, self.n2)) * self._mu) % self.n
            for c in np.asarray(ct, dtype=object).reshape(-1)
        ]

    # -- homomorphic ops ------------------------------------------------
    def add(self, a, b):
        a = np.asarray(a, dtype=object)
        b = np.asarray(b, dtype=object)
        fa, fb = np.broadcast_arrays(a, b)
        out = np.empty(fa.shape, dtype=object)
        for idx in np.ndindex(fa.shape):
            out[idx] = (int(fa[idx]) * int(fb[idx])) % self.n2
        return out

    def add_at(self, acc, idx, vals, chunk: int = 16):
        """Scatter homomorphic add: ``acc[idx[i]] += vals[i]`` row-wise, the
        ``np.add.at`` of the Paillier domain.  Hom-add is modmul in Z_{n^2},
        so each chunk accumulates raw integer products via ``np.multiply.at``
        (numpy's C-level loop over object ints) and reduces the touched rows
        mod n^2 once per chunk instead of once per instance.

        acc: (m, n_slots) object array, mutated in place and returned.
        idx: (k,) row indices; vals: (k, n_slots) object ciphertexts.
        """
        acc = np.asarray(acc, dtype=object)
        idx = np.asarray(idx, dtype=np.int64)
        vals = np.asarray(vals, dtype=object)
        for lo in range(0, len(idx), chunk):
            sl = idx[lo:lo + chunk]
            np.multiply.at(acc, sl, vals[lo:lo + chunk])
            touched = np.unique(sl)
            acc[touched] = acc[touched] % self.n2
        return acc

    def mul_pow2(self, ct, k: int):
        e = pow(2, k)
        ct = np.asarray(ct, dtype=object)
        out = np.empty(ct.shape, dtype=object)
        for idx in np.ndindex(ct.shape):
            out[idx] = pow(int(ct[idx]), e, self.n2)
        return out

    def sub(self, a, b):
        """Homomorphic a - b: multiply by b^(n-1) (scalar -1 mod n)."""
        b = np.asarray(b, dtype=object)
        neg = np.empty(b.shape, dtype=object)
        for idx in np.ndindex(b.shape):
            neg[idx] = pow(int(b[idx]), self.n - 1, self.n2)
        return self.add(a, neg)

    def zero(self, shape) -> np.ndarray:
        out = np.empty(tuple(shape), dtype=object)
        enc_zero = int(self.encrypt_ints([0])[0])
        for idx in np.ndindex(out.shape):
            out[idx] = enc_zero
        return out
