from .interface import get_cipher  # noqa: F401
