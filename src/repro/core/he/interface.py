"""Cipher suite interface for SecureBoost+.

Two backend families implement the same protocol surface:

* ``limb`` backends (:mod:`plain`, :mod:`affine`): a ciphertext batch is a
  jnp int32 array ``(..., L)`` of radix-2**8 limbs.  Homomorphic addition is
  limb addition, so histogram building can accumulate *lazily* (no carries,
  no modular reduction) in a widened accumulator and reduce once per bin.
  These are the JAX/TPU execution paths.

* ``pyobj`` backend (:mod:`paillier`): ciphertexts are numpy object arrays of
  python ints.  Real Paillier; used as the correctness/security oracle and
  for the paper's Paillier cost column.  Not JAX-traceable by design.

All suites expose:

  plaintext_bits   usable plaintext width iota (packing plans against this)
  backend          "limb" | "pyobj"
  encrypt / decrypt_to_ints
  add (canonical), mul_pow2 (homomorphic multiply by 2**k - cipher compress)
  and for limb backends: lazy histogram hooks (hist_width / reduce).
"""

from __future__ import annotations


def get_cipher(name: str, **kwargs):
    if name == "plain":
        from .plain import PlainCipher
        return PlainCipher(**kwargs)
    if name == "affine":
        from .affine import AffineCipher
        return AffineCipher.keygen(**kwargs)
    if name == "paillier":
        from .paillier import PaillierCipher
        return PaillierCipher.keygen(**kwargs)
    raise ValueError(f"unknown cipher suite: {name!r}")
