"""Identity 'cipher' over limb vectors (debug / lossless-parity backend).

Same dataflow and bit layout as the affine scheme but encryption is the
identity.  Arithmetic is mod 2**(8*L).  Used to prove the federated protocol
is bit-identical to local plaintext training, and as the fastest JAX path.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import limbs


class PlainCipher:
    backend = "limb"
    name = "plain"

    def __init__(self, bits: int = 512, hist_headroom_limbs: int = 3):
        self.Ln = limbs.num_limbs_for_bits(bits)
        self.plaintext_bits = self.Ln * limbs.RADIX_BITS - 1
        self.hist_headroom_limbs = hist_headroom_limbs

    # -- guest ---------------------------------------------------------
    def encrypt_ints(self, xs) -> jnp.ndarray:
        return jnp.asarray(limbs.from_pyints(list(xs), self.Ln))

    def encrypt_limbs(self, x):
        L = x.shape[-1]
        if L < self.Ln:
            x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, self.Ln - L)])
        return x[..., : self.Ln]

    def decrypt_to_ints(self, ct) -> list:
        return limbs.to_pyints(np.asarray(ct))

    def decrypt_limbs(self, ct):
        return ct

    # -- homomorphic ops ------------------------------------------------
    @staticmethod
    def _align(a, b):
        La, Lb = a.shape[-1], b.shape[-1]
        if La < Lb:
            a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, Lb - La)])
        elif Lb < La:
            b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, La - Lb)])
        return a, b

    def add(self, a, b):
        return limbs.add(*self._align(a, b))

    def sub(self, a, b):
        """Homomorphic a - b (valid when the underlying plaintexts satisfy
        a >= b, which histogram subtraction guarantees)."""
        return limbs.sub(*self._align(a, b))

    def mul_pow2(self, ct, k: int):
        return limbs.mask_bits(
            limbs.shift_left_bits(ct, k, self.Ln + self.hist_headroom_limbs),
            self.Ln * limbs.RADIX_BITS + self.hist_headroom_limbs * limbs.RADIX_BITS,
        )

    # -- lazy histogram hooks -------------------------------------------
    @property
    def hist_width(self) -> int:
        return self.Ln + self.hist_headroom_limbs

    def reduce(self, acc):
        """Canonicalize a lazy accumulator (values stay below 2**(8*width)).
        Limbs may be mixed-sign (lazy subtraction) as long as values >= 0."""
        return limbs.carry_fix(acc)

    def lazy_sub(self, parent, child_lazy, count_bound: int):
        """Histogram subtraction in the lazy limb domain: canonical parent
        minus an un-carried child accumulator, still lazy (mixed-sign limbs,
        resolved by the next :meth:`reduce`).  Values are true sums here, so
        ``parent >= child`` holds and no modular offset is needed;
        ``count_bound`` is unused (kept for interface parity with affine)."""
        w = child_lazy.shape[-1]
        return limbs.pad_limbs(parent, w)[..., :w] - child_lazy

    def zero(self, shape) -> jnp.ndarray:
        return jnp.zeros(tuple(shape) + (self.Ln,), dtype=jnp.int32)
