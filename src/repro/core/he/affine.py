"""IterativeAffine-style additively homomorphic cipher over limb vectors.

This is the JAX/TPU execution path for SecureBoost+'s ciphertext arithmetic
(the paper ships Paillier and IterativeAffine; only the affine family's
homomorphic-add-is-modadd structure maps onto the MXU -- see DESIGN.md §3).

    E(x)   = (a * x) mod n          (a random, gcd(a, n) = 1)
    E(x) + E(y) mod n = E(x + y)    additive homomorphism
    s * E(x) mod n    = E(s * x)    scalar homomorphism
    D(c)   = (a^{-1} * c) mod n

Encryption/decryption are modular multiplications by a *fixed* big integer,
lowered as Toeplitz matmuls + Barrett reduction (``kernels/modmul`` provides
the Pallas version; this module is the jnp fallback and the key holder).

Security note (honest): a known plaintext/ciphertext pair reveals ``a``; the
paper's IterativeAffine has the same symmetric-key character and was chosen
there for speed, with Paillier as the hardened option.  We mirror that menu:
``paillier.py`` is the semantically secure backend (python-int oracle), this
backend reproduces the affine column's cost structure at full fidelity.
"""

from __future__ import annotations

import math
import random as _random

import jax.numpy as jnp
import numpy as np

from ...analysis.registry import declassifies
from . import limbs


class AffineCipher:
    backend = "limb"
    name = "affine"

    def __init__(self, n_int: int, a_int: int, hist_headroom_limbs: int = 3):
        if math.gcd(a_int, n_int) != 1:
            raise ValueError("a must be invertible mod n")
        self.n_int = n_int
        self.a_int = a_int
        self.a_inv_int = pow(a_int, -1, n_int)
        self.Ln = limbs.num_limbs_for_bits(n_int.bit_length())
        self.plaintext_bits = n_int.bit_length() - 1
        self.hist_headroom_limbs = hist_headroom_limbs
        self.bctx = limbs.barrett_precompute(n_int, self.Ln)
        a_l = limbs.from_pyints([a_int], self.Ln)[0]
        ai_l = limbs.from_pyints([self.a_inv_int], self.Ln)[0]
        self.T_enc = jnp.asarray(limbs.toeplitz(a_l, self.Ln))
        self.T_dec = jnp.asarray(limbs.toeplitz(ai_l, self.Ln))

    @classmethod
    def keygen(cls, key_bits: int = 1024, seed: int | None = None,
               hist_headroom_limbs: int = 3) -> "AffineCipher":
        rng = _random.Random(seed)
        while True:
            n = rng.getrandbits(key_bits) | (1 << (key_bits - 1)) | 1
            a = rng.getrandbits(key_bits - 1) | 1
            if math.gcd(a, n) == 1:
                return cls(n, a, hist_headroom_limbs)

    # -- guest ---------------------------------------------------------
    @declassifies("affine-scheme encryption: ciphertext limbs only")
    def encrypt_limbs(self, x):
        """x: (..., Lp) plaintext limbs with value < n -> ciphertext (..., Ln)."""
        L = x.shape[-1]
        if L < self.Ln:
            x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, self.Ln - L)])
        elif L > self.Ln:
            raise ValueError("plaintext wider than modulus")
        # mirror the Paillier backend's range check: values >= n would wrap
        # silently and decrypt to garbage
        if bool(jnp.any(limbs.geq(x, jnp.broadcast_to(self.bctx.n, x.shape)))):
            raise ValueError("plaintext out of range (>= modulus n)")
        return limbs.mod_mul_fixed(x, self.T_enc, self.bctx)

    @declassifies("affine-scheme encryption: ciphertext limbs only")
    def encrypt_ints(self, xs) -> jnp.ndarray:
        return self.encrypt_limbs(jnp.asarray(limbs.from_pyints(list(xs), self.Ln)))

    def decrypt_limbs(self, ct):
        return limbs.mod_mul_fixed(ct, self.T_dec, self.bctx)

    def decrypt_to_ints(self, ct) -> list:
        return limbs.to_pyints(np.asarray(self.decrypt_limbs(jnp.asarray(ct))))

    # -- homomorphic ops ------------------------------------------------
    def add(self, a, b):
        n = jnp.pad(self.bctx.n, (0, 1))
        return limbs.cond_sub(limbs.add(jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, 1)]),
                                        jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, 1)])),
                              n)[..., : self.Ln]

    def sub(self, a, b):
        """Homomorphic (a - b) mod n: a + (n - b)."""
        n = jnp.broadcast_to(self.bctx.n, b.shape)
        neg_b = jnp.where(limbs.is_zero(b)[..., None], b, limbs.sub(n, b))
        return self.add(a, neg_b)

    def mul_pow2(self, ct, k: int):
        """Homomorphic multiply by 2**k (cipher-compress shift)."""
        wide = limbs.shift_left_bits(ct, k, None)
        return self._reduce_wide(wide)

    def _reduce_wide(self, x):
        L = x.shape[-1]
        if L > 2 * self.Ln:
            raise ValueError("operand too wide; reduce more often")
        return limbs.barrett_reduce(x, self.bctx)

    # -- lazy histogram hooks -------------------------------------------
    @property
    def hist_width(self) -> int:
        return self.Ln + self.hist_headroom_limbs

    def reduce(self, acc):
        """Reduce a lazy accumulator (sum of < 2**(8*headroom) ciphertexts).
        Limbs may be mixed-sign (lazy subtraction) as long as values >= 0."""
        return limbs.barrett_reduce(limbs.carry_fix(acc), self.bctx)

    def lazy_sub(self, parent, child_lazy, count_bound: int):
        """Histogram subtraction in the lazy limb domain: canonical parent
        (mod n) minus an un-carried child accumulator.  The child's lazy
        value can reach ``count_bound * n``, so ``count_bound * n`` is added
        to keep the represented value non-negative; the next :meth:`reduce`
        Barrett-reduces it away (sibling = parent - child mod n).  Requires
        ``(count_bound + 1) * n < RADIX**width``, i.e. count_bound below
        2**(8 * headroom) -- the same bound as direct lazy accumulation."""
        w = child_lazy.shape[-1]
        off = jnp.asarray(
            limbs.from_pyints([max(int(count_bound), 0) * self.n_int], w)[0])
        return limbs.pad_limbs(parent, w)[..., :w] + off - child_lazy

    def zero(self, shape) -> jnp.ndarray:
        return jnp.zeros(tuple(shape) + (self.Ln,), dtype=jnp.int32)
