"""SecureBoost+ core: vertical federated GBDT over homomorphic encryption."""

from .boosting import LocalGBDT, SBTParams, VerticalBoosting  # noqa: F401
