"""SecureBoost+ core: vertical federated GBDT over homomorphic encryption."""

from .boosting import LocalGBDT, SBTParams, VerticalBoosting  # noqa: F401
from .frontier import CipherFrontier, FrontierState, GuestFrontier  # noqa: F401
from .party import PartyUnavailable  # noqa: F401
