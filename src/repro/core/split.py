"""Split gain and global split finding (paper eqs 6-7, 18-20, Algorithms 2/6).

All candidate splits -- guest plaintext ones and decrypted host ones -- are
reduced to flat arrays of (g_l, h_l, count_l) per candidate, evaluated
vectorized, and the arg-max returned.  MO trees use vector-valued g/h with
the diagonal-Hessian score (eq 19).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..analysis.registry import declassifies


@dataclasses.dataclass
class SplitCandidates:
    """Flat candidate set for one node from one party."""
    party: int                    # -1 = guest, k >= 0 = host k
    sid: np.ndarray               # (m,) split ids (host: shuffled ids)
    g_l: np.ndarray               # (m,) or (m, l) left-side gradient sums
    h_l: np.ndarray               # (m,) or (m, l)
    cnt_l: np.ndarray             # (m,) left-side instance counts


@dataclasses.dataclass
class BestSplit:
    party: int
    sid: int
    gain: float
    g_l: np.ndarray
    h_l: np.ndarray
    cnt_l: int


@declassifies("aggregate leaf statistic: part of the model the protocol "
              "discloses to every party by design")
def leaf_weight(G, H, lam: float, learning_rate: float = 1.0):
    """eq 7 / eq 18 (vector form), scaled by the learning rate."""
    return -learning_rate * np.asarray(G) / (np.asarray(H) + lam)


def _score(G, H, lam):
    """-1/2 * sum_j G_j^2 / (H_j + lam); scalar case is eq 6's per-side term."""
    G = np.asarray(G, np.float64)
    H = np.asarray(H, np.float64)
    s = (G * G) / (H + lam)
    return s if s.ndim <= 1 else s.sum(axis=-1)


def split_gains(g_l, h_l, G_tot, H_tot, lam: float):
    """Vectorized gain (eq 6; eq 19-20 for vector g/h): (m,) float64."""
    g_l = np.asarray(g_l, np.float64)
    h_l = np.asarray(h_l, np.float64)
    g_r = np.asarray(G_tot) - g_l
    h_r = np.asarray(H_tot) - h_l

    def term(G, H):
        s = (G * G) / (H + lam)
        return s.sum(axis=-1) if s.ndim > 1 else s

    parent = np.asarray(G_tot, np.float64) ** 2 / (np.asarray(H_tot) + lam)
    parent = parent.sum() if parent.ndim else float(parent)
    return 0.5 * (term(g_l, h_l) + term(g_r, h_r) - parent)


@declassifies("the split decision (gain arg-max) the protocol reveals to "
              "every party by design")
def find_best_split(cands: list[SplitCandidates], G_tot, H_tot, n_tot: int,
                    lam: float, min_leaf: int = 1,
                    min_gain: float = 1e-6) -> BestSplit | None:
    best = None
    for c in cands:
        if len(c.sid) == 0:
            continue
        gains = split_gains(c.g_l, c.h_l, G_tot, H_tot, lam)
        cnt_r = n_tot - c.cnt_l
        valid = (c.cnt_l >= min_leaf) & (cnt_r >= min_leaf)
        gains = np.where(valid, gains, -np.inf)
        i = int(np.argmax(gains))
        if gains[i] > (best.gain if best else min_gain):
            best = BestSplit(party=c.party, sid=int(c.sid[i]),
                             gain=float(gains[i]),
                             g_l=np.asarray(c.g_l)[i],
                             h_l=np.asarray(c.h_l)[i],
                             cnt_l=int(c.cnt_l[i]))
    return best


def candidates_from_cumsum(G_cum, H_cum, C_cum, party: int) -> SplitCandidates:
    """Flatten (n_f, n_b[, l]) cumulative histograms into candidates.

    Split id encodes (fid, bid): sid = fid * n_b + bid; the last bin of each
    feature is excluded (empty right side).  For host parties the caller
    shuffles sids before sending to the guest.
    """
    n_f, n_b = G_cum.shape[:2]
    fid, bid = np.meshgrid(np.arange(n_f), np.arange(n_b - 1), indexing="ij")
    sid = (fid * n_b + bid).reshape(-1)
    g_l = G_cum[:, : n_b - 1].reshape((-1,) + G_cum.shape[2:])
    h_l = H_cum[:, : n_b - 1].reshape((-1,) + H_cum.shape[2:])
    c_l = C_cum[:, : n_b - 1].reshape(-1)
    return SplitCandidates(party=party, sid=sid, g_l=g_l, h_l=h_l, cnt_l=c_l)


def decode_sid(sid: int, n_b: int) -> tuple[int, int]:
    return sid // n_b, sid % n_b
