"""Quantile binning (paper §2.3.1) with sparse-aware zero bin (§6.2).

Each party bins its own features once, up front.  ``BinnedData`` keeps the
int32 bin matrix, the thresholds (for split-point interpretation at
inference), and -- when ``sparse=True`` -- the per-feature bin index that
value 0.0 falls into, enabling the sparse histogram recovery trick: zero
entries are masked out of histogram accumulation and their bin is recovered
as node_total - sum(other bins).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..kernels.binning import bucketize, fit_quantile_thresholds


@dataclasses.dataclass
class BinnedData:
    bins: np.ndarray           # (n_i, n_f) int32
    thresholds: np.ndarray     # (n_f, n_b-1) fp32, +inf padded
    n_bins: int
    zero_bins: np.ndarray | None = None   # (n_f,) int32, sparse mode only
    zero_mask: np.ndarray | None = None   # (n_i, n_f) bool: True where x==0
    _thr_dev: object = dataclasses.field(default=None, repr=False)

    @property
    def n_instances(self) -> int:
        return self.bins.shape[0]

    @property
    def n_features(self) -> int:
        return self.bins.shape[1]

    def device_thresholds(self):
        """Thresholds as a device-resident fp32 array, uploaded once and
        cached: every ``apply_binning`` (one per party per predict batch)
        previously re-placed the (n_f, n_b-1) table on device."""
        if self._thr_dev is None:
            import jax.numpy as jnp
            self._thr_dev = jnp.asarray(self.thresholds, jnp.float32)
        return self._thr_dev

    def split_value(self, fid: int, bid: int) -> float:
        """Threshold meaning 'go left iff bin <= bid'."""
        thr = self.thresholds[fid]
        if bid < len(thr) and np.isfinite(thr[bid]):
            return float(thr[bid])
        return float("inf")


def bin_features(X: np.ndarray, n_bins: int = 32, sparse: bool = False,
                 use_pallas: bool = True) -> BinnedData:
    X = np.asarray(X, np.float32)
    thr = fit_quantile_thresholds(X, n_bins)
    bins = np.asarray(bucketize(X, thr, use_pallas=use_pallas))
    zero_bins = zero_mask = None
    if sparse:
        zeros = np.zeros((1, X.shape[1]), np.float32)
        zero_bins = np.asarray(bucketize(zeros, thr, use_pallas=False))[0]
        zero_mask = X == 0.0
    return BinnedData(bins=bins.astype(np.int32), thresholds=thr,
                      n_bins=n_bins, zero_bins=zero_bins, zero_mask=zero_mask)


def apply_binning(X: np.ndarray, binned: BinnedData,
                  use_pallas: bool = True) -> np.ndarray:
    """Bin new data with already-fitted thresholds (inference path).  Reads
    the cached device-resident threshold table, shared by the serving
    engine and the legacy predict loop."""
    return np.asarray(bucketize(np.asarray(X, np.float32),
                                binned.device_thresholds(),
                                use_pallas=use_pallas)).astype(np.int32)
