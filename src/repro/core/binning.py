"""Quantile binning (paper §2.3.1) with sparse-aware zero bin (§6.2).

Each party bins its own features once, up front.  ``BinnedData`` keeps the
int32 bin matrix, the thresholds (for split-point interpretation at
inference), and -- when ``sparse=True`` -- the per-feature bin index that
value 0.0 falls into, enabling the sparse histogram recovery trick: zero
entries are masked out of histogram accumulation and their bin is recovered
as node_total - sum(other bins).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..kernels.binning import (bucketize, fit_quantile_thresholds,
                               fit_sketch, merge_sketch, sketch_thresholds)
from ..kernels.binning.sketch import DEFAULT_CAPACITY


@dataclasses.dataclass
class BinnedData:
    bins: np.ndarray           # (n_i, n_f) int32
    thresholds: np.ndarray     # (n_f, n_b-1) fp32, +inf padded
    n_bins: int
    zero_bins: np.ndarray | None = None   # (n_f,) int32, sparse mode only
    zero_mask: np.ndarray | None = None   # (n_i, n_f) bool: True where x==0
    _thr_dev: object = dataclasses.field(default=None, repr=False)

    @property
    def n_instances(self) -> int:
        return self.bins.shape[0]

    @property
    def n_features(self) -> int:
        return self.bins.shape[1]

    def __getstate__(self):
        # Never ship the cached device-resident threshold table across a
        # PartyProcess spawn/pickle: it re-uploads lazily on first use.
        state = self.__dict__.copy()
        state["_thr_dev"] = None
        return state

    def device_thresholds(self):
        """Thresholds as a device-resident fp32 array, uploaded once and
        cached: every ``apply_binning`` (one per party per predict batch)
        previously re-placed the (n_f, n_b-1) table on device."""
        if self._thr_dev is None:
            import jax.numpy as jnp
            self._thr_dev = jnp.asarray(self.thresholds, jnp.float32)
        return self._thr_dev

    def split_value(self, fid: int, bid: int) -> float:
        """Threshold meaning 'go left iff bin <= bid'."""
        thr = self.thresholds[fid]
        if bid < len(thr) and np.isfinite(thr[bid]):
            return float(thr[bid])
        return float("inf")


def bin_features(X: np.ndarray, n_bins: int = 32, sparse: bool = False,
                 use_pallas: bool = True) -> BinnedData:
    X = np.asarray(X, np.float32)
    thr = fit_quantile_thresholds(X, n_bins)
    bins = np.asarray(bucketize(X, thr, use_pallas=use_pallas))
    zero_bins = zero_mask = None
    if sparse:
        zeros = np.zeros((1, X.shape[1]), np.float32)
        zero_bins = np.asarray(bucketize(zeros, thr, use_pallas=False))[0]
        zero_mask = X == 0.0
    return BinnedData(bins=bins.astype(np.int32), thresholds=thr,
                      n_bins=n_bins, zero_bins=zero_bins, zero_mask=zero_mask)


def _bin_dtype(n_bins: int):
    """Smallest signed dtype that holds bin ids plus the -1 sparse mask."""
    if n_bins <= 127:
        return np.int8
    if n_bins <= 32767:
        return np.int16
    return np.int32


def bin_features_stream(blocks, n_bins: int = 32, sparse: bool = False,
                        use_pallas: bool = True,
                        capacity: int = DEFAULT_CAPACITY) -> BinnedData:
    """Out-of-core twin of ``bin_features``: two passes over a ``RowBlocks``
    source, never holding X.  Pass 1 fits a mergeable quantile sketch per
    block and merges; pass 2 bucketizes each block into a preallocated bin
    matrix stored at the smallest dtype that fits (int8 for n_bins<=127 --
    4x less resident than the monolithic int32 matrix).  Below the sketch
    capacity the thresholds -- and therefore every bin id -- are
    bit-identical to the monolithic fit."""
    sk = None
    for _, Xb in blocks:
        part = fit_sketch(np.asarray(Xb, np.float32), capacity)
        sk = part if sk is None else merge_sketch(sk, part, capacity)
    thr = sketch_thresholds(sk, n_bins)
    dt = _bin_dtype(n_bins)
    bins = np.empty((blocks.n_rows, blocks.n_features), dt)
    zero_mask = np.empty(bins.shape, bool) if sparse else None
    for start, Xb in blocks:
        Xb = np.asarray(Xb, np.float32)
        bins[start:start + len(Xb)] = np.asarray(
            bucketize(Xb, thr, use_pallas=use_pallas)).astype(dt)
        if sparse:
            zero_mask[start:start + len(Xb)] = Xb == 0.0
    zero_bins = None
    if sparse:
        zeros = np.zeros((1, blocks.n_features), np.float32)
        zero_bins = np.asarray(bucketize(zeros, thr, use_pallas=False))[0]
    return BinnedData(bins=bins, thresholds=thr, n_bins=n_bins,
                      zero_bins=zero_bins, zero_mask=zero_mask)


def apply_binning(X: np.ndarray, binned: BinnedData,
                  use_pallas: bool = True) -> np.ndarray:
    """Bin new data with already-fitted thresholds (inference path).  Reads
    the cached device-resident threshold table, shared by the serving
    engine and the legacy predict loop."""
    return np.asarray(bucketize(np.asarray(X, np.float32),
                                binned.device_thresholds(),
                                use_pallas=use_pallas)).astype(np.int32)
