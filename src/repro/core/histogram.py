"""Node-level histogram engines (paper Algorithms 1 & 5).

Two engines share one split-finding path:

* :class:`CipherHistogram` -- host side.  Accumulates packed-GH ciphertexts
  into (feature, bin) cells via the Pallas one-hot-matmul kernel (lazy limb
  sums), then canonicalizes once per bin (``cipher.reduce``: carry-fix +
  Barrett).  Supports ciphertext histogram subtraction (§4.3), the sparse
  zero-bin recovery trick (§6.2), and bin cumsum in the ciphertext domain.
  Ciphertext batches carry a slot axis (SBT-MO packs ``n_k`` ciphertexts per
  instance): per-instance cts are (n, n_slots, L) limbs (or (n, n_slots)
  object ints for the Paillier oracle); histograms are (n_f, n_b, n_slots, L)
  (resp. (n_f, n_b, n_slots)).  Binary tasks use n_slots = 1.

* :class:`PlainHistogram` -- guest side (and the local-XGBoost baseline).
  Same shapes in plaintext float64 via ``np.add.at``.
"""

from __future__ import annotations

import numpy as np

from ..kernels.histogram import ciphertext_histogram, count_histogram
from .binning import BinnedData


class PlainHistogram:
    """Plaintext (g, h, count) histograms: (n_f, n_b) float64 / int64."""

    def __init__(self, n_bins: int, sparse: bool = False):
        self.n_bins = n_bins
        self.sparse = sparse

    def node_histogram(self, data: BinnedData, g: np.ndarray, h: np.ndarray,
                       rows: np.ndarray):
        bins = data.bins[rows]                        # (r, n_f)
        n_f = bins.shape[1]
        out_dim = np.asarray(g).shape[1:]             # () scalar or (l,) MO
        G = np.zeros((n_f, self.n_bins) + out_dim)
        H = np.zeros((n_f, self.n_bins) + out_dim)
        C = np.zeros((n_f, self.n_bins), np.int64)
        gr, hr = g[rows], h[rows]
        if self.sparse and data.zero_mask is not None:
            zmask = data.zero_mask[rows]
            for f in range(n_f):
                keep = ~zmask[:, f]
                np.add.at(G[f], bins[keep, f], gr[keep])
                np.add.at(H[f], bins[keep, f], hr[keep])
                np.add.at(C[f], bins[keep, f], 1)
                zb = int(data.zero_bins[f])
                G[f, zb] += gr.sum(axis=0) - G[f].sum(axis=0)
                H[f, zb] += hr.sum(axis=0) - H[f].sum(axis=0)
                C[f, zb] += len(rows) - C[f].sum()
        else:
            for f in range(n_f):
                np.add.at(G[f], bins[:, f], gr)
                np.add.at(H[f], bins[:, f], hr)
                np.add.at(C[f], bins[:, f], 1)
        return (G, H, C)

    @staticmethod
    def subtract(parent, child):
        return tuple(p - c for p, c in zip(parent, child))

    @staticmethod
    def cumsum(hist):
        return tuple(np.cumsum(x, axis=1) for x in hist)


class CipherHistogram:
    """Ciphertext histograms over limb arrays (or Paillier object arrays)."""

    def __init__(self, cipher, n_bins: int, sparse: bool = False,
                 use_pallas: bool = True):
        self.cipher = cipher
        self.n_bins = n_bins
        self.sparse = sparse
        self.use_pallas = use_pallas

    # -- core accumulation ------------------------------------------------
    def node_histogram(self, data: BinnedData, cts, rows: np.ndarray):
        """cts: (n, n_slots, L) limbs or (n, n_slots) object ints.
        Returns (hist, counts)."""
        bins = data.bins[rows].astype(np.int32)
        if self.sparse and data.zero_mask is not None:
            bins = np.where(data.zero_mask[rows], -1, bins)
        counts = np.asarray(count_histogram(bins, self.n_bins)).astype(np.int64)

        if self.cipher.backend == "limb":
            hist = self._limb_hist(bins, cts, rows)
        else:
            hist = self._pyobj_hist(bins, cts, rows)

        if self.sparse and data.zero_mask is not None:
            hist = self._sparse_fix(data, hist, cts, rows)
            zb = np.asarray(data.zero_bins, np.int64)
            for f in range(counts.shape[0]):
                counts[f, zb[f]] += len(rows) - counts[f].sum()
        return hist, counts

    def _limb_hist(self, bins, cts, rows):
        import jax.numpy as jnp
        sel = jnp.asarray(cts)[jnp.asarray(np.asarray(rows, np.int64))]
        n, n_slots, per = sel.shape
        width = self.cipher.hist_width
        padded = jnp.pad(sel, ((0, 0), (0, 0), (0, width - per)))
        lazy = ciphertext_histogram(bins, padded.reshape(n, n_slots * width),
                                    self.n_bins, use_pallas=self.use_pallas)
        lazy = lazy.reshape(lazy.shape[0], self.n_bins, n_slots, width)
        return self.cipher.reduce(lazy)

    def _pyobj_hist(self, bins, cts, rows):
        cts = np.asarray(cts, dtype=object)[np.asarray(rows, np.int64)]
        n_f = bins.shape[1]
        n_slots = cts.shape[1]
        hist = self.cipher.zero((n_f, self.n_bins, n_slots))
        for i in range(bins.shape[0]):
            for f in range(n_f):
                b = bins[i, f]
                if b < 0:
                    continue
                hist[f, b] = self.cipher.add(hist[f, b], cts[i])
        return hist

    # -- paper tricks -------------------------------------------------------
    def _sparse_fix(self, data: BinnedData, hist, cts, rows):
        """zero-bin += node_total - sum(all accumulated bins)  (§6.2)."""
        node_total = self.node_total(cts, rows)            # (n_slots, ...)
        zb = np.asarray(data.zero_bins, np.int64)
        if self.cipher.backend == "limb":
            import jax.numpy as jnp
            hist = jnp.asarray(hist)
            width = self.cipher.hist_width
            wide = jnp.pad(hist, ((0, 0), (0, 0), (0, 0),
                                  (0, width - hist.shape[-1])))
            nz = self.cipher.reduce(wide.sum(axis=1))      # (n_f, n_slots, L)
            rec = self.cipher.sub(
                jnp.broadcast_to(node_total[None], nz.shape), nz)
            for f in range(hist.shape[0]):
                hist = hist.at[f, zb[f]].set(
                    self.cipher.add(hist[f, zb[f]], rec[f]))
            return hist
        n_f = hist.shape[0]
        for f in range(n_f):
            acc = hist[f, 0]
            for b in range(1, self.n_bins):
                acc = self.cipher.add(acc, hist[f, b])
            rec = self.cipher.sub(node_total, acc)
            hist[f, zb[f]] = self.cipher.add(hist[f, zb[f]], rec)
        return hist

    def node_total(self, cts, rows):
        """Sum of all instance ciphertexts in the node: (n_slots, ...)."""
        if self.cipher.backend == "limb":
            import jax.numpy as jnp
            sel = jnp.asarray(cts)[jnp.asarray(np.asarray(rows, np.int64))]
            wide = jnp.pad(sel, ((0, 0), (0, 0),
                                 (0, self.cipher.hist_width - sel.shape[-1])))
            return self.cipher.reduce(wide.sum(axis=0))
        sel = np.asarray(cts, dtype=object)[np.asarray(rows, np.int64)]
        tot = self.cipher.zero((sel.shape[1],))
        for i in range(sel.shape[0]):
            tot = self.cipher.add(tot, sel[i])
        return tot

    def subtract(self, parent, child):
        """Ciphertext histogram subtraction: sibling = parent - child (§4.3)."""
        ph, pc = parent
        ch, cc = child
        return self.cipher.sub(ph, ch), pc - cc

    def cumsum(self, hist):
        """Prefix-sum over the bin axis in the ciphertext domain."""
        if self.cipher.backend == "limb":
            import jax.numpy as jnp
            width = self.cipher.hist_width
            wide = jnp.pad(jnp.asarray(hist),
                           ((0, 0), (0, 0), (0, 0),
                            (0, width - hist.shape[-1])))
            return self.cipher.reduce(jnp.cumsum(wide, axis=1))
        out = np.empty(hist.shape, dtype=object)
        for f in range(hist.shape[0]):
            acc = None
            for b in range(hist.shape[1]):
                acc = hist[f, b] if acc is None else self.cipher.add(acc, hist[f, b])
                out[f, b] = acc
        return out
