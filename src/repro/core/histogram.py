"""Histogram engines (paper Algorithms 1 & 5), node-level and layer-batched.

Two engines share one split-finding path:

* :class:`CipherHistogram` -- host side.  Accumulates packed-GH ciphertexts
  into (feature, bin) cells via the Pallas one-hot-matmul kernel (lazy limb
  sums), then canonicalizes once per bin (``cipher.reduce``: carry-fix +
  Barrett).  Supports ciphertext histogram subtraction (§4.3), the sparse
  zero-bin recovery trick (§6.2), and bin cumsum in the ciphertext domain.
  Ciphertext batches carry a slot axis (SBT-MO packs ``n_k`` ciphertexts per
  instance): per-instance cts are (n, n_slots, L) limbs (or (n, n_slots)
  object ints for the Paillier oracle); histograms are (n_f, n_b, n_slots, L)
  (resp. (n_f, n_b, n_slots)).  Binary tasks use n_slots = 1.

* :class:`PlainHistogram` -- guest side (and the local-XGBoost baseline).
  Same shapes in plaintext float64 via ``np.add.at``.

Both engines additionally expose :meth:`layer_histograms`, the layer-batched
hot path (see DESIGN.md §6): every direct-mode frontier node of one tree
layer is accumulated by a SINGLE kernel launch over the composite one-hot
``node_slot * n_bins + bin``, histogram subtraction for the remaining nodes
is applied in the still-lazy limb domain (``cipher.lazy_sub``), and ONE
``cipher.reduce`` canonicalizes the whole layer.  This collapses
O(2**depth) kernel launches and Barrett passes per layer to O(1).

The cipher layer path operates on a ``core.frontier.CipherFrontier`` — the
device-resident layer state (DESIGN.md §7): bins masked and ciphertexts
width-padded once per tree, parent histograms cached as device arrays.
When the engine is built with a (data, model) mesh the single dispatch is
``shard_map``-sharded (per-shard kernel + lazy int32 psum over "data",
node blocks over "model") and remains bit-identical to one device.
"""

from __future__ import annotations

import numpy as np

from ..kernels.histogram import (allgather_wire_bytes, ciphertext_histogram,
                                 count_histogram, forest_ciphertext_histogram,
                                 layer_ciphertext_histogram,
                                 layer_count_histogram, psum_wire_bytes,
                                 sharded_forest_ciphertext_histogram,
                                 sharded_layer_ciphertext_histogram,
                                 streamed_layer_ciphertext_histogram)
from .binning import BinnedData

# Round-forest global node ids: gid = member * GID_STRIDE + member-local nid.
# Host-side dicts (histogram cache, shuffle perms, split tables) key on the
# opaque gid; k = 1 degenerates to gid == nid, i.e. the classic layer path.
GID_STRIDE = 1 << 20


class PlainHistogram:
    """Plaintext (g, h, count) histograms: (n_f, n_b) float64 / int64.

    ``row_block > 0`` makes the layer path iterate the concatenated row
    index in contiguous chunks (out-of-core guest, DESIGN.md §13): the
    O(rows) gather temporaries (bins / g / h / composite-index slices)
    shrink to O(block) while every ``np.add.at`` still applies the same
    additions to each cell in the same order — float64 accumulation is
    sequential either way, so the result is bit-identical."""

    def __init__(self, n_bins: int, sparse: bool = False, row_block: int = 0):
        self.n_bins = n_bins
        self.sparse = sparse
        self.row_block = row_block

    def node_histogram(self, data: BinnedData, g: np.ndarray, h: np.ndarray,
                       rows: np.ndarray):
        bins = data.bins[rows]                        # (r, n_f)
        n_f = bins.shape[1]
        out_dim = np.asarray(g).shape[1:]             # () scalar or (l,) MO
        G = np.zeros((n_f, self.n_bins) + out_dim)
        H = np.zeros((n_f, self.n_bins) + out_dim)
        C = np.zeros((n_f, self.n_bins), np.int64)
        gr, hr = g[rows], h[rows]
        if self.sparse and data.zero_mask is not None:
            zmask = data.zero_mask[rows]
            for f in range(n_f):
                keep = ~zmask[:, f]
                np.add.at(G[f], bins[keep, f], gr[keep])
                np.add.at(H[f], bins[keep, f], hr[keep])
                np.add.at(C[f], bins[keep, f], 1)
                zb = int(data.zero_bins[f])
                G[f, zb] += gr.sum(axis=0) - G[f].sum(axis=0)
                H[f, zb] += hr.sum(axis=0) - H[f].sum(axis=0)
                C[f, zb] += len(rows) - C[f].sum()
        else:
            for f in range(n_f):
                np.add.at(G[f], bins[:, f], gr)
                np.add.at(H[f], bins[:, f], hr)
                np.add.at(C[f], bins[:, f], 1)
        return (G, H, C)

    def layer_histograms(self, data: BinnedData, g: np.ndarray, h: np.ndarray,
                         node_rows: dict, direct: list, subtract: list,
                         cache: dict) -> dict:
        """Batched node_histogram for one tree layer.

        node_rows: {nid: row ids}; direct: nids accumulated directly (one
        composite ``np.add.at`` pass per feature); subtract: (nid, parent,
        sibling) triples resolved as parent - sibling from ``cache`` /this
        layer's direct results.  Returns {nid: (G, H, C)}.
        """
        out = {}
        if direct:
            n_d, n_b = len(direct), self.n_bins
            rows_cat = np.concatenate([node_rows[nid] for nid in direct])
            slot_cat = np.concatenate(
                [np.full(len(node_rows[nid]), k, np.int64)
                 for k, nid in enumerate(direct)])
            n_f = data.n_features
            out_dim = np.asarray(g).shape[1:]
            G = np.zeros((n_f, n_d * n_b) + out_dim)
            H = np.zeros((n_f, n_d * n_b) + out_dim)
            C = np.zeros((n_f, n_d * n_b), np.int64)
            sparse = self.sparse and data.zero_mask is not None
            if sparse:
                gt = np.zeros((n_d,) + out_dim)
                ht = np.zeros((n_d,) + out_dim)
                ct = np.zeros(n_d, np.int64)
            R = len(rows_cat)
            step = self.row_block if self.row_block > 0 else max(R, 1)
            # contiguous chunks of the concatenated index: each np.add.at
            # sees the same per-cell addition sequence as one monolithic
            # pass, so chunking changes peak memory, not a single bit
            for s0 in range(0, R, step):
                rc = rows_cat[s0: s0 + step]
                sc = slot_cat[s0: s0 + step]
                bins = data.bins[rc]                  # (r, n_f)
                gr, hr = g[rc], h[rc]
                comp = sc[:, None] * n_b + bins       # composite (node, bin)
                zmask = data.zero_mask[rc] if sparse else None
                for f in range(n_f):
                    if sparse:
                        keep = ~zmask[:, f]
                        np.add.at(G[f], comp[keep, f], gr[keep])
                        np.add.at(H[f], comp[keep, f], hr[keep])
                        np.add.at(C[f], comp[keep, f], 1)
                    else:
                        np.add.at(G[f], comp[:, f], gr)
                        np.add.at(H[f], comp[:, f], hr)
                        np.add.at(C[f], comp[:, f], 1)
                if sparse:
                    np.add.at(gt, sc, gr)
                    np.add.at(ht, sc, hr)
                    ct += np.bincount(sc, minlength=n_d)
            Gn = np.moveaxis(G.reshape((n_f, n_d, n_b) + out_dim), 1, 0)
            Hn = np.moveaxis(H.reshape((n_f, n_d, n_b) + out_dim), 1, 0)
            Cn = np.moveaxis(C.reshape(n_f, n_d, n_b), 1, 0)
            if sparse:
                for f in range(n_f):
                    zb = int(data.zero_bins[f])
                    Gn[:, f, zb] += gt - Gn[:, f].sum(axis=1)
                    Hn[:, f, zb] += ht - Hn[:, f].sum(axis=1)
                    Cn[:, f, zb] += ct - Cn[:, f].sum(axis=1)
            for k, nid in enumerate(direct):
                out[nid] = (Gn[k], Hn[k], Cn[k])
        for nid, par, sib in subtract:
            out[nid] = self.subtract(cache[par], out[sib])
        return out

    @staticmethod
    def subtract(parent, child):
        return tuple(p - c for p, c in zip(parent, child))

    @staticmethod
    def cumsum(hist):
        return tuple(np.cumsum(x, axis=1) for x in hist)


class CipherHistogram:
    """Ciphertext histograms over limb arrays (or Paillier object arrays)."""

    def __init__(self, cipher, n_bins: int, sparse: bool = False,
                 use_pallas: bool = True, stats=None, mesh=None,
                 tracer=None):
        from ..obs.trace import NULL_TRACER
        self.cipher = cipher
        self.n_bins = n_bins
        self.sparse = sparse
        self.use_pallas = use_pallas
        self.stats = stats          # optional party.Stats for launch counts
        self.mesh = mesh            # optional (data, model) mesh (DESIGN §5)
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def _mesh_devices(self) -> int:
        return self.mesh.devices.size if self.mesh is not None else 1

    def _count_launch(self):
        if self.stats is not None:
            self.stats.n_hist_launches += 1

    # -- core accumulation ------------------------------------------------
    def node_histogram(self, data: BinnedData, cts, rows: np.ndarray):
        """cts: (n, n_slots, L) limbs or (n, n_slots) object ints.
        Returns (hist, counts)."""
        bins = data.bins[rows].astype(np.int32)
        if self.sparse and data.zero_mask is not None:
            bins = np.where(data.zero_mask[rows], -1, bins)
        counts = np.asarray(count_histogram(bins, self.n_bins)).astype(np.int64)

        if self.cipher.backend == "limb":
            hist = self._limb_hist(bins, cts, rows)
        else:
            hist = self._pyobj_hist(bins, cts, rows)

        if self.sparse and data.zero_mask is not None:
            hist = self._sparse_fix(data, hist, cts, rows)
            zb = np.asarray(data.zero_bins, np.int64)
            for f in range(counts.shape[0]):
                counts[f, zb[f]] += len(rows) - counts[f].sum()
        return hist, counts

    def _limb_hist(self, bins, cts, rows):
        import jax.numpy as jnp
        sel = jnp.asarray(cts)[jnp.asarray(np.asarray(rows, np.int64))]
        n, n_slots, per = sel.shape
        width = self.cipher.hist_width
        padded = jnp.pad(sel, ((0, 0), (0, 0), (0, width - per)))
        lazy = ciphertext_histogram(bins, padded.reshape(n, n_slots * width),
                                    self.n_bins, use_pallas=self.use_pallas)
        self._count_launch()
        lazy = lazy.reshape(lazy.shape[0], self.n_bins, n_slots, width)
        return self.cipher.reduce(lazy)

    def _pyobj_hist(self, bins, cts, rows):
        cts = np.asarray(cts, dtype=object)[np.asarray(rows, np.int64)]
        n_f = bins.shape[1]
        n_slots = cts.shape[1]
        hist = self.cipher.zero((n_f, self.n_bins, n_slots))
        add_at = getattr(self.cipher, "add_at", None)
        if add_at is None:          # generic oracle fallback
            for i in range(bins.shape[0]):
                for f in range(n_f):
                    b = bins[i, f]
                    if b < 0:
                        continue
                    hist[f, b] = self.cipher.add(hist[f, b], cts[i])
            return hist
        for f in range(n_f):
            keep = bins[:, f] >= 0
            if keep.any():
                add_at(hist[f], bins[keep, f], cts[keep])
        return hist

    # -- layer-batched accumulation (DESIGN.md §6/§7) ---------------------
    def layer_histograms(self, frontier, node_rows: dict, direct: list,
                         subtract: list, forest: int = 0) -> dict:
        """All frontier histograms of one tree layer in one batch.

        frontier:  a ``core.frontier.CipherFrontier`` — the device-resident
                   layer state: sparse-masked bins and width-padded
                   ciphertext limbs (placed once per tree), plus the cache
                   of canonical parent histograms as device arrays.
        node_rows: {nid: row positions into the frontier's view}.
        direct:    nids accumulated directly -- ONE kernel dispatch for all
                   (``shard_map``-sharded over the engine's mesh when one is
                   set: per-shard kernel + lazy int32 psum over "data",
                   node blocks over "model").
        subtract:  (nid, parent, sibling) triples; the parent's canonical
                   histogram is read from the frontier cache, the sibling
                   must be in ``direct``.  Subtraction happens in the lazy
                   limb domain (``cipher.lazy_sub``) so a SINGLE
                   ``cipher.reduce`` canonicalizes direct and subtracted
                   nodes together.
        forest:    0 for the classic layer path; k > 0 means ``direct`` /
                   ``subtract`` hold gids of a k-member round-forest layer
                   (``gid = member * GID_STRIDE + nid``) and a row may sit
                   in one direct node *per member* — the accumulation runs
                   the (tree, node)-batched kernel, then the member-major
                   result is gathered back into ``direct`` order so every
                   downstream step (lazy subtraction, the single reduce,
                   cumsum, shuffle, compress) is unchanged.
        Returns {nid: (hist, counts)}; the frontier owns cache writes.
        """
        if self.cipher.backend != "limb":
            return self._pyobj_layer(frontier, node_rows, direct, subtract)
        import jax.numpy as jnp
        n_f, n_b = frontier.data.n_features, self.n_bins
        sparse = frontier.sparse
        slot_of = {nid: k for k, nid in enumerate(direct)}

        out = {}
        n_d = len(direct)
        counts = np.zeros((n_d, n_f, n_b), np.int64)
        lazy = None
        node_slot = None
        if n_d and forest:
            slot_mat, member_local, n_local = frontier.layer_slots_forest(
                node_rows, direct, forest, GID_STRIDE)
            if frontier.stream_blocks is not None:
                lazy_f, cnts_all = self._stream_layer(frontier, slot_mat,
                                                      n_local, forest=forest)
                cnts_m = list(cnts_all)
                n_slots = frontier.stream_blocks.cts.shape[1]
                width = self.cipher.hist_width
            else:
                nh = frontier.bins_np.shape[0]
                cnts_m = [np.asarray(layer_count_histogram(
                    frontier.bins_np, slot_mat[:nh, m], n_local,
                    n_b)).astype(np.int64) for m in range(forest)]
                lazy_f = self._forest_dispatch(frontier, slot_mat, n_local,
                                               forest)
                n, n_slots, width = frontier.state.cts.shape
            for kk, gid in enumerate(direct):
                m, loc = member_local[gid]
                counts[kk] = cnts_m[m][loc]
            lazy_f = lazy_f.reshape(forest, n_local, n_f, n_b, n_slots,
                                    width)
            # gather member-major blocks back into flat ``direct`` order
            t_idx = jnp.asarray(np.array(
                [member_local[gid][0] for gid in direct], np.int32))
            s_idx = jnp.asarray(np.array(
                [member_local[gid][1] for gid in direct], np.int32))
            lazy = lazy_f[t_idx, s_idx]      # (n_d, n_f, n_b, slots, width)
            if sparse:
                # global-slot matrix for the zero-bin recovery scatter: one
                # column per member, entries index into ``direct``
                node_slot = np.full((frontier._n_rows_dev, forest), -1,
                                    np.int32)
                for kk, gid in enumerate(direct):
                    node_slot[node_rows[gid], member_local[gid][0]] = kk
        elif n_d:
            node_slot = frontier.layer_slots(node_rows, direct)
            if frontier.stream_blocks is not None:
                lazy, cnts_all = self._stream_layer(frontier, node_slot, n_d)
                counts = cnts_all[0]
                n_slots = frontier.stream_blocks.cts.shape[1]
                width = self.cipher.hist_width
            else:
                # node_slot is aligned with the (possibly mesh-padded)
                # device bins; the plaintext counts run on the unpadded
                # host mirror
                counts = np.asarray(layer_count_histogram(
                    frontier.bins_np, node_slot[: frontier.bins_np.shape[0]],
                    n_d, n_b)).astype(np.int64)
                lazy = self._layer_dispatch(frontier, node_slot, n_d)
                n, n_slots, width = frontier.state.cts.shape
            lazy = lazy.reshape(n_d, n_f, n_b, n_slots, width)

        if sparse:
            # zero-bin recovery needs canonical per-node totals, so fix the
            # direct batch first, then subtract canonically -- still O(1)
            # vectorized cipher calls per layer.
            if n_d:
                with self.tracer.span("carry_fix", nodes=n_d):
                    canon_direct = self.cipher.reduce(lazy)
                canon_direct = self._layer_sparse_fix(
                    frontier.data, canon_direct, frontier.state.cts,
                    node_slot, frontier=frontier)
                zb = np.asarray(frontier.data.zero_bins, np.int64)
                for k, nid in enumerate(direct):
                    for f in range(n_f):
                        counts[k, f, zb[f]] += (len(node_rows[nid])
                                                - counts[k, f].sum())
                    out[nid] = (canon_direct[k], counts[k])
            if subtract:
                # parents are device arrays in the frontier cache: one stack,
                # no per-node host->device copies
                parents = jnp.stack([frontier.hist(par)
                                     for _, par, _ in subtract])
                children = jnp.stack([out[sib][0] for _, _, sib in subtract])
                subs = self.cipher.sub(parents, children)
                for j, (nid, par, sib) in enumerate(subtract):
                    out[nid] = (subs[j], frontier.count(par) - out[sib][1])
            return out

        # dense path: lazy subtraction, one reduce for the whole layer
        sub_lazy = [self.cipher.lazy_sub(frontier.hist(par),
                                         lazy[slot_of[sib]],
                                         len(node_rows[sib]))
                    for _, par, sib in subtract]
        parts = ([lazy] if n_d else []) + \
            ([jnp.stack(sub_lazy)] if sub_lazy else [])
        if not parts:
            return out
        with self.tracer.span("carry_fix", nodes=n_d + len(subtract)):
            canon = self.cipher.reduce(jnp.concatenate(parts, axis=0))
        for k, nid in enumerate(direct):
            out[nid] = (canon[k], counts[k])
        for j, (nid, par, sib) in enumerate(subtract):
            out[nid] = (canon[n_d + j],
                        frontier.count(par) - counts[slot_of[sib]])
        return out

    def _stream_layer(self, frontier, node_slot: np.ndarray, n_nodes: int,
                      forest: int = 0):
        """Out-of-core layer accumulation (DESIGN.md §13): one pass over
        the frontier's row blocks drives the streamed launch path while the
        plaintext counts accumulate in the same pass.  Returns
        ``(lazy, counts)`` where ``lazy`` matches the monolithic dispatch's
        layout ((n_nodes, n_f, n_b, L) or (k, n_nodes, ...) for a forest)
        and ``counts`` is (max(k, 1), n_nodes, n_f, n_b) int64."""
        n_b = self.n_bins
        n_f = frontier.data.n_features
        k = max(forest, 1)
        cnts = np.zeros((k, n_nodes, n_f, n_b), np.int64)
        stats = self.stats
        launches = [0]

        def blocks():
            for bins_blk, slot_blk, cts_blk in \
                    frontier.iter_stream_blocks(node_slot):
                if forest:
                    for m in range(forest):
                        cnts[m] += np.asarray(layer_count_histogram(
                            bins_blk, slot_blk[:, m], n_nodes, n_b),
                            np.int64)
                else:
                    cnts[0] += np.asarray(layer_count_histogram(
                        bins_blk, slot_blk, n_nodes, n_b), np.int64)
                yield bins_blk, slot_blk, cts_blk.reshape(
                    cts_blk.shape[0], -1)

        def on_block(nbytes):
            self._count_launch()
            launches[0] += 1
            if stats is not None:
                stats.peak_block_bytes = max(stats.peak_block_bytes,
                                             int(nbytes))
            self.tracer.instant("stream_block", blk=launches[0] - 1,
                                nbytes=int(nbytes))

        # pow2 node padding: same compile-bucketing as the monolithic path
        n_pad = 1 << max(n_nodes - 1, 0).bit_length()
        multi = self._mesh_devices() > 1
        lazy = streamed_layer_ciphertext_histogram(
            blocks(), n_pad, n_b, forest=forest,
            mesh=self.mesh if multi else None,
            use_pallas=self.use_pallas, on_block=on_block)
        if multi:
            # same analytic collective ledger as the monolithic dispatch,
            # paid once per block
            sizes = dict(self.mesh.shape)
            mm = sizes.get("model", 1)
            npm = -(-n_pad // mm)
            n_slots = frontier.stream_blocks.cts.shape[1]
            shard_bytes = (k * npm * n_f * n_b * n_slots
                           * self.cipher.hist_width * 4)
            for _ in range(launches[0]):
                if sizes.get("data", 1) > 1:
                    frontier.collective(
                        "hist_psum", psum_wire_bytes(self.mesh, shard_bytes))
                if mm > 1:
                    frontier.collective(
                        "hist_allgather",
                        allgather_wire_bytes(self.mesh, shard_bytes * mm))
        lazy = lazy[:, :n_nodes] if forest else lazy[:n_nodes]
        return lazy, cnts

    def _forest_dispatch(self, frontier, slot_mat: np.ndarray, n_local: int,
                         k: int):
        """One (tree, node)-batched accumulation dispatch for a round-forest
        layer: the member axis rides through the kernel grid while the
        member-local node axis keeps the layer dispatch's "model" blocking.
        Returns (k, n_local, n_f, n_b, L) lazy limb sums."""
        state = frontier.state
        n_slots, width = state.cts.shape[1:]
        flat = frontier.cts_flat
        if self.stats is not None:
            self.stats.peak_block_bytes = max(
                self.stats.peak_block_bytes,
                (int(state.bins.size) + int(slot_mat.size)
                 + int(flat.size)) * 4)
        n_pad = 1 << max(n_local - 1, 0).bit_length()
        if self._mesh_devices() > 1:
            lazy = sharded_forest_ciphertext_histogram(
                state.bins, slot_mat, flat, n_pad, self.n_bins, self.mesh,
                use_pallas=self.use_pallas)[:, :n_local]
            sizes = dict(self.mesh.shape)
            mm = sizes.get("model", 1)
            npm = -(-n_pad // mm)
            shard_bytes = (k * npm * frontier.data.n_features * self.n_bins
                           * n_slots * width * 4)
            if sizes.get("data", 1) > 1:
                frontier.collective("hist_psum",
                                    psum_wire_bytes(self.mesh, shard_bytes))
            if mm > 1:
                frontier.collective(
                    "hist_allgather",
                    allgather_wire_bytes(self.mesh, shard_bytes * mm))
        else:
            lazy = forest_ciphertext_histogram(
                state.bins, slot_mat, flat, n_pad, self.n_bins,
                use_pallas=self.use_pallas)[:, :n_local]
        self._count_launch()
        return lazy

    def _layer_dispatch(self, frontier, node_slot: np.ndarray, n_d: int):
        """One accumulation dispatch for the layer's direct nodes: the
        single-device kernel, or the shard_map dispatch (+ lazy-limb psum
        over "data") when the engine carries a multi-device mesh."""
        state = frontier.state
        n_slots, width = state.cts.shape[1:]
        flat = frontier.cts_flat          # flattened once per tree
        if self.stats is not None:
            self.stats.peak_block_bytes = max(
                self.stats.peak_block_bytes,
                (int(state.bins.size) + int(node_slot.size)
                 + int(flat.size)) * 4)
        # pad the node axis to the next power of two: the node count is a
        # static kernel arg, so this caps distinct jit compilations at
        # O(log max_nodes) per tree shape instead of one per frontier size
        n_pad = 1 << max(n_d - 1, 0).bit_length()
        if self._mesh_devices() > 1:
            lazy = sharded_layer_ciphertext_histogram(
                state.bins, node_slot, flat, n_pad, self.n_bins, self.mesh,
                use_pallas=self.use_pallas)[:n_d]
            sizes = dict(self.mesh.shape)
            # bytes reflect the padded node count the dispatch actually
            # moves; axes of extent 1 run no collective and tally nothing
            mm = sizes.get("model", 1)
            npm = -(-n_pad // mm)
            shard_bytes = (npm * frontier.data.n_features * self.n_bins
                           * n_slots * width * 4)
            if sizes.get("data", 1) > 1:
                frontier.collective("hist_psum",
                                    psum_wire_bytes(self.mesh, shard_bytes))
            if mm > 1:
                frontier.collective(
                    "hist_allgather",
                    allgather_wire_bytes(self.mesh, shard_bytes * mm))
        else:
            lazy = layer_ciphertext_histogram(
                state.bins, node_slot, flat, n_pad, self.n_bins,
                use_pallas=self.use_pallas)[:n_d]
        self._count_launch()
        return lazy

    def _pyobj_layer(self, frontier, node_rows, direct, subtract):
        """Paillier-oracle layer path: per-node accumulation (clarity over
        speed -- the protocol round-trip is still batched by the caller)."""
        out = {}
        for nid in direct:
            out[nid] = self.node_histogram(frontier.data, frontier.cts_obj,
                                           node_rows[nid])
        for nid, par, sib in subtract:
            out[nid] = self.subtract((frontier.hist(par),
                                      frontier.count(par)), out[sib])
        return out

    # -- paper tricks -------------------------------------------------------
    def _layer_sparse_fix(self, data, hist, cts_wide, node_slot,
                          frontier=None):
        """Batched §6.2 recovery: per node, zero-bin += total - sum(bins).

        hist: (n_d, n_f, n_b, n_slots, L) canonical; cts_wide: (n, n_slots,
        width) padded limbs aligned with node_slot.  A 2-D node_slot is the
        round-forest global-slot matrix (one column per member: a row
        contributes its ciphertext to up to one node per member tree).
        ``cts_wide is None`` selects the out-of-core path: the per-node
        totals accumulate over the frontier's row blocks (int32 scatter-adds
        are exact and order-free, so the block split is bit-invisible)."""
        import jax
        import jax.numpy as jnp
        from .he import limbs
        n_d = hist.shape[0]
        width = self.cipher.hist_width
        # per-node ciphertext totals: one scatter-add (per member column in
        # forest mode) + one reduce
        if cts_wide is None:
            n_slots = frontier.stream_blocks.cts.shape[1]
            tot_lazy = jnp.zeros((n_d + 1, n_slots, width), jnp.int32)
            for _, slot_blk, cts_blk in \
                    frontier.iter_stream_blocks(node_slot):
                slot_b = np.where(slot_blk < 0, n_d, slot_blk)
                cw = jnp.asarray(cts_blk)
                for col in (slot_b.T if slot_b.ndim == 2 else [slot_b]):
                    tot_lazy = tot_lazy.at[jnp.asarray(col)].add(cw)
        else:
            slot = np.where(node_slot < 0, n_d, node_slot)
            tot_lazy = jnp.zeros((n_d + 1,) + tuple(cts_wide.shape[1:]),
                                 jnp.int32)
            for col in (slot.T if slot.ndim == 2 else [slot]):
                tot_lazy = tot_lazy.at[jnp.asarray(col)].add(cts_wide)
        if self._mesh_devices() > 1:
            # cts live mesh-sharded; land the small per-node totals next to
            # the (single-device) gathered histograms before mixing
            tot_lazy = jax.device_put(tot_lazy, jax.devices()[0])
        node_total = self.cipher.reduce(tot_lazy[:n_d])   # (n_d, slots, L)
        nz = self.cipher.reduce(
            limbs.pad_limbs(hist, width).sum(axis=2))     # (n_d, n_f, s, L)
        rec = self.cipher.sub(
            jnp.broadcast_to(node_total[:, None], nz.shape), nz)
        zb = np.asarray(data.zero_bins, np.int64)
        for f in range(hist.shape[1]):
            hist = hist.at[:, f, zb[f]].set(
                self.cipher.add(hist[:, f, zb[f]], rec[:, f]))
        return hist

    def _sparse_fix(self, data: BinnedData, hist, cts, rows):
        """zero-bin += node_total - sum(all accumulated bins)  (§6.2)."""
        node_total = self.node_total(cts, rows)            # (n_slots, ...)
        zb = np.asarray(data.zero_bins, np.int64)
        if self.cipher.backend == "limb":
            import jax.numpy as jnp
            from .he import limbs
            hist = jnp.asarray(hist)
            wide = limbs.pad_limbs(hist, self.cipher.hist_width)
            nz = self.cipher.reduce(wide.sum(axis=1))      # (n_f, n_slots, L)
            rec = self.cipher.sub(
                jnp.broadcast_to(node_total[None], nz.shape), nz)
            for f in range(hist.shape[0]):
                hist = hist.at[f, zb[f]].set(
                    self.cipher.add(hist[f, zb[f]], rec[f]))
            return hist
        n_f = hist.shape[0]
        for f in range(n_f):
            acc = hist[f, 0]
            for b in range(1, self.n_bins):
                acc = self.cipher.add(acc, hist[f, b])
            rec = self.cipher.sub(node_total, acc)
            hist[f, zb[f]] = self.cipher.add(hist[f, zb[f]], rec)
        return hist

    def node_total(self, cts, rows):
        """Sum of all instance ciphertexts in the node: (n_slots, ...)."""
        if self.cipher.backend == "limb":
            import jax.numpy as jnp
            from .he import limbs
            sel = jnp.asarray(cts)[jnp.asarray(np.asarray(rows, np.int64))]
            wide = limbs.pad_limbs(sel, self.cipher.hist_width)
            return self.cipher.reduce(wide.sum(axis=0))
        sel = np.asarray(cts, dtype=object)[np.asarray(rows, np.int64)]
        tot = self.cipher.zero((sel.shape[1],))
        for i in range(sel.shape[0]):
            tot = self.cipher.add(tot, sel[i])
        return tot

    def subtract(self, parent, child):
        """Ciphertext histogram subtraction: sibling = parent - child (§4.3)."""
        ph, pc = parent
        ch, cc = child
        return self.cipher.sub(ph, ch), pc - cc

    def _sharded_cumsum(self, wide, bin_axis: int):
        """Mesh-sharded ciphertext-domain prefix sum over the bin axis.

        The leading (node, feature) axes flatten into one embarrassingly
        parallel row axis sharded over "data": each shard cumsums and
        carry-fixes its rows with NO collective — cumsum and reduce are
        per-row — so the result is bit-identical to the single-device path.
        This closes the last single-device remainder of the layer pipeline
        (accumulate and decrypt were sharded in PRs 2-3; the layer cumsum
        between them still serialized on one device).

        Gated exactly like ``_decrypt_ints``: shard only when every data
        shard gets at least one full kernel row block (shallow layers are
        sub-millisecond and would pay a shard_map compile per pow2 bucket).
        Returns None below the gate; the caller falls back to the
        single-device reduce."""
        if self._mesh_devices() <= 1 or bin_axis < 1:
            return None
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from ..kernels.modmul.modmul import BLOCK_N
        from ..parallel.sharding import data_pad, gbdt_sharding
        mesh = self.mesh
        dd = dict(mesh.shape).get("data", 1)
        lead = tuple(wide.shape[:bin_axis])
        G = int(np.prod(lead))
        if dd <= 1 or G < BLOCK_N * dd:
            return None
        tail = tuple(wide.shape[bin_axis:])       # (n_b, slots, width)
        x = wide.reshape((G,) + tail)
        # pow2 bucketing caps distinct compilations at O(log max_G), same
        # rationale as the decrypt stack's candidate padding
        bucket = 1 << max(G - 1, 0).bit_length()
        bucket += data_pad(mesh, bucket)
        if bucket > G:
            x = jnp.pad(x, [(0, bucket - G)] + [(0, 0)] * (x.ndim - 1))
        x = jax.device_put(
            x, gbdt_sharding(mesh, "split_infos", ndim=x.ndim))
        out = shard_map(
            lambda xs: self.cipher.reduce(jnp.cumsum(xs, axis=1)),
            mesh=mesh,
            in_specs=P("data", None, None, None),
            out_specs=P("data", None, None, None),
            check_rep=False)(x)
        # land on one device (jax-0.4.37 eager-mixing caveat, see
        # kernels/histogram/ops.py) before the shuffle/compress consumers
        out = jax.device_put(out[:G], jax.devices()[0])
        # reduce canonicalizes the limb axis (hist width -> Ln)
        return out.reshape(lead + tuple(out.shape[1:]))

    def cumsum(self, hist):
        """Prefix-sum over the bin axis in the ciphertext domain.  Accepts a
        single histogram (n_f, n_b, slots[, L]) or a layer-batched stack with
        any leading axes (..., n_f, n_b, slots[, L])."""
        if self.cipher.backend == "limb":
            import jax.numpy as jnp
            from .he import limbs
            hist = jnp.asarray(hist)
            wide = limbs.pad_limbs(hist, self.cipher.hist_width)
            bin_axis = hist.ndim - 3
            out = self._sharded_cumsum(wide, bin_axis)
            if out is not None:
                return out
            return self.cipher.reduce(jnp.cumsum(wide, axis=bin_axis))
        flat = hist.reshape((-1,) + hist.shape[-2:])   # (G, n_b, slots)
        out = np.empty(flat.shape, dtype=object)
        for i in range(flat.shape[0]):
            acc = None
            for b in range(flat.shape[1]):
                acc = flat[i, b] if acc is None \
                    else self.cipher.add(acc, flat[i, b])
                out[i, b] = acc
        return out.reshape(hist.shape)
