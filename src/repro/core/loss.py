"""Losses and first/second-order derivatives (paper eq 4).

Matches XGBoost conventions: binary logloss (g = p - y, h = p(1-p)) and
softmax cross-entropy for multi-class / multi-output trees (diagonal hessian,
g_k = p_k - y_k, h_k = p_k (1 - p_k)) -- the paper's SBT-MO uses exactly this
diagonal-H form (§5.3.1).
"""

from __future__ import annotations

import numpy as np


def sigmoid(x: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + np.tanh(0.5 * x))


def softmax(x: np.ndarray) -> np.ndarray:
    z = x - x.max(axis=-1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=-1, keepdims=True)


class LogLoss:
    """Binary classification; scores are logits."""
    n_outputs = 1

    @staticmethod
    def init_score(y: np.ndarray) -> float:
        p = np.clip(y.mean(), 1e-6, 1 - 1e-6)
        return float(np.log(p / (1 - p)))

    @staticmethod
    def grad_hess(y: np.ndarray, score: np.ndarray):
        p = sigmoid(score)
        return p - y, np.maximum(p * (1 - p), 1e-16)

    @staticmethod
    def loss(y: np.ndarray, score: np.ndarray) -> float:
        p = np.clip(sigmoid(score), 1e-12, 1 - 1e-12)
        return float(-(y * np.log(p) + (1 - y) * np.log(1 - p)).mean())


class SoftmaxLoss:
    """Multi-class; scores are (n, k) logits, y integer labels."""

    def __init__(self, n_classes: int):
        self.n_classes = n_classes
        self.n_outputs = n_classes

    def init_score(self, y: np.ndarray) -> np.ndarray:
        return np.zeros(self.n_classes, dtype=np.float64)

    def grad_hess(self, y: np.ndarray, score: np.ndarray):
        p = softmax(score)                       # (n, k)
        onehot = np.eye(self.n_classes)[y.astype(np.int64)]
        g = p - onehot
        h = np.maximum(p * (1 - p), 1e-16)
        return g, h

    def loss(self, y: np.ndarray, score: np.ndarray) -> float:
        p = np.clip(softmax(score), 1e-12, None)
        return float(-np.log(p[np.arange(len(y)), y.astype(np.int64)]).mean())
