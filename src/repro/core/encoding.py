"""Fixed-point encoding and GH packing (paper §4.2, Algorithms 3 & 6).

A (g, h) pair is fixed-point encoded (eq 11), g offset to non-negative
(``g_off = |min(g)|``), and packed into one big integer ``gh = g_int << b_h
| h_int`` with bit budgets sized for the worst-case histogram sum over
``n_capacity`` instances (eqs 12-13).  Packing/unpacking is host-side numpy
(runs once per boosting round); the packed plaintext then flows through the
limb-based cipher backends.

Note: Algorithm 6 in the paper writes ``g = gh >> b_g`` -- that is a typo
(the shift must be by ``b_h``, the width of the hessian field); we implement
the correct recovery and verify bit-exactness in tests.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .he import limbs

DEFAULT_PRECISION = 53


@dataclasses.dataclass(frozen=True)
class PackingPlan:
    r: int                # fixed-point fractional bits (eq 11)
    g_off: float          # offset added to every g so encodings are >= 0
    b_g: int              # bits reserved for the g field (eq 13)
    b_h: int              # bits reserved for the h field (eq 13)
    n_capacity: int       # max #instances any histogram sum may contain
    plaintext_bits: int   # iota: usable plaintext width of the cipher

    @property
    def b_gh(self) -> int:
        return self.b_g + self.b_h

    @property
    def limb_width(self) -> int:
        return limbs.num_limbs_for_bits(self.b_gh)

    @property
    def compress_capacity(self) -> int:
        """eta_s = floor(iota / b_gh): split-infos packable per ciphertext."""
        return max(1, self.plaintext_bits // self.b_gh)


def plan_packing(g: np.ndarray, h: np.ndarray, n_capacity: int,
                 plaintext_bits: int, r: int = DEFAULT_PRECISION) -> PackingPlan:
    """Derive bit budgets (eqs 12-13), shrinking r if iota is too small."""
    g = np.asarray(g, dtype=np.float64)
    h = np.asarray(h, dtype=np.float64)
    g_off = float(max(0.0, -float(g.min()))) if g.size else 0.0
    g_max = float(g.max() + g_off) if g.size else 1.0
    h_max = float(max(h.max(), 2.0 ** -r)) if h.size else 1.0
    while True:
        # exact integer bounds on any histogram sum (python ints: no overflow)
        per_g = int(math.floor(g_max * (1 << r))) + 1
        per_h = int(math.floor(h_max * (1 << r))) + 1
        b_g = max(1, (n_capacity * per_g).bit_length())
        b_h = max(1, (n_capacity * per_h).bit_length())
        if b_g + b_h <= plaintext_bits or r <= 4:
            break
        r -= 1
    if b_g + b_h > plaintext_bits:
        raise ValueError(
            f"cannot pack: b_gh={b_g + b_h} > iota={plaintext_bits}")
    return PackingPlan(r=r, g_off=g_off, b_g=b_g, b_h=b_h,
                       n_capacity=n_capacity, plaintext_bits=plaintext_bits)


# ---------------------------------------------------------------------------
# encode (Algorithm 3)
# ---------------------------------------------------------------------------

def encode_int64(x: np.ndarray, r: int) -> np.ndarray:
    """eq 11: round(x * 2**r) as int64 (exact for |x| <= ~2**10 at r=53)."""
    return np.round(np.asarray(x, dtype=np.float64) * float(1 << r)).astype(np.int64)


def _int64_to_limbs(x: np.ndarray, L: int) -> np.ndarray:
    """Non-negative int64 array -> (..., L) radix-2**8 limbs."""
    if np.any(x < 0):
        raise ValueError("negative value in limb conversion")
    shifts = (np.arange(L, dtype=np.int64) * limbs.RADIX_BITS)[None, :]
    return ((x[..., None] >> shifts) & limbs.LIMB_MASK).astype(np.int32)


def pack_gh(g: np.ndarray, h: np.ndarray, plan: PackingPlan) -> np.ndarray:
    """Pack per-instance (g, h) -> (n, Lp) plaintext limbs (Algorithm 3)."""
    g_int = encode_int64(np.asarray(g, np.float64) + plan.g_off, plan.r)
    h_int = encode_int64(h, plan.r)
    Lp = plan.limb_width
    g_l = _int64_to_limbs(g_int, Lp)
    h_l = _int64_to_limbs(h_int, Lp)
    # gh = (g_int << b_h) | h_int, in limb domain (b_h may exceed 63 bits)
    limb_shift, bit_shift = divmod(plan.b_h, limbs.RADIX_BITS)
    g_shifted = np.zeros_like(g_l)
    if bit_shift:
        lo = (g_l.astype(np.int64) << bit_shift) & limbs.LIMB_MASK
        hi = g_l.astype(np.int64) >> (limbs.RADIX_BITS - bit_shift)
        g_shifted_wide = lo
        g_shifted_wide[..., 1:] += hi[..., :-1]
    else:
        g_shifted_wide = g_l.astype(np.int64)
    if limb_shift:
        g_shifted[..., limb_shift:] = g_shifted_wide[..., : Lp - limb_shift]
    else:
        g_shifted = g_shifted_wide
    out = g_shifted.astype(np.int64) + h_l
    while np.any(out > limbs.LIMB_MASK):
        carry = out >> limbs.RADIX_BITS
        out &= limbs.LIMB_MASK
        out[..., 1:] += carry[..., :-1]
    assert np.all(out >= 0) and np.all(out <= limbs.LIMB_MASK)
    return out.astype(np.int32)


# ---------------------------------------------------------------------------
# decode (Algorithm 6, typo-corrected)
# ---------------------------------------------------------------------------

def unpack_gh_int(x: int, plan: PackingPlan, sample_count: int) -> tuple:
    """Recover (sum g, sum h) floats from one decrypted big int."""
    h_int = x & ((1 << plan.b_h) - 1)
    g_int = x >> plan.b_h          # paper alg 6 says b_g: typo, must be b_h
    scale = float(1 << plan.r)
    g = g_int / scale - plan.g_off * sample_count
    h = h_int / scale
    return g, h


def unpack_gh_ints(xs, plan: PackingPlan, counts) -> tuple:
    gs, hs = [], []
    for x, c in zip(xs, counts):
        g, h = unpack_gh_int(int(x), plan, int(c))
        gs.append(g)
        hs.append(h)
    return np.asarray(gs, np.float64), np.asarray(hs, np.float64)


def limbs_to_float64(arr: np.ndarray) -> np.ndarray:
    """(..., L) limbs -> float64 value (rel. error <= 2**-52; fine for gains)."""
    a = np.asarray(arr, dtype=np.float64)
    w = 256.0 ** np.arange(a.shape[-1])
    return a @ w


def unpack_gh_limbs(arr: np.ndarray, plan: PackingPlan,
                    counts: np.ndarray) -> tuple:
    """Vectorized recovery from decrypted plaintext limbs (numpy, float64).

    Used on the guest after decrypt for the limb backends; exactness within
    float64 is sufficient for gain comparison (bit-exact path: python ints).
    """
    a = np.asarray(arr)
    full, part = divmod(plan.b_h, limbs.RADIX_BITS)
    # h = value mod 2**b_h
    h_l = a.copy()
    if part:
        h_l[..., full] = a[..., full] & ((1 << part) - 1)
        h_l[..., full + 1:] = 0
    else:
        h_l[..., full:] = 0
    h = limbs_to_float64(h_l) / float(1 << plan.r)
    # g = value >> b_h
    g_l = _np_shift_right_bits(a, plan.b_h)
    g = limbs_to_float64(g_l) / float(1 << plan.r)
    g = g - plan.g_off * np.asarray(counts, np.float64)
    return g, h


def _np_shift_right_bits(a: np.ndarray, k: int) -> np.ndarray:
    limb_shift, bit_shift = divmod(k, limbs.RADIX_BITS)
    L = a.shape[-1]
    x = np.zeros_like(a)
    if limb_shift < L:
        x[..., : L - limb_shift] = a[..., limb_shift:]
    if bit_shift:
        nxt = np.zeros_like(x)
        nxt[..., :-1] = x[..., 1:]
        x = (x >> bit_shift) | ((nxt << (limbs.RADIX_BITS - bit_shift))
                                & limbs.LIMB_MASK)
    return x
