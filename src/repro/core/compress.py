"""Cipher compressing (paper §4.4, Algorithm 4).

Hosts pack up to ``eta_s = floor(iota / b_gh)`` split-info ciphertexts into
one by repeated homomorphic shift-and-add: ``e <- e * 2**b_gh + c``.  The
guest then performs a single decryption per package and unpacks ``eta_s``
histogram statistics from the plaintext, dividing decryption count and
transfer bytes by ``eta_s`` (eqs 15-16).

Works with any cipher suite (limb backends vectorize over whole batches;
the Paillier oracle loops).  Slot order: the FIRST ciphertext in a group is
most significant (Algorithm 4 shifts the accumulator before each add).
"""

from __future__ import annotations

import numpy as np


def compress_batch(cipher, cts, eta_s: int, b_slot: int):
    """Compress a batch of N ciphertexts into ceil(N / eta_s) packages.

    cts: for limb backends a (N, Ln) array; for pyobj an object array (N,).
    Returns (packages, group_sizes) where group_sizes[i] is how many source
    ciphertexts package i holds (the last group may be short).
    """
    if eta_s < 1:
        raise ValueError("eta_s must be >= 1")
    if cipher.backend == "limb":
        import jax.numpy as jnp
        cts = jnp.asarray(cts)
    n = cts.shape[0]
    n_groups = -(-n // eta_s)
    sizes = np.full(n_groups, eta_s, dtype=np.int64)
    if n % eta_s:
        sizes[-1] = n % eta_s

    if cipher.backend == "limb":
        import jax.numpy as jnp
        pad = n_groups * eta_s - n
        # pad with encrypted zeros at the END of the last group; they occupy
        # the LEAST significant slots, so real stats keep their positions iff
        # we also tell the guest the true group size (we do).  To keep slot
        # arithmetic simple we instead pad and report the padded size layout:
        # the guest unpacks eta_s slots and discards the trailing pad.
        if pad:
            # E(0) = 0 for both limb schemes; match the incoming width
            # (canonical histograms may carry headroom limbs).
            zero_ct = jnp.zeros((pad, cts.shape[-1]), cts.dtype)
            cts = jnp.concatenate([cts, zero_ct], axis=0)
        groups = cts.reshape(n_groups, eta_s, -1)
        acc = groups[:, 0, :]
        for s in range(1, eta_s):
            acc = cipher.mul_pow2(acc, b_slot)
            acc = cipher.add(acc, groups[:, s, :])
        return acc, sizes
    else:  # pyobj (Paillier oracle)
        cts = np.asarray(cts, dtype=object)
        packages = np.empty(n_groups, dtype=object)
        for gi in range(n_groups):
            grp = cts[gi * eta_s: gi * eta_s + int(sizes[gi])]
            acc = grp[0]
            for c in grp[1:]:
                acc = cipher.mul_pow2(np.asarray([acc], dtype=object), b_slot)[0]
                acc = cipher.add(np.asarray([acc], dtype=object),
                                 np.asarray([c], dtype=object))[0]
            packages[gi] = acc
        return packages, sizes


def decompress_ints(plain_ints, sizes, eta_s: int, b_slot: int,
                    padded: bool) -> list:
    """Unpack decrypted package ints back into per-split-info ints.

    ``padded`` says whether short groups were zero-padded to eta_s slots
    (limb backends) or built with their true size (pyobj backend).
    """
    out = []
    mask = (1 << b_slot) - 1
    for x, size in zip(plain_ints, np.asarray(sizes, dtype=np.int64)):
        x = int(x)
        slots_here = eta_s if padded else int(size)
        vals = []
        for _ in range(slots_here):
            vals.append(x & mask)
            x >>= b_slot
        vals.reverse()                  # first ciphertext was most significant
        out.extend(vals[: int(size)])
    return out
