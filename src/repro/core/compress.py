"""Cipher compressing (paper §4.4, Algorithm 4).

Hosts pack up to ``eta_s = floor(iota / b_gh)`` split-info ciphertexts into
one by repeated homomorphic shift-and-add: ``e <- e * 2**b_gh + c``.  The
guest then performs a single decryption per package and unpacks ``eta_s``
histogram statistics from the plaintext, dividing decryption count and
transfer bytes by ``eta_s`` (eqs 15-16).

Works with any cipher suite (limb backends vectorize over whole batches;
the Paillier oracle loops).  Slot order: the FIRST ciphertext in a group is
most significant (Algorithm 4 shifts the accumulator before each add).
"""

from __future__ import annotations

import numpy as np


def compress_batch(cipher, cts, eta_s: int, b_slot: int, mesh=None):
    """Compress a batch of N ciphertexts into ceil(N / eta_s) packages.

    cts: for limb backends a (N, Ln) array; for pyobj an object array (N,).
    Returns (packages, group_sizes) where group_sizes[i] is how many source
    ciphertexts package i holds (the last group may be short).

    ``mesh``: optional (data, model) jax Mesh — large batches shard the
    shift-and-add over the "data" axis (see :func:`_sharded_compress`);
    small ones keep the single-device path.
    """
    if eta_s < 1:
        raise ValueError("eta_s must be >= 1")
    if cipher.backend == "limb":
        import jax.numpy as jnp
        cts = jnp.asarray(cts)
    n = cts.shape[0]
    n_groups = -(-n // eta_s)
    sizes = np.full(n_groups, eta_s, dtype=np.int64)
    if n % eta_s:
        sizes[-1] = n % eta_s

    if cipher.backend == "limb":
        import jax.numpy as jnp
        pad = n_groups * eta_s - n
        # pad with encrypted zeros at the END of the last group; they occupy
        # the LEAST significant slots, so real stats keep their positions iff
        # we also tell the guest the true group size (we do).  To keep slot
        # arithmetic simple we instead pad and report the padded size layout:
        # the guest unpacks eta_s slots and discards the trailing pad.
        if pad:
            # E(0) = 0 for both limb schemes; match the incoming width
            # (canonical histograms may carry headroom limbs).
            zero_ct = jnp.zeros((pad, cts.shape[-1]), cts.dtype)
            cts = jnp.concatenate([cts, zero_ct], axis=0)
        groups = cts.reshape(n_groups, eta_s, -1)
        acc = _sharded_compress(cipher, groups, eta_s, b_slot, mesh)
        if acc is None:
            acc = groups[:, 0, :]
            for s in range(1, eta_s):
                acc = cipher.mul_pow2(acc, b_slot)
                acc = cipher.add(acc, groups[:, s, :])
        return acc, sizes
    else:  # pyobj (Paillier oracle)
        cts = np.asarray(cts, dtype=object)
        packages = np.empty(n_groups, dtype=object)
        for gi in range(n_groups):
            grp = cts[gi * eta_s: gi * eta_s + int(sizes[gi])]
            acc = grp[0]
            for c in grp[1:]:
                acc = cipher.mul_pow2(np.asarray([acc], dtype=object), b_slot)[0]
                acc = cipher.add(np.asarray([acc], dtype=object),
                                 np.asarray([c], dtype=object))[0]
            packages[gi] = acc
        return packages, sizes


def _sharded_compress(cipher, groups, eta_s: int, b_slot: int, mesh):
    """Mesh-sharded shift-and-add over the package axis.

    Every homomorphic op in Algorithm 4 (``mul_pow2`` then ``add``, slot by
    slot) is row-wise over packages, so sharding the group axis over "data"
    runs the whole compress with NO collective and stays bit-identical to
    the single-device loop.  Gated exactly like the sharded decrypt/cumsum
    paths: shard only when every data shard gets at least one full kernel
    row block (``n_groups >= BLOCK_N * data_shards``); returns None below
    the gate and the caller falls back."""
    if mesh is None:
        return None
    dd = dict(mesh.shape).get("data", 1)
    G = int(groups.shape[0])
    from ..kernels.modmul.modmul import BLOCK_N
    if dd <= 1 or G < BLOCK_N * dd:
        return None
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..parallel.sharding import data_pad, gbdt_sharding
    # pow2 bucketing caps distinct shard_map compilations at O(log max_G)
    bucket = 1 << max(G - 1, 0).bit_length()
    bucket += data_pad(mesh, bucket)
    x = groups
    if bucket > G:
        # pad groups are all-zero ciphertexts: E(0) shift-and-adds to E(0)
        x = jnp.pad(x, [(0, bucket - G), (0, 0), (0, 0)])
    x = jax.device_put(x, gbdt_sharding(mesh, "split_infos", ndim=3))

    def shard(xs):
        acc = xs[:, 0, :]
        for s in range(1, eta_s):
            acc = cipher.mul_pow2(acc, b_slot)
            acc = cipher.add(acc, xs[:, s, :])
        return acc

    out = shard_map(shard, mesh=mesh, in_specs=P("data", None, None),
                    out_specs=P("data", None), check_rep=False)(x)
    # land on one device before the decrypt consumer (jax-0.4.37 eager-
    # mixing caveat, see kernels/histogram/ops.py)
    return jax.device_put(out[:G], jax.devices()[0])


def decompress_ints(plain_ints, sizes, eta_s: int, b_slot: int,
                    padded: bool) -> list:
    """Unpack decrypted package ints back into per-split-info ints.

    ``padded`` says whether short groups were zero-padded to eta_s slots
    (limb backends) or built with their true size (pyobj backend).
    """
    out = []
    mask = (1 << b_slot) - 1
    for x, size in zip(plain_ints, np.asarray(sizes, dtype=np.int64)):
        x = int(x)
        slots_here = eta_s if padded else int(size)
        vals = []
        for _ in range(slots_here):
            vals.append(x & mask)
            x >>= b_slot
        vals.reverse()                  # first ciphertext was most significant
        out.extend(vals[: int(size)])
    return out
