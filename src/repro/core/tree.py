"""Layer-wise federated decision-tree growth (paper §2.3, Algorithms 1-6).

One function, :func:`grow_tree`, implements the whole node-splitting
protocol with every optimization toggleable (so the legacy SecureBoost
baseline and every ablation in the paper's figures run through the same
code):

  * GH packing on/off        (packed single ciphertext vs separate [[g]],[[h]])
  * histogram subtraction    (compute smaller child, sibling = parent - child)
  * cipher compressing       (eta_s split-infos per decrypted package)
  * sparse-aware histograms  (zero-bin recovery)
  * MO trees                 (vector g/h, multi-class packing)
  * mix / layered modes      (via the ``feature_parties`` schedule callback)

Party boundaries are explicit: everything that crosses guest<->host goes
through ``ctx.channel.send`` with wire-fidelity byte counts, and HE work is
tallied in ``ctx.stats``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from . import compress as compress_mod
from . import encoding, mo_encoding
from .binning import BinnedData
from .he import limbs
from .histogram import CipherHistogram, PlainHistogram
from .party import Channel, Stats, ct_wire_bytes
from .split import (BestSplit, SplitCandidates, candidates_from_cumsum,
                    decode_sid, find_best_split, leaf_weight)

GUEST = -1
LEAF = -2


# ---------------------------------------------------------------------------
# GH codecs: how (g, h) become plaintexts and come back as sums
# ---------------------------------------------------------------------------

class PackedCodec:
    """SecureBoost+ default: one packed plaintext per instance (Alg 3/6)."""

    def __init__(self, plan: encoding.PackingPlan):
        self.plan = plan
        self.n_slots = 1
        self.compressible = True
        self.b_slot = plan.b_gh
        self.eta_s = plan.compress_capacity

    def encode_plain(self, g, h) -> np.ndarray:
        return encoding.pack_gh(g, h, self.plan)[:, None, :]   # (n, 1, Lp)

    def decode(self, ints: np.ndarray, counts: np.ndarray):
        g_l = np.empty(len(counts)); h_l = np.empty(len(counts))
        for i, (row, c) in enumerate(zip(ints, counts)):
            g_l[i], h_l[i] = encoding.unpack_gh_int(int(row[0]), self.plan, int(c))
        return g_l, h_l


class NoPackCodec:
    """Legacy SecureBoost: separate [[g]] and [[h]] ciphertexts."""

    def __init__(self, r: int, g_off: float):
        self.r = r
        self.g_off = g_off
        self.n_slots = 2
        self.compressible = False

    @classmethod
    def plan(cls, g, r: int = encoding.DEFAULT_PRECISION):
        return cls(r=r, g_off=float(max(0.0, -float(np.min(g))))
                   if np.size(g) else 0.0)

    def encode_plain(self, g, h) -> np.ndarray:
        g_int = encoding.encode_int64(np.asarray(g, np.float64) + self.g_off, self.r)
        h_int = encoding.encode_int64(h, self.r)
        L = limbs.num_limbs_for_bits(70)
        out = np.stack([encoding._int64_to_limbs(g_int, L),
                        encoding._int64_to_limbs(h_int, L)], axis=1)
        return out                                              # (n, 2, L)

    def decode(self, ints: np.ndarray, counts: np.ndarray):
        scale = float(1 << self.r)
        g_l = np.asarray([int(r[0]) for r in ints], np.float64) / scale \
            - self.g_off * np.asarray(counts, np.float64)
        h_l = np.asarray([int(r[1]) for r in ints], np.float64) / scale
        return g_l, h_l


class MOCodec:
    """SecureBoost-MO: vector g/h packed across classes (Alg 7/8)."""

    def __init__(self, plan: mo_encoding.MOPackingPlan):
        self.plan = plan
        self.n_slots = plan.n_k
        self.compressible = False    # paper §7.3.2: compress disabled for MO

    def encode_plain(self, G, H) -> np.ndarray:
        return mo_encoding.pack_gh_mo(G, H, self.plan)          # (n, n_k, Lp)

    def decode(self, ints: np.ndarray, counts: np.ndarray):
        l = self.plan.n_classes
        g_l = np.empty((len(counts), l)); h_l = np.empty((len(counts), l))
        for i, (row, c) in enumerate(zip(ints, counts)):
            g_l[i], h_l[i] = mo_encoding.unpack_gh_mo_ints(
                [int(x) for x in row], self.plan, int(c))
        return g_l, h_l


# ---------------------------------------------------------------------------
# runtime state
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Node:
    nid: int
    depth: int
    party: int = LEAF            # GUEST / host id / LEAF
    fid: int = -1                # guest splits only (host fids stay private)
    bid: int = -1
    sid: int = -1                # host splits: shuffled id (host resolves)
    left: int = -1
    right: int = -1
    weight: np.ndarray | float | None = None
    gain: float = 0.0
    n_rows: int = 0

    @property
    def is_leaf(self) -> bool:
        return self.party == LEAF


@dataclasses.dataclass
class FederatedTree:
    nodes: list
    host_tables: list            # per host: {nid: (fid, bid)} -- host-private


@dataclasses.dataclass
class HostRuntime:
    hid: int
    data: BinnedData
    engine: CipherHistogram
    cts: object = None           # (n_sel, n_slots, L) limbs / (n_sel, n_slots) obj
    view: BinnedData | None = None   # rows restricted to the GOSS selection,
                                     # aligned with cts (host derives it from
                                     # the synced selected-id list)
    hist_cache: dict = dataclasses.field(default_factory=dict)
    perms: dict = dataclasses.field(default_factory=dict)
    table: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class TreeContext:
    params: object               # SBTParams (see boosting.py)
    cipher: object
    codec: object
    channel: Channel
    stats: Stats
    guest_data: BinnedData
    g: np.ndarray                # (n,) or (n, l), GOSS-weighted
    h: np.ndarray
    sel_rows: np.ndarray         # GOSS-selected row ids (into full set)
    hosts: list = dataclasses.field(default_factory=list)
    rng: np.random.Generator = dataclasses.field(
        default_factory=lambda: np.random.default_rng(0))


def _encrypt_all(ctx: TreeContext) -> None:
    """Guest packs + encrypts g/h of selected rows, broadcasts to hosts."""
    p = ctx.params
    plain = ctx.codec.encode_plain(ctx.g[ctx.sel_rows], ctx.h[ctx.sel_rows])
    n, s, Lp = plain.shape
    if ctx.cipher.backend == "limb":
        import jax.numpy as jnp
        from ..kernels.modmul import encrypt_batch
        if ctx.cipher.name == "affine" and p.use_pallas:
            flat = encrypt_batch(ctx.cipher, plain.reshape(n * s, Lp))
        else:
            import jax.numpy as jnp
            flat = ctx.cipher.encrypt_limbs(jnp.asarray(plain.reshape(n * s, Lp)))
        cts = flat.reshape(n, s, -1)
    else:
        ints = limbs.to_pyints(plain.reshape(n * s, Lp))
        cts = ctx.cipher.encrypt_ints(ints).reshape(n, s)
    ctx.stats.n_encrypt += n * s
    nbytes = n * s * ct_wire_bytes(ctx.cipher) + n * 4   # + selected row ids
    for host in ctx.hosts:
        host.cts = ctx.channel.send("guest", f"host{host.hid}", "enc_gh",
                                    cts, nbytes)
        # host restricts its binned matrix to the synced selected ids so row
        # positions align with the ciphertext batch
        host.view = dataclasses.replace(
            host.data, bins=host.data.bins[ctx.sel_rows],
            zero_mask=(host.data.zero_mask[ctx.sel_rows]
                       if host.data.zero_mask is not None else None))


def _host_candidates(ctx: TreeContext, host: HostRuntime, nid: int,
                     rows_sel: np.ndarray, mode: str, parent_nid: int = -1,
                     sibling_nid: int = -1) -> SplitCandidates:
    """Host-side Algorithm 5: histogram (direct or by subtraction), cumsum,
    shuffle, compress, send; guest-side decrypt + decode into candidates."""
    p = ctx.params
    engine = host.engine
    n_f, n_b = host.data.n_features, p.n_bins
    n_slots = ctx.codec.n_slots

    if mode == "subtract" and (parent_nid not in host.hist_cache
                               or sibling_nid not in host.hist_cache):
        mode = "direct"          # sibling exited early as a leaf
    if mode == "subtract":
        parent = host.hist_cache[parent_nid]
        child = host.hist_cache[sibling_nid]
        hist, counts = engine.subtract(parent, child)
        ctx.stats.n_hom_add += n_f * n_b * n_slots
    else:
        hist, counts = engine.node_histogram(host.view, host.cts, rows_sel)
        ctx.stats.n_hom_add += int(counts.sum()) * n_slots
    host.hist_cache[nid] = (hist, counts)

    cum = engine.cumsum(hist)
    ctx.stats.n_hom_add += n_f * (n_b - 1) * n_slots
    cum_counts = counts.cumsum(axis=1)

    # flatten to split infos, drop last bin (empty right side)
    if ctx.cipher.backend == "limb":
        import jax.numpy as jnp
        flat = jnp.asarray(cum)[:, : n_b - 1].reshape(n_f * (n_b - 1), n_slots, -1)
    else:
        flat = cum[:, : n_b - 1].reshape(n_f * (n_b - 1), n_slots)
    flat_counts = cum_counts[:, : n_b - 1].reshape(-1)
    m = flat.shape[0]
    ctx.stats.n_split_infos += m

    # real sids use the same fid*n_b+bid encoding as decode_sid
    fid_grid, bid_grid = np.meshgrid(np.arange(n_f), np.arange(n_b - 1),
                                     indexing="ij")
    real_sids = (fid_grid * n_b + bid_grid).reshape(-1)
    perm = ctx.rng.permutation(m)
    host.perms[nid] = real_sids[perm]      # shuffled position -> real sid
    if ctx.cipher.backend == "limb":
        import jax.numpy as jnp
        flat = flat[jnp.asarray(perm)]
    else:
        flat = flat[perm]
    flat_counts = flat_counts[perm]

    wire = ct_wire_bytes(ctx.cipher)
    use_compress = (p.compression and ctx.codec.compressible
                    and ctx.codec.eta_s > 1)
    if use_compress:
        eta = ctx.codec.eta_s
        if ctx.cipher.backend == "limb":
            src = flat[:, 0, :]
        else:
            src = flat[:, 0]
        pkgs, sizes = compress_mod.compress_batch(
            ctx.cipher, src, eta, ctx.codec.b_slot)
        n_pkgs = len(sizes)
        ctx.stats.n_hom_scalar += int(np.sum(sizes - 1))
        ctx.stats.n_hom_add += int(np.sum(sizes - 1))
        payload = (pkgs, sizes, flat_counts)
        nbytes = n_pkgs * wire + m * 8
        ctx.stats.n_packages += n_pkgs
    else:
        payload = (flat, None, flat_counts)
        nbytes = m * n_slots * wire + m * 8
        ctx.stats.n_packages += m * n_slots
    payload = ctx.channel.send(f"host{host.hid}", "guest", "split_infos",
                               payload, nbytes)

    # ---- guest side: decrypt + decode (Algorithm 6) ----
    data, sizes, counts_l = payload
    if use_compress:
        plain = _decrypt_ints(ctx, data)
        ctx.stats.n_decrypt += len(plain)
        vals = compress_mod.decompress_ints(
            plain, sizes, ctx.codec.eta_s, ctx.codec.b_slot,
            padded=(ctx.cipher.backend == "limb"))
        rows = np.asarray(vals, dtype=object).reshape(m, 1)
    else:
        if ctx.cipher.backend == "limb":
            flat2 = np.asarray(data).reshape(m * n_slots, -1)
        else:
            flat2 = data.reshape(m * n_slots)
        plain = _decrypt_ints(ctx, flat2)
        ctx.stats.n_decrypt += m * n_slots
        rows = np.asarray(plain, dtype=object).reshape(m, n_slots)
    g_l, h_l = ctx.codec.decode(rows, counts_l)
    return SplitCandidates(party=host.hid, sid=np.arange(m), g_l=g_l, h_l=h_l,
                           cnt_l=counts_l)


def _decrypt_ints(ctx: TreeContext, cts) -> list:
    if ctx.cipher.backend == "limb":
        import jax.numpy as jnp
        if ctx.cipher.name == "affine" and ctx.params.use_pallas:
            from ..kernels.modmul import decrypt_batch
            pl_limbs = decrypt_batch(ctx.cipher, jnp.asarray(cts))
            return limbs.to_pyints(np.asarray(pl_limbs))
        return ctx.cipher.decrypt_to_ints(jnp.asarray(cts))
    return ctx.cipher.decrypt_to_ints(cts)


def _guest_candidates(ctx: TreeContext, plain_engine: PlainHistogram,
                      cache: dict, nid: int, rows_sel: np.ndarray, mode: str,
                      parent_nid: int = -1, sibling_nid: int = -1):
    if mode == "subtract" and (parent_nid not in cache
                               or sibling_nid not in cache):
        mode = "direct"
    if mode == "subtract":
        hist = plain_engine.subtract(cache[parent_nid], cache[sibling_nid])
    else:
        hist = plain_engine.node_histogram(ctx.guest_data, ctx.g, ctx.h,
                                           rows_sel)
    cache[nid] = hist
    Gc, Hc, Cc = plain_engine.cumsum(hist)
    return candidates_from_cumsum(Gc, Hc, Cc, party=GUEST)


# ---------------------------------------------------------------------------
# the grower
# ---------------------------------------------------------------------------

def grow_tree(ctx: TreeContext,
              feature_parties: Callable[[int], tuple] | None = None
              ) -> FederatedTree:
    """Grow one federated tree.  ``feature_parties(depth) -> (use_guest,
    host_ids)`` schedules which parties contribute split candidates at each
    depth (mix / layered modes); default: everyone, every depth."""
    p = ctx.params
    if feature_parties is None:
        feature_parties = lambda d: (True, [h.hid for h in ctx.hosts])

    any_host = any(feature_parties(d)[1] for d in range(p.max_depth))
    if any_host:
        _encrypt_all(ctx)

    plain_engine = PlainHistogram(p.n_bins, sparse=p.sparse)
    guest_cache: dict = {}

    n_all = ctx.guest_data.n_instances
    nodes = [Node(nid=0, depth=0, n_rows=n_all)]
    rows_all = {0: np.arange(n_all)}
    rows_sel = {0: np.arange(len(ctx.sel_rows))}   # positions into sel arrays
    hist_mode = {0: ("direct", -1, -1)}

    frontier = [0]
    for depth in range(p.max_depth):
        use_guest, host_ids = feature_parties(depth)
        active_hosts = [h for h in ctx.hosts if h.hid in host_ids]
        next_frontier = []
        # order: direct nodes before subtract nodes (siblings first)
        ordered = [n for n in frontier if hist_mode[n][0] == "direct"] + \
                  [n for n in frontier if hist_mode[n][0] == "subtract"]
        # sync one assignment vector per layer to hosts that participate
        if active_hosts:
            node_of = np.full(len(ctx.sel_rows), -1, np.int32)
            for nid in frontier:
                node_of[rows_sel[nid]] = nid
            for h in active_hosts:
                ctx.channel.send("guest", f"host{h.hid}", "assign_sync",
                                 node_of, node_of.size * 4)

        for nid in ordered:
            node = nodes[nid]
            rs = rows_sel[nid]
            mode, par, sib = hist_mode[nid]
            if not p.histogram_subtraction:
                mode, par, sib = "direct", -1, -1

            gsel = ctx.g[ctx.sel_rows][rs]
            hsel = ctx.h[ctx.sel_rows][rs]
            G_tot = gsel.sum(axis=0)
            H_tot = hsel.sum(axis=0)

            if len(rs) < 2 * p.min_leaf or len(rs) == 0:
                node.weight = leaf_weight(G_tot, H_tot, p.lam, p.learning_rate)
                continue

            cands = []
            if use_guest and ctx.guest_data.n_features > 0:
                cands.append(_guest_candidates(
                    ctx, plain_engine, guest_cache, nid, ctx.sel_rows[rs],
                    mode, par, sib))
            for h in active_hosts:
                cands.append(_host_candidates(ctx, h, nid, rs, mode, par, sib))

            best = find_best_split(cands, G_tot, H_tot, len(rs), p.lam,
                                   p.min_leaf, p.min_gain)
            if best is None:
                node.weight = leaf_weight(G_tot, H_tot, p.lam, p.learning_rate)
                continue

            # resolve the split owner + instance assignment
            ra = rows_all[nid]
            fsel = ctx.sel_rows[rs]                 # full ids of selected rows
            if best.party == GUEST:
                fid, bid = decode_sid(best.sid, p.n_bins)
                go_left = ctx.guest_data.bins[ra, fid] <= bid
                go_left_sel = ctx.guest_data.bins[fsel, fid] <= bid
                node.party, node.fid, node.bid = GUEST, fid, bid
            else:
                host = next(h for h in ctx.hosts if h.hid == best.party)
                ctx.channel.send("guest", f"host{host.hid}", "chosen_sid",
                                 (nid, best.sid), 8)
                real_sid = int(host.perms[nid][best.sid])
                fid, bid = decode_sid(real_sid, p.n_bins)
                host.table[nid] = (fid, bid)
                go_left = host.data.bins[ra, fid] <= bid
                go_left_sel = host.data.bins[fsel, fid] <= bid
                ctx.channel.send(f"host{host.hid}", "guest", "assign_mask",
                                 go_left, (len(go_left) + 7) // 8)
                node.party, node.sid = host.hid, best.sid
            node.gain = best.gain

            lid, rid = len(nodes), len(nodes) + 1
            node.left, node.right = lid, rid
            rows_all[lid], rows_all[rid] = ra[go_left], ra[~go_left]
            rows_sel[lid], rows_sel[rid] = rs[go_left_sel], rs[~go_left_sel]
            nodes.append(Node(nid=lid, depth=depth + 1, n_rows=len(rows_all[lid])))
            nodes.append(Node(nid=rid, depth=depth + 1, n_rows=len(rows_all[rid])))
            # subtraction schedule: smaller child direct, sibling subtracts
            if len(rows_sel[lid]) <= len(rows_sel[rid]):
                hist_mode[lid] = ("direct", -1, -1)
                hist_mode[rid] = ("subtract", nid, lid)
            else:
                hist_mode[rid] = ("direct", -1, -1)
                hist_mode[lid] = ("subtract", nid, rid)
            next_frontier += [lid, rid]
        # free parent histograms no longer needed
        for nid in frontier:
            guest_cache.pop(hist_mode[nid][1], None)
            for h in ctx.hosts:
                h.hist_cache.pop(hist_mode[nid][1], None)
        frontier = next_frontier

    # finalize leaves at max depth
    for node in nodes:
        if node.left == -1 and node.weight is None:
            rs = rows_sel[node.nid]
            gsel = ctx.g[ctx.sel_rows][rs]
            hsel = ctx.h[ctx.sel_rows][rs]
            node.weight = leaf_weight(gsel.sum(axis=0), hsel.sum(axis=0),
                                      p.lam, p.learning_rate)

    # leaf row assignment for the score update
    leaf_rows = {n.nid: rows_all[n.nid] for n in nodes if n.left == -1}
    tree = FederatedTree(nodes=nodes,
                         host_tables=[h.table for h in ctx.hosts])
    tree.leaf_rows = leaf_rows
    return tree


def predict_tree(tree: FederatedTree, guest_bins: np.ndarray,
                 host_bins: list) -> np.ndarray:
    """Route binned instances through the tree (simulation: reads host
    tables directly; the real protocol does the same lookups host-side)."""
    n = guest_bins.shape[0]
    first = next(nd for nd in tree.nodes if nd.weight is not None)
    w0 = np.asarray(first.weight)
    out = np.zeros((n,) + w0.shape)
    node_of = np.zeros(n, np.int64)
    changed = True
    while changed:
        changed = False
        for nd in tree.nodes:
            if nd.left == -1:
                continue
            sel = node_of == nd.nid
            if not sel.any():
                continue
            if nd.party == GUEST:
                go_left = guest_bins[sel, nd.fid] <= nd.bid
            else:
                fid, bid = tree.host_tables[nd.party][nd.nid]
                go_left = host_bins[nd.party][sel, fid] <= bid
            ids = np.where(sel)[0]
            node_of[ids[go_left]] = nd.left
            node_of[ids[~go_left]] = nd.right
            changed = True
    for nd in tree.nodes:
        if nd.left == -1 and nd.weight is not None:
            out[node_of == nd.nid] = nd.weight
    return out
