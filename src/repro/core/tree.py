"""Layer-wise federated decision-tree growth (paper §2.3, Algorithms 1-6).

One function, :func:`grow_tree`, implements the whole node-splitting
protocol with every optimization toggleable (so the legacy SecureBoost
baseline and every ablation in the paper's figures run through the same
code):

  * GH packing on/off        (packed single ciphertext vs separate [[g]],[[h]])
  * histogram subtraction    (compute smaller child, sibling = parent - child)
  * cipher compressing       (eta_s split-infos per decrypted package)
  * sparse-aware histograms  (zero-bin recovery)
  * MO trees                 (vector g/h, multi-class packing)
  * mix / layered modes      (via the ``feature_parties`` schedule callback)

The hot path is *layer-batched* (DESIGN.md §6): per (layer, host) pair the
protocol performs ONE histogram kernel launch covering every direct-mode
frontier node, ONE ``cipher.reduce``, ONE ciphertext cumsum, and ONE
``split_infos`` message answered by ONE batched guest decrypt -- all nodes'
shuffled candidates travel concatenated, with per-node offsets implied by
the fixed per-node candidate count.  Kernel launches and round-trips per
tree are therefore O(depth), not O(2**depth); ``Stats.n_hist_launches`` /
``Stats.n_split_roundtrips`` make the collapse measurable.

Layer state is *device-resident* (DESIGN.md §7): each host builds a
``CipherFrontier`` per tree (bins masked + ciphertexts width-padded once,
parent histograms cached as device arrays) and, when the engine carries a
(data, model) mesh, the single layer dispatch is ``shard_map``-sharded with
a lazy-limb psum over instance shards -- bit-identical to one device.

Party boundaries are explicit: everything that crosses guest<->host goes
through ``ctx.channel.send`` with wire-fidelity byte counts, and HE work is
tallied in ``ctx.stats``.

Host-side protocol logic lives in :class:`HostRuntime` (DESIGN.md §10):
every guest->host message is a *serializable* payload (numpy/limb tensors,
ints, small dicts — never live Python objects), handled by
``HostRuntime.deliver(tag, payload)``, and every host->guest reply is
emitted through ``channel.send`` and picked up with ``collect(tag)``.  The
grower only talks to hosts through this tagged-message surface, so the same
code runs in-process (``HostRuntime`` is the handle, the shared
:class:`Channel` is the ledger) or one-party-per-OS-process
(``runtime/transport.py`` ships the identical payloads over a
length-prefixed socket and the handle becomes a ``RemoteHostHandle``) —
bit-identically, with identical per-tag ledgers.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from ..analysis import schema as wire
from . import compress as compress_mod
from . import encoding, mo_encoding
from .binning import BinnedData
from .frontier import CipherFrontier, CtsBlocks, GuestFrontier
from .he import limbs
from .histogram import GID_STRIDE, CipherHistogram, PlainHistogram
from .party import Channel, Stats, ct_wire_bytes
from .split import (BestSplit, SplitCandidates, candidates_from_cumsum,
                    decode_sid, find_best_split, leaf_weight)

GUEST = -1
LEAF = -2


# ---------------------------------------------------------------------------
# GH codecs: how (g, h) become plaintexts and come back as sums
# ---------------------------------------------------------------------------

class PackedCodec:
    """SecureBoost+ default: one packed plaintext per instance (Alg 3/6)."""

    def __init__(self, plan: encoding.PackingPlan):
        self.plan = plan
        self.n_slots = 1
        self.compressible = True
        self.b_slot = plan.b_gh
        self.eta_s = plan.compress_capacity

    def encode_plain(self, g, h) -> np.ndarray:
        return encoding.pack_gh(g, h, self.plan)[:, None, :]   # (n, 1, Lp)

    def decode(self, ints: np.ndarray, counts: np.ndarray):
        g_l = np.empty(len(counts)); h_l = np.empty(len(counts))
        for i, (row, c) in enumerate(zip(ints, counts)):
            g_l[i], h_l[i] = encoding.unpack_gh_int(int(row[0]), self.plan, int(c))
        return g_l, h_l


class NoPackCodec:
    """Legacy SecureBoost: separate [[g]] and [[h]] ciphertexts."""

    def __init__(self, r: int, g_off: float):
        self.r = r
        self.g_off = g_off
        self.n_slots = 2
        self.compressible = False

    @classmethod
    def plan(cls, g, r: int = encoding.DEFAULT_PRECISION):
        return cls(r=r, g_off=float(max(0.0, -float(np.min(g))))
                   if np.size(g) else 0.0)

    def encode_plain(self, g, h) -> np.ndarray:
        g_int = encoding.encode_int64(np.asarray(g, np.float64) + self.g_off, self.r)
        h_int = encoding.encode_int64(h, self.r)
        L = limbs.num_limbs_for_bits(70)
        out = np.stack([encoding._int64_to_limbs(g_int, L),
                        encoding._int64_to_limbs(h_int, L)], axis=1)
        return out                                              # (n, 2, L)

    def decode(self, ints: np.ndarray, counts: np.ndarray):
        scale = float(1 << self.r)
        g_l = np.asarray([int(r[0]) for r in ints], np.float64) / scale \
            - self.g_off * np.asarray(counts, np.float64)
        h_l = np.asarray([int(r[1]) for r in ints], np.float64) / scale
        return g_l, h_l


class MOCodec:
    """SecureBoost-MO: vector g/h packed across classes (Alg 7/8)."""

    def __init__(self, plan: mo_encoding.MOPackingPlan):
        self.plan = plan
        self.n_slots = plan.n_k
        self.compressible = False    # paper §7.3.2: compress disabled for MO

    def encode_plain(self, G, H) -> np.ndarray:
        return mo_encoding.pack_gh_mo(G, H, self.plan)          # (n, n_k, Lp)

    def decode(self, ints: np.ndarray, counts: np.ndarray):
        l = self.plan.n_classes
        g_l = np.empty((len(counts), l)); h_l = np.empty((len(counts), l))
        for i, (row, c) in enumerate(zip(ints, counts)):
            g_l[i], h_l[i] = mo_encoding.unpack_gh_mo_ints(
                [int(x) for x in row], self.plan, int(c))
        return g_l, h_l


# ---------------------------------------------------------------------------
# runtime state
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Node:
    nid: int
    depth: int
    party: int = LEAF            # GUEST / host id / LEAF
    fid: int = -1                # guest splits only (host fids stay private)
    bid: int = -1
    sid: int = -1                # host splits: shuffled id (host resolves)
    left: int = -1
    right: int = -1
    weight: np.ndarray | float | None = None
    gain: float = 0.0
    n_rows: int = 0

    @property
    def is_leaf(self) -> bool:
        return self.party == LEAF


@dataclasses.dataclass
class FederatedTree:
    nodes: list
    host_tables: list            # per host: {nid: (fid, bid)} -- host-private

    def node_arrays(self) -> dict:
        """Flat per-node arrays for the serving packer (serving/packed.py).

        Returns structure (party/left/right/depth), the guest's own
        (fid, bid) pairs, and the (n_nodes, w_dim) leaf-weight matrix
        (zeros at internal nodes).  Host split tables stay in
        ``host_tables`` — they are exported by the *host* half only.
        Deliberately contains nothing row-level: a packed model must be
        shippable to a serving process with no training-set residue.
        """
        nodes = self.nodes
        n = len(nodes)
        party = np.fromiter((nd.party for nd in nodes), np.int32, n)
        left = np.fromiter((nd.left for nd in nodes), np.int32, n)
        right = np.fromiter((nd.right for nd in nodes), np.int32, n)
        depth = np.fromiter((nd.depth for nd in nodes), np.int32, n)
        fid = np.fromiter((nd.fid for nd in nodes), np.int32, n)
        bid = np.fromiter((nd.bid for nd in nodes), np.int32, n)
        first_w = next(np.asarray(nd.weight, np.float64)
                       for nd in nodes if nd.weight is not None)
        weight = np.zeros((n, first_w.size), np.float64)
        for nd in nodes:
            if nd.weight is not None:
                weight[nd.nid] = np.asarray(nd.weight,
                                            np.float64).reshape(-1)
        return {"party": party, "left": left, "right": right,
                "depth": depth, "fid": fid, "bid": bid, "weight": weight}

    def signature(self) -> tuple:
        """Hashable, exact digest of the tree: structure, guest splits,
        host shuffled split ids, and the raw float64 leaf-weight bits.
        Two trees are bit-identical iff their signatures are equal — the
        equality the fault-tolerant runtime's replay guarantee is stated
        in (a resumed run must produce THIS tuple, not merely a close
        one), and what the chaos suite asserts against the fault-free
        oracle."""
        return tuple(
            (nd.nid, nd.depth, nd.party, nd.fid, nd.bid, nd.sid,
             nd.left, nd.right,
             None if nd.weight is None else
             np.asarray(nd.weight, np.float64).tobytes())
            for nd in self.nodes)


@dataclasses.dataclass
class HostRuntime:
    """One host party: private data + the host side of the protocol.

    In-process, the instance doubles as the guest's handle — ``deliver``
    runs the handler synchronously and ``collect`` pops the reply the
    handler emitted.  Under ``runtime/transport.py`` the same instance runs
    inside the host's own OS process, driven by decoded socket frames, and
    the guest holds a ``RemoteHostHandle`` with the identical
    deliver/collect surface.  All handler inputs and all replies are
    serializable (numpy/limb tensors + plain python), never shared live
    objects.
    """

    hid: int
    data: BinnedData
    engine: object               # CipherHistogram (fresh per tree)
    cts: object = None           # (n_sel, n_slots, L) limbs / (n_sel, n_slots) obj
    frontier: CipherFrontier | None = None   # device-resident layer state:
                                     # the GOSS-selected view + padded cts +
                                     # parent-histogram cache (DESIGN.md §7)
    perms: dict = dataclasses.field(default_factory=dict)
    table: dict = dataclasses.field(default_factory=dict)
    params: object = None        # wired by bind()
    cipher: object = None
    channel: Channel | None = None
    stats: Stats | None = None
    codec: object = None         # packing view from the enc_gh payload
    shuffle_rng: object = None   # host-PRIVATE split-id shuffle stream
    table_sinks: dict | None = None   # round-forest demux: member ->
                                 # per-member split table mirror (wired by a
                                 # PartyProcess so serving export sees local
                                 # nids per member tree; None in-process)
    _outbox: dict = dataclasses.field(default_factory=dict)
    _asm: dict | None = None     # in-flight chunked enc_gh assembly (§13)

    # -- wiring ---------------------------------------------------------
    def bind(self, params, cipher, channel, stats) -> None:
        """Attach the run context.  In-process these are the guest's own
        objects (one shared ledger/stats, as always); in a PartyProcess
        they are the host's private instances."""
        self.params, self.cipher = params, cipher
        self.channel, self.stats = channel, stats

    def deliver(self, tag: str, payload) -> None:
        {wire.ENC_GH: self.begin_tree,
         wire.ASSIGN_SYNC: self.on_assign_sync,
         wire.CHOSEN_SID: self.on_chosen_sid}[tag](payload)

    def collect(self, tag: str):
        """Pop the pending reply the last handler emitted for ``tag``."""
        return self._outbox[tag].pop(0)

    def _reply(self, tag: str, payload, nbytes: int) -> None:
        self.channel.send(f"host{self.hid}", "guest", tag, payload, nbytes)
        self._outbox.setdefault(tag, []).append(payload)

    # -- handlers (Algorithm 5, host side) ------------------------------
    def begin_tree(self, msg: dict) -> None:
        """enc_gh: adopt the encrypted GH batch, restrict the binned view
        to the synced selected ids so row positions align with the
        ciphertext batch, and build the device-resident frontier.

        A chunked frame (``"blk" in msg``, DESIGN.md §13) carries one row
        block of the batch; blocks assemble host-side into a compact uint8
        :class:`CtsBlocks` and the frontier is built in stream mode once
        the last block lands.  blk 0 is the replay anchor: a re-delivered
        sequence restarts assembly idempotently, matching the monolithic
        frame's re-delivery semantics."""
        if "blk" in msg:
            self._begin_tree_block(msg)
            return
        sel = np.asarray(msg["sel_rows"])
        self._adopt_tree(msg, sel, msg["cts"])

    def _begin_tree_block(self, msg: dict) -> None:
        b = int(msg["blk"])
        tree = int(msg["tree"])
        if b == 0:
            cts0 = np.asarray(msg["cts"])
            n = int(msg["n_rows"])
            self._asm = {
                "tree": tree, "msg0": msg,
                "sel": np.zeros(n, np.int64),
                "blocks": CtsBlocks(n, cts0.shape[1], cts0.shape[2],
                                    int(msg["row_block"])),
            }
        elif self._asm is None or self._asm["tree"] != tree:
            return        # duplicate mid-tree block after completion: drop
        asm = self._asm
        sel_blk = np.asarray(msg["sel_rows"])
        start = b * asm["blocks"].block
        asm["sel"][start: start + len(sel_blk)] = sel_blk
        asm["blocks"].set_block(b, np.asarray(msg["cts"], np.uint8))
        if asm["blocks"].complete:
            self._asm = None
            self._adopt_tree(asm["msg0"], asm["sel"], asm["blocks"])

    def _adopt_tree(self, msg: dict, sel: np.ndarray, cts) -> None:
        import types
        self.codec = types.SimpleNamespace(**msg["codec"])
        # host-private shuffle stream: deterministic per (seed, tree, hid)
        # so an in-process run and a process-per-party run permute split
        # ids identically without the stream ever crossing the wire
        self.shuffle_rng = np.random.default_rng(
            (int(msg["seed"]), 23, int(msg["tree"]), self.hid))
        self.cts = cts
        self.perms = {}
        self.table = {}
        n_all = self.data.bins.shape[0]
        if isinstance(cts, CtsBlocks) and len(sel) == n_all \
                and np.array_equal(sel, np.arange(n_all, dtype=sel.dtype)):
            # identity selection (no GOSS): skip the O(rows) fancy-index
            # copy of the compact bin matrix in stream mode
            view = self.data
        else:
            view = dataclasses.replace(
                self.data, bins=self.data.bins[sel],
                zero_mask=(self.data.zero_mask[sel]
                           if self.data.zero_mask is not None else None))
        self.frontier = CipherFrontier(self.engine, view, self.cts,
                                       channel=self.channel,
                                       party=f"host{self.hid}")
        if self.stats is not None:
            self.stats.n_cts_placements += self.frontier.n_cts_placements

    def on_assign_sync(self, plan: dict) -> None:
        """One layer, batched: one histogram accumulation, one
        ``cipher.reduce``, one ciphertext cumsum, one shuffle/compress
        pass, ONE ``split_infos`` reply.  On limb backends everything is
        async dispatch — in-process the guest's plaintext histograms run
        while this work is in flight; across processes the overlap is
        physical."""
        p = self.params
        t0_host = time.perf_counter()
        splittable = [int(nid) for nid in plan["splittable"]]
        forest = int(plan.get("forest", 0) or 0)

        # prune the parent-histogram cache to exactly this layer's
        # subtract parents — BEFORE the empty-layer return, so an
        # all-leaf layer frees the previous layer's cache just like the
        # guest-side eviction loop does: a remote host never sees that
        # loop, the plan itself is its eviction schedule (in-process this
        # is a no-op shadow of the guest's eviction)
        if self.frontier is not None:
            keep = ({int(par) for _, mode, par, _ in plan["modes"]
                     if mode == "subtract"}
                    if p.histogram_subtraction else set())
            size = self.frontier.evict_except(keep)
            # gauge, not counter (max-merged across parties): in-process
            # the guest's end-of-layer measurement already dominates it
            self.stats.peak_hist_cache = max(self.stats.peak_hist_cache,
                                             size)
        if not splittable:
            return
        codec, cipher = self.codec, self.cipher
        engine = self.engine
        node_of = np.asarray(plan["node_of"])
        hist_mode = {int(nid): (mode, int(par), int(sib))
                     for nid, mode, par, sib in plan["modes"]}
        n_f, n_b = self.data.n_features, p.n_bins
        n_slots = codec.n_slots

        limb = cipher.backend == "limb"
        if limb:
            import jax.numpy as jnp

        direct, subtract = _resolve_modes(splittable, hist_mode,
                                          self.frontier,
                                          p.histogram_subtraction)
        if forest:
            # round-forest plan: node_of is (n_sel, k) and node ids are gids
            node_rows = {nid: np.where(node_of[:, nid // GID_STRIDE]
                                       == nid)[0] for nid in splittable}
        else:
            node_rows = {nid: np.where(node_of == nid)[0]
                         for nid in splittable}
        hists = self.frontier.layer_histograms(node_rows, direct, subtract,
                                               forest=forest)
        for nid in direct:
            self.stats.n_hom_add += int(hists[nid][1].sum()) * n_slots
        self.stats.n_hom_add += len(subtract) * n_f * n_b * n_slots

        # batched cumsum over the node axis, then per-node shuffle + concat
        # (histograms are already device arrays -- no host round-trip)
        if limb:
            stack = jnp.stack([hists[nid][0] for nid in splittable])
        else:
            stack = np.stack([hists[nid][0] for nid in splittable])
        cum = engine.cumsum(stack)
        self.stats.n_hom_add += len(splittable) * n_f * (n_b - 1) * n_slots

        m = n_f * (n_b - 1)          # candidates per node (fixed)
        fid_grid, bid_grid = np.meshgrid(np.arange(n_f), np.arange(n_b - 1),
                                         indexing="ij")
        real_sids = (fid_grid * n_b + bid_grid).reshape(-1)
        flats, counts_l = [], []
        for k, nid in enumerate(splittable):
            # flatten to split infos, drop last bin (empty right side)
            if limb:
                flat = cum[k][:, : n_b - 1].reshape(m, n_slots, -1)
            else:
                flat = cum[k][:, : n_b - 1].reshape(m, n_slots)
            fc = hists[nid][1].cumsum(axis=1)[:, : n_b - 1].reshape(-1)
            # real sids use the same fid*n_b+bid encoding as decode_sid
            perm = self.shuffle_rng.permutation(m)
            self.perms[nid] = real_sids[perm]  # shuffled position -> real sid
            if limb:
                flat = flat[jnp.asarray(perm)]
            else:
                flat = flat[perm]
            flats.append(flat)
            counts_l.append(fc[perm])
        self.stats.n_split_infos += m * len(splittable)
        flat_all = (jnp.concatenate(flats, axis=0) if limb
                    else np.concatenate(flats, axis=0))
        counts_all = np.concatenate(counts_l)
        M = m * len(splittable)

        ct_bytes = ct_wire_bytes(cipher)
        use_compress = (p.compression and codec.compressible
                        and codec.eta_s > 1)
        if use_compress:
            eta = codec.eta_s
            src = flat_all[:, 0, :] if limb else flat_all[:, 0]
            pkgs, sizes = compress_mod.compress_batch(
                cipher, src, eta, codec.b_slot,
                mesh=getattr(engine, "mesh", None))
            n_pkgs = len(sizes)
            self.stats.n_hom_scalar += int(np.sum(sizes - 1))
            self.stats.n_hom_add += int(np.sum(sizes - 1))
            payload = {"data": pkgs, "sizes": sizes, "counts": counts_all,
                       "m": m}
            nbytes = n_pkgs * ct_bytes + M * 8
            self.stats.n_packages += n_pkgs
        else:
            payload = {"data": flat_all, "sizes": None, "counts": counts_all,
                       "m": m}
            nbytes = M * n_slots * ct_bytes + M * 8
            self.stats.n_packages += M * n_slots
        self._reply(wire.SPLIT_INFOS, payload, nbytes)
        self.channel.tracer.complete(
            "host_layer", int(t0_host * 1e9),
            int((time.perf_counter() - t0_host) * 1e9),
            tree=int(plan.get("tree", -1)), nodes=len(splittable))

    def on_chosen_sid(self, msg: dict) -> None:
        """The guest committed to one of this host's shuffled candidates:
        resolve it against the private permutation, record the (fid, bid)
        in the host-private table, and answer with the go-left bitmask
        over the node's instance space."""
        nid, sid = int(msg["nid"]), int(msg["sid"])
        rows = np.asarray(msg["rows"])
        real_sid = int(self.perms[nid][sid])
        fid, bid = decode_sid(real_sid, self.params.n_bins)
        self.table[nid] = (fid, bid)
        if self.table_sinks is not None:
            # round-forest gids demux into per-member tables with LOCAL
            # nids, so the serving export sees one table per member tree
            m, loc = divmod(nid, GID_STRIDE)
            self.table_sinks.setdefault(m, {})[loc] = (fid, bid)
        go_left = self.data.bins[rows, fid] <= bid
        self._reply(wire.ASSIGN_MASK, go_left, (len(go_left) + 7) // 8)


@dataclasses.dataclass
class TreeContext:
    params: object               # SBTParams (see boosting.py)
    cipher: object
    codec: object
    channel: Channel
    stats: Stats
    guest_data: BinnedData
    g: np.ndarray                # (n,) or (n, l), GOSS-weighted
    h: np.ndarray
    sel_rows: np.ndarray         # GOSS-selected row ids (into full set)
    hosts: list = dataclasses.field(default_factory=list)
    tree_idx: int = 0            # global tree counter (host shuffle seeds)
    forest_k: int = 1            # round-forest width sharing ONE enc_gh
    enc_shipped: bool = False    # enc_gh already broadcast (pipelined pump
                                 # ran before the grower, DESIGN.md §12)


def _crypto_mesh(params, cipher):
    """The (data, model) mesh when the limb crypto endpoints shard, else
    None (single device, or the python-int Paillier oracle)."""
    mesh = getattr(params, "mesh", None)
    if cipher.backend == "limb" and mesh is not None \
            and mesh.devices.size > 1:
        return mesh
    return None


def _encrypt_all(ctx: TreeContext, g_sel: np.ndarray,
                 h_sel: np.ndarray) -> None:
    """Guest packs + encrypts g/h of selected rows, broadcasts to hosts.

    Limb-backend ciphertexts are *born* at histogram width with their
    at-rest sharding (rule-table entries ``enc_plain`` / ``gh_cts``,
    DESIGN.md §8): the plaintext batch is placed once (padded to the
    data-axis extent), per-shard Pallas kernels encrypt with no collective,
    and :class:`CipherFrontier` adopts the buffers as-is — zero
    host->device re-placements after encryption.  The wire-byte ledger
    keeps protocol-fidelity counts via ``ct_wire_bytes`` regardless of the
    in-memory limb layout.
    """
    p = ctx.params
    blk = _stream_block(p, ctx.cipher, len(g_sel))
    if blk:
        _encrypt_all_chunked(ctx, g_sel, h_sel, blk)
        return
    t0 = time.perf_counter()
    plain = ctx.codec.encode_plain(g_sel, h_sel)
    n, s, Lp = plain.shape
    if ctx.cipher.backend == "limb":
        import jax
        import jax.numpy as jnp
        from ..kernels.modmul import encrypt_batch
        width = ctx.cipher.hist_width
        mesh = _crypto_mesh(p, ctx.cipher)
        if mesh is not None:
            from ..parallel.sharding import data_pad, gbdt_sharding
            pad = data_pad(mesh, n)
            if pad:     # pad rows encrypt 0 -> 0 and never receive a slot
                plain = np.concatenate(
                    [plain, np.zeros((pad, s, Lp), plain.dtype)], axis=0)
            plain_dev = jax.device_put(jnp.asarray(plain, jnp.int32),
                                       gbdt_sharding(mesh, "enc_plain"))
            if ctx.cipher.name == "affine" and p.use_pallas:
                cts = encrypt_batch(ctx.cipher, plain_dev, mesh=mesh,
                                    out_width=width)
            else:
                cts = limbs.pad_limbs(ctx.cipher.encrypt_limbs(plain_dev),
                                      width)
            # re-commit with the identical at-rest sharding (no data
            # movement): a plain GSPMD array sidesteps the §7 eager-op
            # caveat for partially-replicated shard_map outputs
            cts = jax.device_put(cts, gbdt_sharding(mesh, "gh_cts"))
        elif ctx.cipher.name == "affine" and p.use_pallas:
            cts = encrypt_batch(ctx.cipher, plain.reshape(n * s, Lp),
                                out_width=width).reshape(n, s, width)
        else:
            cts = limbs.pad_limbs(
                ctx.cipher.encrypt_limbs(jnp.asarray(plain)), width)
        jax.block_until_ready(cts)
    else:
        ints = limbs.to_pyints(plain.reshape(n * s, Lp))
        cts = ctx.cipher.encrypt_ints(ints).reshape(n, s)
    ctx.stats.n_encrypt += n * s
    dt = time.perf_counter() - t0
    ctx.stats.encrypt_seconds += dt
    ctx.channel.tracer.complete("encrypt", int(t0 * 1e9), int(dt * 1e9),
                                tree=int(ctx.tree_idx), rows=int(n))
    nbytes = n * s * ct_wire_bytes(ctx.cipher) + n * 4   # + selected row ids
    codec_view = {"n_slots": int(ctx.codec.n_slots),
                  "compressible": bool(ctx.codec.compressible),
                  "eta_s": int(getattr(ctx.codec, "eta_s", 0)),
                  "b_slot": int(getattr(ctx.codec, "b_slot", 0))}
    payload = {"tree": int(ctx.tree_idx), "seed": int(p.seed),
               "forest": int(ctx.forest_k), "sel_rows": ctx.sel_rows,
               "codec": codec_view, "cts": cts}
    for host in ctx.hosts:
        host.bind(ctx.params, ctx.cipher, ctx.channel, ctx.stats)
        ctx.channel.send("guest", f"host{host.hid}", wire.ENC_GH, payload,
                         nbytes)
        host.deliver(wire.ENC_GH, payload)
    ctx.enc_shipped = True


def _stream_block(params, cipher, n: int) -> int:
    """Row-block size for the out-of-core path, or 0 for monolithic.

    Streaming engages only when a positive ``row_block`` is set, the batch
    actually exceeds it, and the cipher is limb-backed (the python-int
    Paillier oracle keeps the small-data monolithic path)."""
    rb = int(getattr(params, "row_block", 0) or 0)
    if rb > 0 and n > rb and cipher.backend == "limb":
        return rb
    return 0


def _encrypt_all_chunked(ctx: TreeContext, g_sel: np.ndarray,
                         h_sel: np.ndarray, block: int) -> None:
    """Chunked encrypt->ship (DESIGN.md §13): one row block at a time.

    Each block is encoded, encrypted on the single-device limb path, cast
    to its canonical radix-2^8 uint8 limbs and broadcast under the same
    ``enc_gh`` tag with framing fields (``blk``/``n_blocks``/``n_rows``/
    ``row_block`` plus the block's slice of ``sel_rows``).  Encryption is
    row-wise deterministic, so the concatenation of block ciphertexts is
    bit-identical to the monolithic batch; per-block wire bytes sum to the
    monolithic ledger total.  No party ever holds the full ciphertext
    batch: the guest frees each block after the ship and hosts assemble
    into a host-compact :class:`CtsBlocks`."""
    import jax
    import jax.numpy as jnp

    from ..kernels.modmul import encrypt_batch
    p = ctx.params
    n = len(g_sel)
    n_blocks = -(-n // block)
    Ln = ctx.cipher.Ln
    wire_ct = ct_wire_bytes(ctx.cipher)
    codec_view = {"n_slots": int(ctx.codec.n_slots),
                  "compressible": bool(ctx.codec.compressible),
                  "eta_s": int(getattr(ctx.codec, "eta_s", 0)),
                  "b_slot": int(getattr(ctx.codec, "b_slot", 0))}
    for host in ctx.hosts:
        host.bind(ctx.params, ctx.cipher, ctx.channel, ctx.stats)
    sel_rows = np.asarray(ctx.sel_rows)
    for b in range(n_blocks):
        t0 = time.perf_counter()
        lo, hi = b * block, min((b + 1) * block, n)
        plain = ctx.codec.encode_plain(g_sel[lo:hi], h_sel[lo:hi])
        r, s, Lp = plain.shape
        if ctx.cipher.name == "affine" and p.use_pallas:
            cts = encrypt_batch(ctx.cipher, plain.reshape(r * s, Lp),
                                out_width=Ln).reshape(r, s, Ln)
        else:
            cts = limbs.pad_limbs(
                ctx.cipher.encrypt_limbs(jnp.asarray(plain)), Ln)
        cts_u8 = np.asarray(jax.device_get(cts)).astype(np.uint8)
        ctx.stats.n_encrypt += r * s
        dt = time.perf_counter() - t0
        ctx.stats.encrypt_seconds += dt
        ctx.channel.tracer.complete("encrypt_block", int(t0 * 1e9),
                                    int(dt * 1e9), tree=int(ctx.tree_idx),
                                    blk=int(b), rows=int(r))
        ctx.stats.peak_block_bytes = max(
            ctx.stats.peak_block_bytes, int(cts_u8.nbytes) + r * 8)
        payload = {"tree": int(ctx.tree_idx), "seed": int(p.seed),
                   "forest": int(ctx.forest_k), "codec": codec_view,
                   "blk": b, "n_blocks": n_blocks, "n_rows": n,
                   "row_block": int(block),
                   "sel_rows": sel_rows[lo:hi], "cts": cts_u8}
        nbytes = r * s * wire_ct + r * 4
        for host in ctx.hosts:
            ctx.channel.send("guest", f"host{host.hid}", wire.ENC_GH, payload,
                             nbytes)
            host.deliver(wire.ENC_GH, payload)
    ctx.enc_shipped = True


class _EncryptPump:
    """Background encrypt-and-ship of one tree's ``enc_gh`` (DESIGN.md §12).

    Pipelined mode runs :func:`_encrypt_all` on a worker thread so the
    guest's plaintext work (layer-0 histogram candidates, or the previous
    round's remaining layers in the boosting driver's cross-round prefetch)
    overlaps the encrypt + broadcast.  The payload is byte-identical to the
    synchronous call — only wall-clock ordering changes — so pipelined runs
    stay bit-identical to sequential ones.

    ``join`` settles the overlap accounting: the encrypt wall time that
    elapsed before the joiner arrived was *hidden* behind useful work and
    accrues to ``Stats.prefetch_seconds`` (a subset of ``encrypt_seconds``,
    which :func:`_encrypt_all` still tallies in full); the per-tree hidden
    fraction lands in ``Stats.wire_overlap``.
    """

    def __init__(self, ctx: TreeContext, g_sel: np.ndarray,
                 h_sel: np.ndarray):
        import threading
        self.ctx = ctx
        self._err: BaseException | None = None
        self._done_t: float | None = None
        self._t0 = time.perf_counter()
        self._thread = threading.Thread(
            target=self._run, args=(g_sel, h_sel), daemon=True)
        self._thread.start()

    def _run(self, g_sel, h_sel) -> None:
        try:
            _encrypt_all(self.ctx, g_sel, h_sel)
        except BaseException as e:          # surfaced at join()
            self._err = e
        finally:
            self._done_t = time.perf_counter()

    def join(self) -> None:
        t_join = time.perf_counter()
        self._thread.join()
        if self._err is not None:
            raise self._err
        enc = max(self._done_t - self._t0, 0.0)
        hidden = max(0.0, min(self._done_t, t_join) - self._t0)
        stats = self.ctx.stats
        stats.prefetch_seconds += hidden
        stats.wire_overlap.append(hidden / enc if enc > 0 else 0.0)


def _resolve_modes(splittable: list, hist_mode: dict, cache,
                   subtraction_on: bool) -> tuple[list, list]:
    """Partition a layer's splittable nodes into direct / subtract batches.

    ``cache`` is any container answering ``nid in cache`` for cached parent
    histograms (a ``CipherFrontier`` / ``GuestFrontier``).  A node keeps its
    scheduled "subtract" mode only when its parent's histogram is cached AND
    its (direct-mode) sibling is being computed this layer -- otherwise it
    falls back to direct, exactly like the per-node path did when a sibling
    exited early as a leaf.  ``splittable`` must be ordered direct-first so
    siblings are classified before their subtract partners."""
    direct: list = []
    subtract: list = []
    direct_set: set = set()
    for nid in splittable:
        mode, par, sib = hist_mode[nid] if subtraction_on \
            else ("direct", -1, -1)
        if mode == "subtract" and par in cache and sib in direct_set:
            subtract.append((nid, par, sib))
        else:
            direct.append(nid)
            direct_set.add(nid)
    return direct, subtract


def _host_layer_finish(ctx: TreeContext, hid: int,
                       splittable: list, pending: dict) -> dict:
    """Guest side of the layer batch: ONE batched decrypt + decode
    (Algorithm 6) of the candidate stack a host answered ``assign_sync``
    with (``HostRuntime.on_assign_sync``).  In-process the stack is still
    device-resident and the first ``np.asarray`` synchronizes the whole
    in-flight cipher pipeline; over the transport it arrives as a decoded
    limb tensor.  Returns {nid: SplitCandidates}."""
    limb = ctx.cipher.backend == "limb"
    n_slots = ctx.codec.n_slots
    data, sizes, cl = pending["data"], pending["sizes"], pending["counts"]
    m = int(pending["m"])
    M = m * len(splittable)
    use_compress = sizes is not None
    if use_compress:
        plain = _decrypt_ints(ctx, data)
        ctx.stats.n_decrypt += len(plain)
        vals = compress_mod.decompress_ints(
            plain, sizes, ctx.codec.eta_s, ctx.codec.b_slot, padded=limb)
        rows = np.asarray(vals, dtype=object).reshape(M, 1)
    else:
        if limb:
            # keep the candidate stack on device into the batched decrypt
            flat2 = data.reshape(M * n_slots, -1)
        else:
            flat2 = data.reshape(M * n_slots)
        plain = _decrypt_ints(ctx, flat2)
        ctx.stats.n_decrypt += M * n_slots
        rows = np.asarray(plain, dtype=object).reshape(M, n_slots)
    g_l, h_l = ctx.codec.decode(rows, cl)
    out = {}
    for k, nid in enumerate(splittable):
        sl = slice(k * m, (k + 1) * m)
        out[nid] = SplitCandidates(party=hid, sid=np.arange(m),
                                   g_l=g_l[sl], h_l=h_l[sl], cnt_l=cl[sl])
    return out


def _decrypt_ints(ctx: TreeContext, cts) -> list:
    if ctx.cipher.backend == "limb":
        import jax.numpy as jnp
        if ctx.cipher.name == "affine" and ctx.params.use_pallas:
            from ..kernels.modmul import decrypt_batch
            x = jnp.asarray(cts)
            mesh = _crypto_mesh(ctx.params, ctx.cipher)
            n = x.shape[0]
            # shard only when every shard gets at least one full-size kernel
            # row block: cipher-compressed package batches are small by
            # design (that is the point of compression) and would pay a
            # shard_map compile per pow2 bucket for sub-millisecond matmuls;
            # large stacks (no-compress / MO / deep frontiers) shard for real
            from ..kernels.modmul.modmul import BLOCK_N
            dd = dict(mesh.shape).get("data", 1) if mesh is not None else 1
            if mesh is not None and n >= BLOCK_N * dd:
                import jax

                from ..parallel.sharding import data_pad, gbdt_sharding
                # the candidate stack is still device-resident: pad the
                # candidate axis to the next power of two (the per-layer
                # candidate count varies with the frontier, and the padded
                # extent is a static shape — pow2 bucketing caps distinct
                # compilations at O(log max_M), mirroring the node padding
                # of the layer dispatch), then shard per the rule table and
                # decrypt per shard with no collective
                bucket = 1 << max(n - 1, 0).bit_length()
                bucket += data_pad(mesh, bucket)
                if bucket > n:
                    x = jnp.pad(x, [(0, bucket - n)]
                                + [(0, 0)] * (x.ndim - 1))
                x = jax.device_put(
                    x, gbdt_sharding(mesh, "split_infos", ndim=x.ndim))
                pl_limbs = decrypt_batch(ctx.cipher, x, mesh=mesh)
                return limbs.to_pyints(np.asarray(pl_limbs)[:n])
            pl_limbs = decrypt_batch(ctx.cipher, x)
            return limbs.to_pyints(np.asarray(pl_limbs))
        return ctx.cipher.decrypt_to_ints(jnp.asarray(cts))
    return ctx.cipher.decrypt_to_ints(cts)


def _guest_layer_candidates(ctx: TreeContext, guest_frontier: GuestFrontier,
                            splittable: list, rows_sel: dict,
                            hist_mode: dict) -> dict:
    """Guest-side plaintext mirror of the layer batch: one composite
    ``np.add.at`` pass for all direct nodes, subtraction for the rest."""
    direct, subtract = _resolve_modes(splittable, hist_mode, guest_frontier,
                                      ctx.params.histogram_subtraction)
    node_rows = {nid: ctx.sel_rows[rows_sel[nid]] for nid in splittable}
    hists = guest_frontier.layer_histograms(node_rows, direct, subtract)
    out = {}
    for nid in splittable:
        Gc, Hc, Cc = guest_frontier.cumsum(hists[nid])
        out[nid] = candidates_from_cumsum(Gc, Hc, Cc, party=GUEST)
    return out


# ---------------------------------------------------------------------------
# the grower
# ---------------------------------------------------------------------------

def grow_tree(ctx: TreeContext,
              feature_parties: Callable[[int], tuple] | None = None
              ) -> tuple:
    """Grow one federated tree.  ``feature_parties(depth) -> (use_guest,
    host_ids)`` schedules which parties contribute split candidates at each
    depth (mix / layered modes); default: everyone, every depth.

    Returns ``(tree, leaf_rows)``: the model and the training row -> leaf
    assignment.  ``leaf_rows`` is train-side state consumed once by the
    boosting driver's score update — it is deliberately NOT attached to the
    :class:`FederatedTree`, so a model held for serving (or exported via
    ``serving/export.py``) carries no row-level training residue."""
    p = ctx.params
    t_tree = time.perf_counter()
    if feature_parties is None:
        feature_parties = lambda d: (True, [h.hid for h in ctx.hosts])

    # hoisted once per tree: g/h restricted to the GOSS selection
    g_sel = ctx.g[ctx.sel_rows]
    h_sel = ctx.h[ctx.sel_rows]

    pump = None
    any_host = any(feature_parties(d)[1] for d in range(p.max_depth))
    if any_host and not ctx.enc_shipped:
        if getattr(p, "pipeline", False):
            # pipelined: encrypt + broadcast on a worker thread; the guest's
            # layer-0 plaintext candidates run concurrently and the pump is
            # joined right before the first assign_sync (DESIGN.md §12)
            pump = _EncryptPump(ctx, g_sel, h_sel)
        else:
            _encrypt_all(ctx, g_sel, h_sel)

    plain_engine = PlainHistogram(p.n_bins, sparse=p.sparse,
                                 row_block=getattr(p, "row_block", 0))
    guest_frontier = GuestFrontier(plain_engine, ctx.guest_data, ctx.g, ctx.h)

    n_all = ctx.guest_data.n_instances
    nodes = [Node(nid=0, depth=0, n_rows=n_all)]
    rows_all = {0: np.arange(n_all)}
    rows_sel = {0: np.arange(len(ctx.sel_rows))}   # positions into sel arrays
    hist_mode = {0: ("direct", -1, -1)}

    frontier = [0]
    for depth in range(p.max_depth):
        use_guest, host_ids = feature_parties(depth)
        active_hosts = [h for h in ctx.hosts if h.hid in host_ids]
        next_frontier = []
        # order: direct nodes before subtract nodes (siblings first)
        ordered = [n for n in frontier if hist_mode[n][0] == "direct"] + \
                  [n for n in frontier if hist_mode[n][0] == "subtract"]
        # sync one assignment vector per layer to hosts that participate
        if active_hosts:
            node_of = np.full(len(ctx.sel_rows), -1, np.int32)
            for nid in frontier:
                node_of[rows_sel[nid]] = nid

        # triage: nodes too small to split become leaves immediately; the
        # rest form this layer's batch
        splittable = []
        for nid in ordered:
            rs = rows_sel[nid]
            if len(rs) < 2 * p.min_leaf or len(rs) == 0:
                nodes[nid].weight = leaf_weight(
                    g_sel[rs].sum(axis=0), h_sel[rs].sum(axis=0),
                    p.lam, p.learning_rate)
            else:
                splittable.append(nid)

        # one candidate batch per party for the whole layer.  The layer
        # plan (assignment vector + splittable batch + subtraction
        # schedule) is ONE serializable assign_sync message per host; each
        # host answers with ONE split_infos message.  In-process the
        # deliver below runs the host pipeline as jax async dispatch; with
        # remote hosts it is a no-op (the channel already shipped the
        # plan) and every host process computes concurrently.  Either way
        # the guest's plaintext numpy histograms run while the host cipher
        # work is in flight, and only then does the guest block on the
        # batched decrypt — the two sides are independent until
        # find_best_split (DESIGN.md §8).
        # pipelined: the guest's plaintext layer candidates are the useful
        # work that hides the pump's encrypt + broadcast; compute them
        # BEFORE joining, then join so the assign_sync below never races
        # ahead of the enc_gh it depends on
        pre_cands = None
        if pump is not None:
            if splittable and use_guest and ctx.guest_data.n_features > 0:
                pre_cands = _guest_layer_candidates(
                    ctx, guest_frontier, splittable, rows_sel, hist_mode)
            pump.join()
            pump = None

        guest_cands: dict = {}
        host_cands: dict = {}
        t0 = time.perf_counter()
        if active_hosts:
            plan = {"tree": int(ctx.tree_idx),
                    "node_of": node_of,
                    "splittable": list(splittable),
                    "modes": [(nid,) + tuple(hist_mode[nid])
                              for nid in splittable]}
            for h in active_hosts:
                ctx.channel.send("guest", f"host{h.hid}", wire.ASSIGN_SYNC,
                                 plan, node_of.size * 4)
                h.deliver(wire.ASSIGN_SYNC, plan)
        if splittable:
            t1 = time.perf_counter()
            if pre_cands is not None:
                guest_cands = pre_cands
            elif use_guest and ctx.guest_data.n_features > 0:
                guest_cands = _guest_layer_candidates(
                    ctx, guest_frontier, splittable, rows_sel, hist_mode)
            t2 = time.perf_counter()
            for h in active_hosts:
                pend = h.collect(wire.SPLIT_INFOS)
                ctx.stats.n_split_roundtrips += 1
                host_cands[h.hid] = _host_layer_finish(ctx, h.hid,
                                                       splittable, pend)
            t3 = time.perf_counter()
            if active_hosts:
                ctx.stats.host_dispatch_seconds += t1 - t0
                ctx.stats.guest_hist_seconds += t2 - t1
                ctx.stats.host_wait_seconds += t3 - t2
                # overlap only exists for async-dispatch backends: the
                # Paillier oracle completes synchronously inside dispatch,
                # so nothing is in flight while the guest works
                if guest_cands and ctx.cipher.backend == "limb":
                    denom = t3 - t0
                    ctx.stats.layer_overlap.append(
                        (t2 - t1) / denom if denom > 0 else 0.0)
            tr = ctx.channel.tracer
            if tr.enabled:
                # re-emit the already-measured phase floats as spans:
                # perf_counter() and perf_counter_ns() share one clock
                tkw = dict(tree=int(ctx.tree_idx), depth=int(depth))
                tr.complete("dispatch", int(t0 * 1e9), int((t1 - t0) * 1e9),
                            **tkw)
                tr.complete("guest_hist", int(t1 * 1e9),
                            int((t2 - t1) * 1e9), **tkw)
                tr.complete("decrypt_wait", int(t2 * 1e9),
                            int((t3 - t2) * 1e9), **tkw)
                tr.complete("layer", int(t0 * 1e9), int((t3 - t0) * 1e9),
                            nodes=len(splittable), **tkw)

        for nid in splittable:
            node = nodes[nid]
            rs = rows_sel[nid]
            G_tot = g_sel[rs].sum(axis=0)
            H_tot = h_sel[rs].sum(axis=0)

            cands = []
            if nid in guest_cands:
                cands.append(guest_cands[nid])
            for h in active_hosts:
                cands.append(host_cands[h.hid][nid])

            best = find_best_split(cands, G_tot, H_tot, len(rs), p.lam,
                                   p.min_leaf, p.min_gain)
            if best is None:
                node.weight = leaf_weight(G_tot, H_tot, p.lam, p.learning_rate)
                continue

            # resolve the split owner + instance assignment
            ra = rows_all[nid]
            fsel = ctx.sel_rows[rs]                 # full ids of selected rows
            if best.party == GUEST:
                fid, bid = decode_sid(best.sid, p.n_bins)
                go_left = ctx.guest_data.bins[ra, fid] <= bid
                go_left_sel = ctx.guest_data.bins[fsel, fid] <= bid
                node.party, node.fid, node.bid = GUEST, fid, bid
            else:
                # the chosen split id travels WITH the node's instance
                # space (the host resolves its private (fid, bid) and
                # answers one go-left bitmask over those rows); the
                # selected-row mask is derived guest-side — fsel is always
                # a subset of the ascending ra, so no second message
                host = next(h for h in ctx.hosts if h.hid == best.party)
                msg = {"nid": nid, "sid": best.sid, "rows": ra}
                ctx.channel.send("guest", f"host{host.hid}", wire.CHOSEN_SID,
                                 msg, 8 + 4 * len(ra))
                host.deliver(wire.CHOSEN_SID, msg)
                go_left = np.asarray(host.collect(wire.ASSIGN_MASK), bool)
                go_left_sel = go_left[np.searchsorted(ra, fsel)]
                node.party, node.sid = host.hid, best.sid
            node.gain = best.gain

            lid, rid = len(nodes), len(nodes) + 1
            node.left, node.right = lid, rid
            rows_all[lid], rows_all[rid] = ra[go_left], ra[~go_left]
            rows_sel[lid], rows_sel[rid] = rs[go_left_sel], rs[~go_left_sel]
            nodes.append(Node(nid=lid, depth=depth + 1, n_rows=len(rows_all[lid])))
            nodes.append(Node(nid=rid, depth=depth + 1, n_rows=len(rows_all[rid])))
            # subtraction schedule: smaller child direct, sibling subtracts
            if len(rows_sel[lid]) <= len(rows_sel[rid]):
                hist_mode[lid] = ("direct", -1, -1)
                hist_mode[rid] = ("subtract", nid, lid)
            else:
                hist_mode[rid] = ("direct", -1, -1)
                hist_mode[lid] = ("subtract", nid, rid)
            next_frontier += [lid, rid]
        # free cached histograms: keep ONLY the parents the next layer's
        # subtract-mode nodes will read.  Evicting just the used parents
        # leaked every histogram cached for a node that became a leaf
        # (triage, best=None, or max depth) — device memory grew with each
        # dead branch for the tree's remainder.
        keep = ({hist_mode[c][1] for c in next_frontier
                 if hist_mode[c][0] == "subtract"}
                if p.histogram_subtraction else set())
        sizes = [guest_frontier.evict_except(keep)]
        for h in ctx.hosts:
            # remote handles hold no frontier: their PartyProcess evicts
            # against the same schedule when the next assign_sync arrives
            if getattr(h, "frontier", None) is not None:
                sizes.append(h.frontier.evict_except(keep))
        ctx.stats.peak_hist_cache = max(ctx.stats.peak_hist_cache,
                                        max(sizes))
        ctx.stats.peak_frontier = max(ctx.stats.peak_frontier, len(frontier))
        frontier = next_frontier

    if pump is not None:        # degenerate: no layer ever joined it
        pump.join()

    # finalize leaves at max depth
    for node in nodes:
        if node.left == -1 and node.weight is None:
            rs = rows_sel[node.nid]
            node.weight = leaf_weight(g_sel[rs].sum(axis=0),
                                      h_sel[rs].sum(axis=0),
                                      p.lam, p.learning_rate)

    # leaf row assignment for the score update (returned alongside, never
    # retained on the model: the tree must stay free of row-level state)
    leaf_rows = {n.nid: rows_all[n.nid] for n in nodes if n.left == -1}
    tree = FederatedTree(nodes=nodes,
                         host_tables=[h.table for h in ctx.hosts])
    ctx.channel.tracer.complete(
        "tree", int(t_tree * 1e9),
        int((time.perf_counter() - t_tree) * 1e9),
        tree=int(ctx.tree_idx), n_nodes=len(nodes))
    return tree, leaf_rows


def grow_forest(ctx: TreeContext, bags: list,
                feature_parties: Callable[[int], tuple] | None = None
                ) -> list:
    """Grow one round-forest: ``k = len(bags)`` bagged member trees that
    share ONE ``enc_gh`` broadcast (FedGBF-style round bagging, DESIGN.md
    §12).  ``bags[m]`` holds member m's row subset as positions into
    ``ctx.sel_rows``; bags restrict only which rows *contribute* g/h to
    split finding — every training row still routes through every member
    for the score update, so ``rows_all`` starts at the full set per member.

    All members grow in lockstep, layer by layer.  Each layer is still ONE
    ``assign_sync`` -> ONE ``split_infos`` -> ONE batched decrypt per host:
    the assignment matrix gains a member column, the histogram launch
    batches over (member, node) via the forest kernel, and node ids on the
    wire are globals ``gid = member * GID_STRIDE + local_nid`` (host dicts
    key on the opaque gid; the guest demuxes tables per member on
    finalize).  Amortization is the point: k trees cost one encrypt
    round-trip and O(depth) — not O(k * depth) — protocol round trips.

    Returns ``[(tree, leaf_rows), ...]`` per member, the same pair
    :func:`grow_tree` returns.
    """
    p = ctx.params
    k = len(bags)
    if feature_parties is None:
        feature_parties = lambda d: (True, [h.hid for h in ctx.hosts])

    g_sel = ctx.g[ctx.sel_rows]
    h_sel = ctx.h[ctx.sel_rows]

    pump = None
    any_host = any(feature_parties(d)[1] for d in range(p.max_depth))
    if any_host and not ctx.enc_shipped:
        if getattr(p, "pipeline", False):
            pump = _EncryptPump(ctx, g_sel, h_sel)
        else:
            _encrypt_all(ctx, g_sel, h_sel)

    plain_engine = PlainHistogram(p.n_bins, sparse=p.sparse,
                                 row_block=getattr(p, "row_block", 0))
    guest_frontier = GuestFrontier(plain_engine, ctx.guest_data, ctx.g, ctx.h)

    n_all = ctx.guest_data.n_instances
    # per-member node lists carry LOCAL nids; all protocol/guest dict state
    # (rows, modes, caches, host tables) keys on the global gid
    nodes = [[Node(nid=0, depth=0, n_rows=n_all)] for _ in range(k)]
    rows_all: dict = {}
    rows_sel: dict = {}
    hist_mode: dict = {}
    frontier: list = []
    for m in range(k):
        gid0 = m * GID_STRIDE
        rows_all[gid0] = np.arange(n_all)
        rows_sel[gid0] = np.asarray(bags[m])
        hist_mode[gid0] = ("direct", -1, -1)
        frontier.append(gid0)

    for depth in range(p.max_depth):
        use_guest, host_ids = feature_parties(depth)
        active_hosts = [h for h in ctx.hosts if h.hid in host_ids]
        next_frontier = []
        ordered = [n for n in frontier if hist_mode[n][0] == "direct"] + \
                  [n for n in frontier if hist_mode[n][0] == "subtract"]
        if active_hosts:
            # one assignment column per member: a row sits in at most one
            # frontier node per member tree
            node_of = np.full((len(ctx.sel_rows), k), -1, np.int32)
            for gid in frontier:
                node_of[rows_sel[gid], gid // GID_STRIDE] = gid

        splittable = []
        for gid in ordered:
            rs = rows_sel[gid]
            node = nodes[gid // GID_STRIDE][gid % GID_STRIDE]
            if len(rs) < 2 * p.min_leaf or len(rs) == 0:
                node.weight = leaf_weight(
                    g_sel[rs].sum(axis=0), h_sel[rs].sum(axis=0),
                    p.lam, p.learning_rate)
            else:
                splittable.append(gid)

        pre_cands = None
        if pump is not None:
            if splittable and use_guest and ctx.guest_data.n_features > 0:
                pre_cands = _guest_layer_candidates(
                    ctx, guest_frontier, splittable, rows_sel, hist_mode)
            pump.join()
            pump = None

        guest_cands: dict = {}
        host_cands: dict = {}
        t0 = time.perf_counter()
        if active_hosts:
            plan = {"tree": int(ctx.tree_idx), "forest": k,
                    "node_of": node_of,
                    "splittable": list(splittable),
                    "modes": [(gid,) + tuple(hist_mode[gid])
                              for gid in splittable]}
            for h in active_hosts:
                ctx.channel.send("guest", f"host{h.hid}", wire.ASSIGN_SYNC,
                                 plan, node_of.size * 4)
                h.deliver(wire.ASSIGN_SYNC, plan)
        if splittable:
            t1 = time.perf_counter()
            if pre_cands is not None:
                guest_cands = pre_cands
            elif use_guest and ctx.guest_data.n_features > 0:
                guest_cands = _guest_layer_candidates(
                    ctx, guest_frontier, splittable, rows_sel, hist_mode)
            t2 = time.perf_counter()
            for h in active_hosts:
                pend = h.collect(wire.SPLIT_INFOS)
                ctx.stats.n_split_roundtrips += 1
                host_cands[h.hid] = _host_layer_finish(ctx, h.hid,
                                                       splittable, pend)
            t3 = time.perf_counter()
            if active_hosts:
                ctx.stats.host_dispatch_seconds += t1 - t0
                ctx.stats.guest_hist_seconds += t2 - t1
                ctx.stats.host_wait_seconds += t3 - t2
                if guest_cands and ctx.cipher.backend == "limb":
                    denom = t3 - t0
                    ctx.stats.layer_overlap.append(
                        (t2 - t1) / denom if denom > 0 else 0.0)
            tr = ctx.channel.tracer
            if tr.enabled:
                tkw = dict(tree=int(ctx.tree_idx), depth=int(depth))
                tr.complete("dispatch", int(t0 * 1e9), int((t1 - t0) * 1e9),
                            **tkw)
                tr.complete("guest_hist", int(t1 * 1e9),
                            int((t2 - t1) * 1e9), **tkw)
                tr.complete("decrypt_wait", int(t2 * 1e9),
                            int((t3 - t2) * 1e9), **tkw)
                tr.complete("layer", int(t0 * 1e9), int((t3 - t0) * 1e9),
                            nodes=len(splittable), **tkw)

        for gid in splittable:
            m = gid // GID_STRIDE
            node = nodes[m][gid % GID_STRIDE]
            rs = rows_sel[gid]
            G_tot = g_sel[rs].sum(axis=0)
            H_tot = h_sel[rs].sum(axis=0)

            cands = []
            if gid in guest_cands:
                cands.append(guest_cands[gid])
            for h in active_hosts:
                cands.append(host_cands[h.hid][gid])

            best = find_best_split(cands, G_tot, H_tot, len(rs), p.lam,
                                   p.min_leaf, p.min_gain)
            if best is None:
                node.weight = leaf_weight(G_tot, H_tot, p.lam,
                                          p.learning_rate)
                continue

            ra = rows_all[gid]
            fsel = ctx.sel_rows[rs]
            if best.party == GUEST:
                fid, bid = decode_sid(best.sid, p.n_bins)
                go_left = ctx.guest_data.bins[ra, fid] <= bid
                go_left_sel = ctx.guest_data.bins[fsel, fid] <= bid
                node.party, node.fid, node.bid = GUEST, fid, bid
            else:
                host = next(h for h in ctx.hosts if h.hid == best.party)
                msg = {"nid": gid, "sid": best.sid, "rows": ra}
                ctx.channel.send("guest", f"host{host.hid}", wire.CHOSEN_SID,
                                 msg, 8 + 4 * len(ra))
                host.deliver(wire.CHOSEN_SID, msg)
                go_left = np.asarray(host.collect(wire.ASSIGN_MASK), bool)
                go_left_sel = go_left[np.searchsorted(ra, fsel)]
                node.party, node.sid = host.hid, best.sid
            node.gain = best.gain

            lid, rid = len(nodes[m]), len(nodes[m]) + 1
            gl, gr = m * GID_STRIDE + lid, m * GID_STRIDE + rid
            node.left, node.right = lid, rid
            rows_all[gl], rows_all[gr] = ra[go_left], ra[~go_left]
            rows_sel[gl], rows_sel[gr] = rs[go_left_sel], rs[~go_left_sel]
            nodes[m].append(Node(nid=lid, depth=depth + 1,
                                 n_rows=len(rows_all[gl])))
            nodes[m].append(Node(nid=rid, depth=depth + 1,
                                 n_rows=len(rows_all[gr])))
            if len(rows_sel[gl]) <= len(rows_sel[gr]):
                hist_mode[gl] = ("direct", -1, -1)
                hist_mode[gr] = ("subtract", gid, gl)
            else:
                hist_mode[gr] = ("direct", -1, -1)
                hist_mode[gl] = ("subtract", gid, gr)
            next_frontier += [gl, gr]

        keep = ({hist_mode[c][1] for c in next_frontier
                 if hist_mode[c][0] == "subtract"}
                if p.histogram_subtraction else set())
        sizes = [guest_frontier.evict_except(keep)]
        for h in ctx.hosts:
            if getattr(h, "frontier", None) is not None:
                sizes.append(h.frontier.evict_except(keep))
        ctx.stats.peak_hist_cache = max(ctx.stats.peak_hist_cache,
                                        max(sizes))
        ctx.stats.peak_frontier = max(ctx.stats.peak_frontier, len(frontier))
        frontier = next_frontier

    if pump is not None:
        pump.join()

    # finalize: leaves at max depth, per-member host-table demux (gid ->
    # local nid; remote handles hold no table — their PartyProcess demuxes
    # via ``table_sinks`` into its own per-member export tables)
    tables_by_member = [[{} for _ in ctx.hosts] for _ in range(k)]
    for j, h in enumerate(ctx.hosts):
        for gid, fb in getattr(h, "table", {}).items():
            mm, loc = divmod(int(gid), GID_STRIDE)
            tables_by_member[mm][j][loc] = fb
    out = []
    for m in range(k):
        for node in nodes[m]:
            if node.left == -1 and node.weight is None:
                rs = rows_sel[m * GID_STRIDE + node.nid]
                node.weight = leaf_weight(g_sel[rs].sum(axis=0),
                                          h_sel[rs].sum(axis=0),
                                          p.lam, p.learning_rate)
        leaf_rows = {nd.nid: rows_all[m * GID_STRIDE + nd.nid]
                     for nd in nodes[m] if nd.left == -1}
        out.append((FederatedTree(nodes=nodes[m],
                                  host_tables=tables_by_member[m]),
                    leaf_rows))
    return out


def predict_tree(tree: FederatedTree, guest_bins: np.ndarray,
                 host_bins: list) -> np.ndarray:
    """Route binned instances through the tree (simulation: reads host
    tables directly; the real protocol does the same lookups host-side)."""
    n = guest_bins.shape[0]
    first = next(nd for nd in tree.nodes if nd.weight is not None)
    w0 = np.asarray(first.weight)
    out = np.zeros((n,) + w0.shape)
    node_of = np.zeros(n, np.int64)
    changed = True
    while changed:
        changed = False
        for nd in tree.nodes:
            if nd.left == -1:
                continue
            sel = node_of == nd.nid
            if not sel.any():
                continue
            if nd.party == GUEST:
                go_left = guest_bins[sel, nd.fid] <= nd.bid
            else:
                fid, bid = tree.host_tables[nd.party][nd.nid]
                go_left = host_bins[nd.party][sel, fid] <= bid
            ids = np.where(sel)[0]
            node_of[ids[go_left]] = nd.left
            node_of[ids[~go_left]] = nd.right
            changed = True
    for nd in tree.nodes:
        if nd.left == -1 and nd.weight is not None:
            out[node_of == nd.nid] = nd.weight
    return out
