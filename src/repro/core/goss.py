"""Gradient-based One-Side Sampling (paper §6.1, from LightGBM).

Keep the top_rate fraction of instances by |g| (or L2 norm of the gradient
vector for MO trees), uniformly sample other_rate of the rest, and amplify
the small-gradient samples' g/h by (1 - top_rate) / other_rate.

``other_rate <= 0`` means top-only selection: no rest samples, no
amplification.  (Forcing ``n_other = max(1, ...)`` there used to select one
rest sample and amplify it by (1 - top_rate)/1e-12 — a ~1e12x weight that
silently corrupted every g/h sum downstream.)
"""

from __future__ import annotations

import numpy as np


def goss_sample(g: np.ndarray, top_rate: float = 0.2, other_rate: float = 0.1,
                rng: np.random.Generator | None = None):
    """Returns (indices, weights): selected instance ids + per-id multiplier."""
    rng = rng or np.random.default_rng(0)
    n = g.shape[0]
    mag = np.abs(g) if g.ndim == 1 else np.linalg.norm(g, axis=-1)
    n_top = max(1, int(round(n * top_rate)))
    n_other = max(1, int(round(n * other_rate))) if other_rate > 0 else 0
    order = np.argsort(-mag, kind="stable")
    top_idx = order[:n_top]
    rest = order[n_top:]
    other_idx = rng.choice(rest, size=min(n_other, len(rest)), replace=False) \
        if n_other and len(rest) else np.empty(0, np.int64)
    amplify = (1.0 - top_rate) / other_rate if other_rate > 0 else 0.0
    idx = np.concatenate([top_idx, other_idx]).astype(np.int64)
    w = np.concatenate([np.ones(len(top_idx)),
                        np.full(len(other_idx), amplify)])
    return idx, w
