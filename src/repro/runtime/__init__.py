from .fault import (Heartbeat, ResilientLoop, StragglerError,  # noqa: F401
                    StragglerPolicy)
from .transport import (LoopbackEndpoint, MultiHostRun,  # noqa: F401
                        PartyProcess, RemoteHostHandle, RemoteServingHost,
                        SocketEndpoint, TransportChannel, TransportError,
                        decode_payload, encode_payload, host_main)
