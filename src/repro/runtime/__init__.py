from .fault import (Heartbeat, ResilientLoop, StragglerError,  # noqa: F401
                    StragglerPolicy)
