from .chaos import (Corrupt, Delay, DropConn, FaultPlan,  # noqa: F401
                    FaultyEndpoint, Kill, Truncate, Wedge)
from .fault import (Heartbeat, ResilientLoop, StragglerError,  # noqa: F401
                    StragglerPolicy)
from .transport import (LoopbackEndpoint, MultiHostRun,  # noqa: F401
                        PartyProcess, PeerRestarted, RemoteHostHandle,
                        RemoteServingHost, SocketEndpoint, TransportChannel,
                        TransportError, decode_payload, encode_payload,
                        host_main)
