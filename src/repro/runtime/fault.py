"""Fault tolerance + straggler mitigation for the training loop.

Designed for the 1000+ node regime where *something* is always failing:

* :class:`ResilientLoop` -- wraps the step function; on device/runtime
  errors it restores the latest checkpoint and replays.  Retries use
  exponential backoff; a persistent failure (same step failing
  ``max_retries`` times) raises to the launcher, which reschedules the job
  on a healed slice (elastic restore makes any mesh shape valid).

* :class:`Heartbeat` -- thread that stamps a file every ``interval``; an
  external supervisor (or the provided ``watch`` classmethod) detects a
  wedged process by mtime and kills/restarts.  This is the standard
  TPU-pod babysitter pattern.

* :class:`StragglerPolicy` -- per-step wall-time tracker.  Steps are SPMD
  (no per-device skew visible from inside), so mitigation acts at the step
  level: a step exceeding ``factor`` x the trailing median marks the slice
  degraded; after ``tolerance`` marks the loop checkpoints and exits with a
  distinct code so the launcher can migrate off the slow slice.  At the
  data layer, the loader's bounded prefetch queue stops a slow input host
  from stalling the collective (skip-slow-shard).
"""

from __future__ import annotations

import collections
import os
import statistics
import threading
import time


class StragglerError(RuntimeError):
    pass


class Heartbeat:
    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        while not self._stop.wait(self.interval):
            self.beat()

    def beat(self):
        # on-disk format unchanged (external babysitters parse it); only
        # the LIVENESS JUDGEMENT below moved off the wall clock
        with open(self.path, "w") as f:
            f.write(str(time.time()))

    def start(self):
        self.beat()
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()

    # mtime observations per path: (last mtime seen, monotonic clock at
    # the moment it changed).  Comparing ``time.time() - mtime`` against
    # the timeout was wrong under NTP: a forward wall-clock step ages a
    # perfectly fresh beat past the timeout (spurious wedged-host verdict
    # -> pointless restart), a backward step revives a dead one.  A peer
    # is now wedged only when its mtime has been UNCHANGED for ``timeout``
    # seconds of the observer's own monotonic clock.
    _watch: dict = {}
    _watch_lock = threading.Lock()

    @classmethod
    def is_alive(cls, path: str, timeout: float) -> bool:
        try:
            mtime = os.path.getmtime(path)
        except OSError:
            return False
        now = time.monotonic()
        with cls._watch_lock:
            prev = cls._watch.get(path)
            if prev is None or prev[0] != mtime:
                cls._watch[path] = (mtime, now)
                return True
            return now - prev[1] < timeout


class StragglerPolicy:
    def __init__(self, factor: float = 2.5, tolerance: int = 5,
                 window: int = 50):
        self.factor = factor
        self.tolerance = tolerance
        self.times = collections.deque(maxlen=window)
        self.strikes = 0

    def observe(self, step_seconds: float) -> None:
        if len(self.times) >= 10:
            med = statistics.median(self.times)
            if step_seconds > self.factor * med:
                self.strikes += 1
                if self.strikes >= self.tolerance:
                    raise StragglerError(
                        f"step {step_seconds:.2f}s > {self.factor}x median "
                        f"{med:.2f}s for {self.strikes} steps: slice degraded")
            else:
                self.strikes = max(0, self.strikes - 1)
        self.times.append(step_seconds)

    def check(self, step_seconds: float) -> bool:
        """Non-raising :meth:`observe`: True once the peer is degraded.

        The federated runtime's supervisor uses this form — a SLOW host
        is *marked*, never restarted (restarting loses real tree
        progress for zero correctness gain; only a WEDGED host, one that
        stops answering heartbeats entirely, gets restarted)."""
        try:
            self.observe(step_seconds)
            return False
        except StragglerError:
            return True


class ResilientLoop:
    """step_fn(state, batch) -> state; save_fn(step, state); restore_fn()
    -> (step, state).  Runs to n_steps surviving transient failures.

    ``step_fn`` must treat ``state`` functionally (return a new state, as
    jax pytree updates do): the no-checkpoint fallback replays from the
    state object the caller passed in, which only equals the true initial
    state if steps never mutated it in place."""

    def __init__(self, step_fn, save_fn, restore_fn, next_batch,
                 save_every: int = 100, max_retries: int = 3,
                 backoff: float = 1.0, straggler: StragglerPolicy | None = None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.next_batch = next_batch
        self.save_every = save_every
        self.max_retries = max_retries
        self.backoff = backoff
        self.straggler = straggler or StragglerPolicy()
        self.failures = 0

    def run(self, state, start_step: int, n_steps: int):
        step = start_step
        retries = 0
        last_saved = None            # step of the newest checkpoint this run
        initial = (start_step, state)
        while step < n_steps:
            try:
                # monotonic: a wall-clock (NTP) step during the step_fn
                # call must not read as a straggler strike
                t0 = time.monotonic()
                state = self.step_fn(state, self.next_batch(step))
                self.straggler.observe(time.monotonic() - t0)
                step += 1
                retries = 0
                if step % self.save_every == 0:
                    self.save_fn(step, state)
                    last_saved = step
            except StragglerError:
                self.save_fn(step, state)
                raise
            except Exception:                      # noqa: BLE001
                self.failures += 1
                retries += 1
                if retries > self.max_retries:
                    raise
                time.sleep(self.backoff * (2 ** (retries - 1)))
                # a failure before the first save may have no checkpoint to
                # restore: replay from the caller's initial (step, state)
                # instead of crashing inside restore_fn.  ONLY a missing
                # checkpoint qualifies — a present-but-corrupt one (or a
                # transient I/O error) must surface, not silently restart
                # training from scratch.
                try:
                    step, state = self.restore_fn()
                except FileNotFoundError:
                    if last_saved is not None:
                        raise
                    step, state = initial
        # the tail n_steps % save_every steps used to be lost: a crash
        # after run() returned replayed them from the last periodic save.
        # (step > start_step: a zero-step invocation must stay I/O-free,
        # not rewrite an existing checkpoint.)  The save gets the same
        # transient-failure budget as a training step — a completed run
        # must not abort on one flaky write — but ultimately raises:
        # silently losing the final checkpoint is the bug being fixed
        if last_saved != step and step > start_step:
            for attempt in range(self.max_retries + 1):
                try:
                    self.save_fn(step, state)
                    break
                except Exception:                  # noqa: BLE001
                    self.failures += 1
                    if attempt == self.max_retries:
                        raise
                    time.sleep(self.backoff * (2 ** attempt))
        return step, state
