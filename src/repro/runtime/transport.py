"""Multi-host party runtime: a real transport behind the Channel contract
(DESIGN.md §10).

The whole protocol — training (per-layer ``assign_sync`` -> ``split_infos``
-> batched decrypt, §8) and serving (one ``predict_bits`` round-trip per
host per batch, §9) — already flows through tagged, serializable messages
(``core/tree.py``, ``serving/engine.py``).  This module gives those
messages a wire:

* a **payload codec**: numpy/limb tensors, python-int object arrays
  (Paillier ciphertexts), ints/floats/strs/bytes and nested
  tuples/lists/dicts <-> length-prefixed binary.  No pickle anywhere on
  the wire.
* **framed endpoints**: a length-prefixed TCP socket transport and an
  in-memory loopback with the identical framing (the loopback pumps the
  peer inline — single-threaded, deterministic, still exercising the full
  encode/decode path).
* :class:`TransportChannel` — a :class:`~repro.core.party.Channel` whose
  ``send`` *ships* outgoing frames and whose ``recv`` records incoming
  ones, so each party's ledger converges to the same per-tag byte totals
  as the in-process shared ledger (the oracle).  Actual framed socket
  bytes are tallied separately (``tx_bytes``/``rx_bytes``) so the
  analytic wire model (paper eqs 10/16) can be compared against what the
  socket really moved.
* :class:`PartyProcess` — hosts ONE party per OS process for both
  training (drives the party's :class:`~repro.core.tree.HostRuntime`) and
  serving (a :class:`~repro.serving.engine.PartyBits` evaluator built
  from the host's own reloaded export half).
* :class:`MultiHostRun` — guest-side orchestration: spawn host processes,
  train over the sockets, export per-party halves, serve from the
  reloaded halves.

A forced-2-process run is bit-identical to the in-process ``Channel`` run
with identical per-tag ledgers and round-trip counts (asserted in
``tests/test_transport.py``).
"""

from __future__ import annotations

import os
import random as _random
import socket as _socket
import struct
import threading
import time
from collections import Counter, deque

import numpy as np

from ..analysis import schema as wire
from ..analysis.schema import KIND_CTRL, KIND_PROTO, WireSchemaError
from ..core.party import Channel, Stats
from ..obs import trace as obs_trace
from ..obs.metrics import MetricsRegistry
from ..obs.trace import NULL_TRACER, Tracer

_U32 = struct.Struct("!I")
_I64 = struct.Struct("!q")
_F64 = struct.Struct("!d")


class TransportError(RuntimeError):
    pass


class PeerRestarted(TransportError):
    """A lost peer was re-acquired (respawned or re-dialed), but its
    protocol state for the in-flight unit of work is gone: the caller
    must replay from the last resume boundary (the per-tree snapshot),
    not retry the failed frame."""


class RemoteError(TransportError):
    """The peer ANSWERED — with an application-level error frame.  The
    peer is alive and the connection is fine, so this must bypass both
    the retry/reconnect ladder (retrying a deterministic protocol error
    loops forever) and the serving-mode ``PartyUnavailable`` conversion
    (an answering party is not an unavailable one)."""


# one frame may legitimately carry a whole ciphertext batch, but a frame
# claiming more than this is a corrupt/hostile length prefix — refusing
# it bounds what a single bad u32 can make us allocate
MAX_FRAME_BYTES = 1 << 30


def conformance_check(kind, src, dst, tag, payload) -> None:
    """Opt-in ship-time schema validation (``wire.set_conformance(True)``
    or ``REPRO_WIRE_CONFORMANCE=1``).  A violation is a transport-layer
    refusal — the frame never reaches the socket."""
    if not wire.conformance_enabled():
        return
    try:
        wire.validate(kind, src, dst, tag, payload)
    except WireSchemaError as e:
        raise TransportError(f"wire schema violation: {e}") from e


# ---------------------------------------------------------------------------
# payload codec (no pickle on the wire)
# ---------------------------------------------------------------------------

def _enc_str(out: bytearray, s: str) -> None:
    b = s.encode("utf-8")
    out += _U32.pack(len(b))
    out += b


def _enc_bigint(out: bytearray, x: int) -> None:
    sign = 1 if x < 0 else 0
    raw = abs(x).to_bytes((abs(x).bit_length() + 7) // 8 or 1, "big")
    out += bytes([sign])
    out += _U32.pack(len(raw))
    out += raw


def _encode(obj, out: bytearray) -> None:
    if obj is None:
        out += b"N"
    elif isinstance(obj, (bool, np.bool_)):
        out += (b"T" if obj else b"F")
    elif isinstance(obj, (int, np.integer)):
        x = int(obj)
        if -(2 ** 63) <= x < 2 ** 63:
            out += b"i"
            out += _I64.pack(x)
        else:
            out += b"I"
            _enc_bigint(out, x)
    elif isinstance(obj, (float, np.floating)):
        out += b"f"
        out += _F64.pack(float(obj))
    elif isinstance(obj, str):
        out += b"s"
        _enc_str(out, obj)
    elif isinstance(obj, (bytes, bytearray)):
        out += b"b"
        out += _U32.pack(len(obj))
        out += bytes(obj)
    elif isinstance(obj, tuple):
        out += b"u"
        out += _U32.pack(len(obj))
        for it in obj:
            _encode(it, out)
    elif isinstance(obj, list):
        out += b"l"
        out += _U32.pack(len(obj))
        for it in obj:
            _encode(it, out)
    elif isinstance(obj, dict):
        out += b"d"
        out += _U32.pack(len(obj))
        for k, v in obj.items():
            _encode(k, out)
            _encode(v, out)
    else:
        if not isinstance(obj, np.ndarray) and hasattr(obj, "__array__"):
            obj = np.asarray(obj)       # jax arrays land here (sync point)
        if not isinstance(obj, np.ndarray):
            raise TransportError(f"unserializable payload type "
                                 f"{type(obj).__name__}")
        if obj.dtype == object:
            # Paillier ciphertexts / decrypted ints: python bigints
            out += b"O"
            out += bytes([obj.ndim])
            for d in obj.shape:
                out += _I64.pack(d)
            for x in obj.reshape(-1).tolist():
                if not isinstance(x, int):
                    raise TransportError(
                        f"object arrays may only carry python ints, got "
                        f"{type(x).__name__}")
                _enc_bigint(out, x)
        else:
            out += b"a"
            _enc_str(out, str(obj.dtype))
            out += bytes([obj.ndim])
            for d in obj.shape:
                out += _I64.pack(d)
            out += np.ascontiguousarray(obj).tobytes()


def encode_payload(obj) -> bytes:
    out = bytearray()
    _encode(obj, out)
    return bytes(out)


class _Reader:
    def __init__(self, buf: bytes):
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        b = self.buf[self.pos: self.pos + n]
        if len(b) != n:
            raise TransportError("truncated payload")
        self.pos += n
        return b

    def u32(self) -> int:
        return _U32.unpack(self.take(4))[0]

    def i64(self) -> int:
        return _I64.unpack(self.take(8))[0]

    def string(self) -> str:
        return self.take(self.u32()).decode("utf-8")

    def bigint(self) -> int:
        sign = self.take(1)[0]
        raw = self.take(self.u32())
        x = int.from_bytes(raw, "big")
        return -x if sign else x


def _decode(r: _Reader):
    t = r.take(1)
    if t == b"N":
        return None
    if t == b"T":
        return True
    if t == b"F":
        return False
    if t == b"i":
        return r.i64()
    if t == b"I":
        return r.bigint()
    if t == b"f":
        return _F64.unpack(r.take(8))[0]
    if t == b"s":
        return r.string()
    if t == b"b":
        return r.take(r.u32())
    if t == b"u":
        return tuple(_decode(r) for _ in range(r.u32()))
    if t == b"l":
        return [_decode(r) for _ in range(r.u32())]
    if t == b"d":
        return {_decode(r): _decode(r) for _ in range(r.u32())}
    if t == b"a":
        dtype = np.dtype(r.string())
        shape = _shape(r)
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        arr = np.frombuffer(r.take(n * dtype.itemsize), dtype=dtype)
        return arr.reshape(shape).copy()
    if t == b"O":
        shape = _shape(r)
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        # each bigint needs >= 6 encoded bytes: bound the object-array
        # allocation by what the buffer could possibly hold BEFORE
        # np.empty, or a forged shape header allocates n*8 bytes for free
        if n * 6 > len(r.buf) - r.pos:
            raise TransportError("object-array shape exceeds payload")
        arr = np.empty(n, dtype=object)
        for i in range(n):
            arr[i] = r.bigint()
        return arr.reshape(shape)
    raise TransportError(f"bad payload type byte {t!r}")


def _shape(r: _Reader) -> tuple:
    shape = tuple(r.i64() for _ in range(r.take(1)[0]))
    if any(d < 0 for d in shape) \
            or int(np.prod(shape, dtype=np.float64)) > 2 ** 62:
        raise TransportError(f"bad array shape {shape}")
    return shape


def decode_payload(buf: bytes):
    r = _Reader(buf)
    try:
        obj = _decode(r)
    except TransportError:
        raise
    except Exception as e:          # noqa: BLE001 -- fuzz contract: any
        # malformed byte stream (bad dtype string, non-utf8, numpy/struct
        # refusals) surfaces as TransportError, never as a random
        # internal exception the framing layer can't classify
        raise TransportError(f"malformed payload: "
                             f"{type(e).__name__}: {e}") from e
    if r.pos != len(buf):
        raise TransportError("trailing bytes in payload")
    return obj


# ---------------------------------------------------------------------------
# framing + endpoints
# ---------------------------------------------------------------------------

def encode_frame(kind: int, src: str, dst: str, tag: str, nbytes: int,
                 payload, payload_bytes: bytes | None = None,
                 seq: int = 0) -> bytes:
    out = bytearray([kind])
    _enc_str(out, src)
    _enc_str(out, dst)
    _enc_str(out, tag)
    out += _I64.pack(int(seq))
    out += _I64.pack(int(nbytes))
    out += (payload_bytes if payload_bytes is not None
            else encode_payload(payload))
    return bytes(out)


def decode_frame(buf: bytes) -> tuple:
    r = _Reader(buf)
    try:
        kind = r.take(1)[0]
        if kind not in (KIND_PROTO, KIND_CTRL):
            raise TransportError(f"bad frame kind byte {kind}")
        src, dst, tag = r.string(), r.string(), r.string()
        seq = r.i64()
        nbytes = r.i64()
    except TransportError:
        raise
    except Exception as e:          # noqa: BLE001
        raise TransportError(f"malformed frame header: "
                             f"{type(e).__name__}: {e}") from e
    payload = decode_payload(buf[r.pos:])
    return kind, src, dst, tag, seq, nbytes, payload


def peek_frame_header(buf: bytes) -> tuple:
    """(kind, src, dst, tag, seq) without touching the payload — what the
    fault-injection layer matches rules against (decoding a multi-MB
    ciphertext batch just to learn its tag would make chaos mode alter
    the timing it is trying to perturb)."""
    r = _Reader(buf)
    kind = r.take(1)[0]
    src, dst, tag = r.string(), r.string(), r.string()
    return kind, src, dst, tag, r.i64()


class SocketEndpoint:
    """Length-prefixed frames over one TCP connection (TCP_NODELAY: the
    protocol is strict request/reply, Nagle only adds latency)."""

    def __init__(self, sock: _socket.socket):
        sock.setsockopt(_socket.IPPROTO_TCP, _socket.TCP_NODELAY, 1)
        self.sock = sock
        self.dead = False

    def send_bytes(self, frame: bytes) -> None:
        if self.dead:
            raise TransportError("endpoint is dead (mid-frame timeout): "
                                 "reconnect before sending")
        try:
            self.sock.sendall(_U32.pack(len(frame)) + frame)
        except OSError as e:
            raise TransportError(f"send failed: {e}") from e

    def _read_exact(self, n: int) -> bytes:
        buf = bytearray(n)
        view = memoryview(buf)
        got = 0
        while got < n:
            try:
                r = self.sock.recv_into(view[got:], n - got)
            except _socket.timeout:
                raise
            except OSError as e:
                raise TransportError(f"recv failed: {e}") from e
            if r == 0:
                raise TransportError("peer closed the connection")
            got += r
        return bytes(buf)

    def recv_bytes(self, timeout: float | None = None) -> bytes:
        if self.dead:
            raise TransportError("endpoint is dead (mid-frame timeout): "
                                 "reconnect before receiving")
        try:
            self.sock.settimeout(timeout)
        except OSError as e:        # closed under us (chaos / supervisor)
            raise TransportError(f"recv failed: {e}") from e
        try:
            n = _U32.unpack(self._read_exact(4))[0]
            if n > MAX_FRAME_BYTES:
                self.dead = True            # prefix is garbage: framing lost
                self.close()
                raise TransportError(f"frame length {n} exceeds "
                                     f"{MAX_FRAME_BYTES} (corrupt prefix)")
            return self._read_exact(n)
        except _socket.timeout as e:
            # the timeout may have fired AFTER the length prefix (or part
            # of the body) was consumed: the stream is mid-frame, and the
            # next recv would decode body bytes as a length prefix.  A
            # timed-out endpoint is dead — callers must reconnect.
            self.dead = True
            self.close()
            raise TransportError(f"recv timed out after {timeout}s "
                                 f"(endpoint closed: stream may be "
                                 f"mid-frame)") from e

    def poll(self) -> bool:
        import select
        return bool(select.select([self.sock], [], [], 0)[0])

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


class LoopbackEndpoint:
    """In-memory endpoint with the same framed interface.  ``on_deliver``
    (when set on the *receiving* end) is invoked after each delivery —
    the inline pump that lets a PartyProcess handle frames synchronously
    inside the sender's call, single-threaded and deterministic."""

    def __init__(self):
        self.inbox: deque = deque()
        self.peer: "LoopbackEndpoint | None" = None
        self.on_deliver = None
        self.closed = False

    @classmethod
    def pair(cls) -> tuple:
        a, b = cls(), cls()
        a.peer, b.peer = b, a
        return a, b

    def send_bytes(self, frame: bytes) -> None:
        if self.peer is None or self.peer.closed:
            raise TransportError("loopback peer closed")
        self.peer.inbox.append(frame)
        if self.peer.on_deliver is not None:
            self.peer.on_deliver()

    def recv_bytes(self, timeout: float | None = None) -> bytes:
        if not self.inbox:
            raise TransportError("loopback recv on empty inbox (protocol "
                                 "desync: no pending frame)")
        return self.inbox.popleft()

    def poll(self) -> bool:
        return bool(self.inbox)

    def close(self) -> None:
        self.closed = True


# ---------------------------------------------------------------------------
# the channel over a transport
# ---------------------------------------------------------------------------

class _BrokerInbox:
    """Async receive queue for one peer (DESIGN.md §12).

    A broker thread drains the peer's endpoint continuously — every frame
    is read off the socket, decoded, mirrored into the ledger and deduped
    by seq the moment it ARRIVES, then parked in a per-tag inbox.  The
    protocol thread consumes from the inboxes instead of the socket, so a
    pipelined guest's ``enc_gh`` for round r+1 is accepted (bytes moved,
    payload decoded) while the party is still deep in round r's histogram
    compute.  Consumption is arrival-ordered by default (``pop()``); a
    caller that knows its tag may pull past queued frames of other tags
    (``pop(tag=...)``) — ledger convergence is unaffected because the
    mirror happens at ingest, not at consumption.

    A transport failure poisons the inbox: the pending error re-raises on
    every subsequent pop until :meth:`TransportChannel.start_broker` is
    called again over a fresh endpoint (the host re-dial loop does this).
    """

    def __init__(self, channel: "TransportChannel", src: str):
        self.channel = channel
        self.src = src
        self.cond = threading.Condition()
        self.inbox: dict = {}       # tag -> deque of ingested frames
        self.order: deque = deque()  # tags in arrival order
        self.err: BaseException | None = None
        self.stop = False
        self.thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"broker-{channel.party}-{src}")

    def _run(self) -> None:
        ch = self.channel
        while not self.stop:
            try:
                ep = ch.peers.get(self.src)
                if ep is None:
                    raise TransportError(f"{ch.party}: no endpoint for "
                                         f"{self.src!r}")
                t0 = time.perf_counter()
                frame = ep.recv_bytes(ch.timeout)
                got = ch._ingest(frame, t0)
            except BaseException as e:          # noqa: BLE001 -- poison:
                # the protocol thread re-raises this from its next pop
                with self.cond:
                    if not self.stop:
                        self.err = e
                    self.cond.notify_all()
                return
            if got is None:
                continue                        # skimmed / deduped
            with self.cond:
                self.inbox.setdefault(got[3], deque()).append(got)
                self.order.append(got[3])
                depth = len(self.order)
                self.cond.notify_all()
            ch.metrics.gauge("broker_depth").observe(depth)
            if ch.tracer.enabled:
                ch.tracer.instant("broker_park", cat="transport",
                                  src=self.src, tag=got[3], depth=depth)

    def _waited(self, got, t_ns: int):
        """Emit the protocol thread's park-to-pop wait as a span."""
        tr = self.channel.tracer
        if tr.enabled:
            tr.complete("broker_pop", t_ns, time.perf_counter_ns() - t_ns,
                        cat="transport", src=self.src, tag=got[3])
        return got

    def pop(self, tag: str | None = None, timeout: float | None = None):
        """Next ingested frame — arrival order, or first frame of ``tag``."""
        t_ns = (time.perf_counter_ns() if self.channel.tracer.enabled
                else 0)
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        with self.cond:
            while True:
                if tag is None:
                    if self.order:
                        return self._waited(
                            self.inbox[self.order.popleft()].popleft(), t_ns)
                else:
                    q = self.inbox.get(tag)
                    if q:
                        self.order.remove(tag)   # earliest entry of tag
                        return self._waited(q.popleft(), t_ns)
                if self.err is not None:
                    raise self.err
                budget = (None if deadline is None
                          else deadline - time.monotonic())
                if budget is not None and budget <= 0:
                    raise TransportError(
                        f"{self.channel.party}: broker recv of "
                        f"{tag or 'any'!r} from {self.src} timed out "
                        f"after {timeout}s")
                self.cond.wait(budget)

    def try_pop(self):
        with self.cond:
            if self.order:
                return self.inbox[self.order.popleft()].popleft()
            if self.err is not None:
                raise self.err
            return None

    def pending(self, tag: str) -> int:
        with self.cond:
            return len(self.inbox.get(tag, ()))


class TransportChannel(Channel):
    """The Channel contract over real endpoints.

    ``send`` keeps the exact in-process accounting (same tags, same
    analytic nbytes) and additionally ships the frame when ``dst`` is a
    remote peer; ``recv`` decodes one incoming frame and records it in
    the ledger, so a 2-party conversation yields the same per-tag ledger
    on each side as the single in-process ledger does.  Framed bytes that
    actually crossed the transport are counted per tag in
    ``tx_bytes``/``rx_bytes`` (control frames included): the gap between
    those and the ledger is the protocol-vs-socket overhead the
    transport benchmark reports.
    """

    def __init__(self, party: str, peers: dict, timeout: float = 600.0,
                 max_retries: int = 2, retry_backoff: float = 0.05):
        super().__init__()
        self.party = party
        self.peers = peers
        self.timeout = timeout
        self.max_retries = max_retries
        self.retry_backoff = retry_backoff
        self.tx_bytes = Counter()       # tag -> framed bytes shipped
        self.rx_bytes = Counter()       # tag -> framed bytes received
        self._enc_memo = (object(), b"")    # one-slot broadcast memo
                                            # (sentinel: matches nothing)
        # sequence numbers: every PROTOCOL frame carries a per-(peer, tag)
        # seq so the receiver can count retransmitted/replayed frames in
        # its mirrored ledger exactly once (DESIGN.md §11)
        self.send_seq = Counter()       # (dst, tag) -> last seq sent
        self.last_seen = Counter()      # (src, tag) -> last seq mirrored
        # reconnect hook: called with the peer name after a failed
        # send/recv; reestablishes the endpoint (guest: accept+respawn —
        # raises PeerRestarted to force a tree replay; host: re-dial —
        # returns, and the retried op resumes against the new socket)
        self.reconnect = None
        self.on_rtt = None              # (peer, tag, seconds) per recv
        self.on_ctrl = None             # skim hook for async control
                                        # frames (supervisor hb_ack)
        self.serving_mode = False       # typed PartyUnavailable errors
        self._send_locks: dict = {}     # per-peer: supervisor thread pings
                                        # must not interleave frame bytes
                                        # with training-thread sends
        self._brokers: dict = {}        # src -> _BrokerInbox (async recv)
        self._mirror_lock = threading.Lock()    # rx/tx byte counters are
                                        # touched by broker + send threads
        self._jitter = _random.Random(len(party) * 2654435761 + 17)
        # transport-plane instruments (per-tag RTT histograms, broker
        # queue depth, retry count) — separate from Stats.metrics, which
        # holds TRAINING timers; both surface through the status frame
        self.metrics = MetricsRegistry()

    def _send_lock(self, dst: str):
        lock = self._send_locks.get(dst)
        if lock is None:
            lock = self._send_locks[dst] = threading.Lock()
        return lock

    # -- retry ----------------------------------------------------------
    def _with_retry(self, op, peer: str):
        """Run ``op`` with exponential backoff + jitter; between attempts
        let the reconnect hook reestablish the peer's endpoint."""
        delay = self.retry_backoff
        for attempt in range(self.max_retries + 1):
            try:
                return op()
            except (PeerRestarted, RemoteError):
                raise               # replay / surface: never blind-retry
            except TransportError as e:
                if self.serving_mode and peer.startswith("host"):
                    from ..core.party import PartyUnavailable
                    raise PartyUnavailable(peer, str(e)) from e
                if attempt == self.max_retries:
                    raise
                self.metrics.counter("transport_retries").add()
                if self.tracer.enabled:
                    self.tracer.instant("retry", cat="transport", peer=peer,
                                        attempt=attempt + 1,
                                        error=type(e).__name__)
                if self.reconnect is not None:
                    self.reconnect(peer)    # may raise PeerRestarted
                time.sleep(delay + self._jitter.uniform(0.0, delay / 2))
                delay *= 2

    # -- outgoing -------------------------------------------------------
    def send(self, src: str, dst: str, tag: str, payload, nbytes: int):
        super().send(src, dst, tag, payload, nbytes)
        if dst != self.party:
            self.send_seq[(dst, tag)] += 1
            seq = self.send_seq[(dst, tag)]
            self._with_retry(
                lambda: self._ship(KIND_PROTO, src, dst, tag, nbytes,
                                   payload, seq), dst)
        return payload

    def control_send(self, dst: str, tag: str, payload) -> None:
        self._with_retry(
            lambda: self._ship(KIND_CTRL, self.party, dst, tag, 0, payload),
            dst)

    def _ship(self, kind, src, dst, tag, nbytes, payload, seq=0) -> None:
        ep = self.peers.get(dst)
        if ep is None:
            raise TransportError(f"{self.party}: no endpoint for {dst!r}")
        conformance_check(kind, src, dst, tag, payload)
        # broadcast memo: the guest sends the SAME payload object to every
        # host back to back (enc_gh ciphertext batch, layer plans) — encode
        # it once, not once per destination (the enc_gh encode includes a
        # jax device sync)
        memo_obj, payload_bytes = self._enc_memo
        if payload is not memo_obj:
            payload_bytes = encode_payload(payload)
            self._enc_memo = (payload, payload_bytes)
        frame = encode_frame(kind, src, dst, tag, nbytes, None,
                             payload_bytes=payload_bytes, seq=seq)
        t_ns = time.perf_counter_ns() if self.tracer.enabled else 0
        with self._send_lock(dst):
            ep.send_bytes(frame)
        with self._mirror_lock:
            self.tx_bytes[tag] += len(frame) + 4    # + length prefix
        if self.tracer.enabled:
            # physical view (framed bytes incl. prefix): cat "transport",
            # never "wire" — the ledger audit must not see frame overhead
            self.tracer.complete("ship", t_ns,
                                 time.perf_counter_ns() - t_ns,
                                 cat="transport", dst=dst, tag=tag,
                                 seq=int(seq), nbytes=len(frame) + 4)
        # a retried send re-enters here through peers[dst] (possibly a
        # fresh endpoint) with the SAME seq: the receiver dedupes

    # -- incoming -------------------------------------------------------
    def _ingest(self, frame: bytes, t0: float):
        """Decode, account, dedup and mirror ONE incoming frame.  Returns
        the ``(kind, src, dst, tag, payload)`` tuple, or None when the
        frame was swallowed (skimmed control ack, deduped retransmission).
        Shared by the synchronous read path and the broker thread — both
        must apply identical mirror/dedup semantics or the converged
        per-tag ledgers drift between brokered and unbrokered parties."""
        kind, fsrc, fdst, tag, seq, nbytes, payload = decode_frame(frame)
        with self._mirror_lock:
            self.rx_bytes[tag] += len(frame) + 4
        if kind == KIND_PROTO:
            # per-tag round-trip (recv-start to frame decoded) — feeds
            # the status snapshot alongside the straggler policy's view
            self.metrics.histogram(f"rtt:{tag}").observe(
                time.perf_counter() - t0)
        if self.tracer.enabled:
            self.tracer.instant("recv", cat="transport", src=fsrc, tag=tag,
                                seq=int(seq), nbytes=len(frame) + 4)
        if self.on_rtt is not None and kind == KIND_PROTO:
            self.on_rtt(fsrc, tag, time.perf_counter() - t0)
        if kind == KIND_CTRL and tag == wire.ERROR:
            # a peer's dying words: surface its actual failure instead
            # of a tag mismatch now / 'peer closed' later
            raise RemoteError(f"peer {fsrc} failed: {payload}")
        if kind == KIND_CTRL and self.on_ctrl is not None \
                and self.on_ctrl(fsrc, tag, payload):
            return None             # skimmed (liveness ack): not ours
        if kind == KIND_PROTO:
            if seq <= self.last_seen[(fsrc, tag)]:
                # retransmission of a frame already mirrored.  Counted
                # once; and — except for enc_gh, the idempotent tree
                # replay anchor — not re-delivered either, or a
                # duplicated chosen_sid would corrupt the frontier.
                if tag != wire.ENC_GH:
                    return None
            else:
                self.last_seen[(fsrc, tag)] = seq
                # mirror the sender's ledger entry (analytic nbytes
                # travels in the frame header) so each side's per-tag
                # totals converge to the in-process shared ledger
                Channel.send(self, fsrc, fdst, tag, payload, nbytes)
        return kind, fsrc, fdst, tag, payload

    def _read(self, src: str, timeout: float | None = None):
        br = self._brokers.get(src)
        if br is not None:
            return br.pop(timeout=self.timeout if timeout is None
                          else timeout)
        def op():
            return self._read_once(src, timeout)
        return self._with_retry(op, src)

    def _read_once(self, src: str, timeout: float | None = None):
        while True:
            ep = self.peers.get(src)
            if ep is None:
                raise TransportError(f"{self.party}: no endpoint for "
                                     f"{src!r}")
            t0 = time.perf_counter()
            frame = ep.recv_bytes(self.timeout if timeout is None
                                  else timeout)
            got = self._ingest(frame, t0)
            if got is not None:
                return got

    # -- async broker (pipelined mode, DESIGN.md §12) -------------------
    def start_broker(self, src: str) -> None:
        """Switch receives from ``src`` to an async broker: a reader
        thread drains the endpoint continuously into per-tag inboxes so
        frames are accepted the moment they arrive — a pipelined guest's
        next-round ``enc_gh`` no longer waits in kernel buffers behind
        the current round's compute.  Idempotent per connection: calling
        it again (after a re-dial swapped ``peers[src]``) replaces the
        poisoned broker with a fresh one."""
        old = self._brokers.pop(src, None)
        if old is not None:
            old.stop = True
        br = _BrokerInbox(self, src)
        self._brokers[src] = br
        br.thread.start()

    def stop_broker(self, src: str | None = None) -> None:
        for key in ([src] if src is not None else list(self._brokers)):
            br = self._brokers.pop(key, None)
            if br is not None:
                br.stop = True
                with br.cond:
                    br.cond.notify_all()

    def broker(self, src: str) -> "_BrokerInbox | None":
        return self._brokers.get(src)

    def recv(self, src: str, tag: str, timeout: float | None = None):
        """Blocking receive of one PROTOCOL frame from ``src``; the tag
        must match (the protocol is strict request/reply — anything else
        is a desync worth crashing on).  Over a broker the match is a
        *selection*: queued frames of other tags (the pipelined next
        round's ``enc_gh``) stay parked instead of tripping the desync
        check."""
        br = self._brokers.get(src)
        if br is not None:
            kind, _, _, ftag, payload = br.pop(
                tag=tag, timeout=self.timeout if timeout is None
                else timeout)
        else:
            kind, _, _, ftag, payload = self._read(src, timeout)
        if kind != KIND_PROTO or ftag != tag:
            raise TransportError(f"{self.party}: expected protocol frame "
                                 f"{tag!r} from {src}, got "
                                 f"{'ctrl' if kind else 'proto'}:{ftag!r}")
        return payload

    def control_recv(self, src: str, tag: str):
        br = self._brokers.get(src)
        if br is not None:
            kind, _, _, ftag, payload = br.pop(tag=tag,
                                               timeout=self.timeout)
        else:
            kind, _, _, ftag, payload = self._read(src)
        if kind != KIND_CTRL or ftag != tag:
            raise TransportError(f"{self.party}: expected control frame "
                                 f"{tag!r} from {src}, got "
                                 f"{'ctrl' if kind else 'proto'}:{ftag!r}")
        return payload

    def recv_any(self, src: str) -> tuple:
        """(kind, tag, payload) of the next frame from ``src`` — the
        PartyProcess serve loop."""
        kind, _, _, tag, payload = self._read(src)
        return kind, tag, payload

    def try_recv_any(self, src: str):
        br = self._brokers.get(src)
        if br is not None:
            got = br.try_pop()
            if got is None:
                return None
            kind, _, _, tag, payload = got
            return kind, tag, payload
        ep = self.peers.get(src)
        if ep is None or not ep.poll():
            return None
        return self.recv_any(src)

    # -- resume boundaries ---------------------------------------------
    def snapshot(self) -> dict:
        """Accounting + sequence state at a tree boundary.  Restoring
        rolls BOTH back, so a replayed tree re-sends frames with the same
        seqs (the peer, also rolled back, counts them fresh) — ledgers
        converge to the fault-free oracle.  ``tx_bytes``/``rx_bytes`` are
        deliberately NOT rolled back: they count what the socket really
        moved, retries included (that gap IS the cost of the fault)."""
        snap = super().snapshot()
        snap["send_seq"] = self.send_seq.copy()
        snap["last_seen"] = self.last_seen.copy()
        return snap

    def restore(self, snap: dict) -> None:
        super().restore(snap)
        self.send_seq = snap["send_seq"].copy()
        self.last_seen = snap["last_seen"].copy()

    def state_dump(self) -> dict:
        """The channel state a party must persist to rejoin a run after
        a process death: full ledger + seq counters, codec-serializable
        (tuple keys survive the payload codec round-trip)."""
        return {"ledger": [tuple(e) for e in self.ledger],
                "totals": dict(self.totals), "msgs": dict(self.msgs),
                "coll_ledger": [tuple(e) for e in self.coll_ledger],
                "coll_totals": dict(self.coll_totals),
                "coll_msgs": dict(self.coll_msgs),
                "send_seq": dict(self.send_seq),
                "last_seen": dict(self.last_seen)}

    def state_load(self, d: dict) -> None:
        self.ledger = [tuple(e) for e in d["ledger"]]
        self.totals = Counter(d["totals"])
        self.msgs = Counter(d["msgs"])
        self.coll_ledger = [tuple(e) for e in d["coll_ledger"]]
        self.coll_totals = Counter(d["coll_totals"])
        self.coll_msgs = Counter(d["coll_msgs"])
        self.send_seq = Counter(d["send_seq"])
        self.last_seen = Counter(d["last_seen"])

    def drain(self, src: str, until_ctrl: str | None = None,
              timeout: float = 1.0) -> int:
        """Discard pending frames from ``src`` WITHOUT mirroring them —
        the aborted attempt's in-flight replies; the rolled-back snapshot
        already forgot their sends.  With ``until_ctrl``, block (up to
        ``timeout`` per frame) until that control tag arrives — the
        resync barrier: a host answers ``resync`` only after flushing
        every previous reply into the stream, so everything drained
        before the ack is provably stale."""
        ep = self.peers.get(src)
        n = 0
        while ep is not None:
            if until_ctrl is None and not ep.poll():
                break
            frame = ep.recv_bytes(timeout)
            kind, _, _, tag, _, _, payload = decode_frame(frame)
            with self._mirror_lock:
                self.rx_bytes[tag] += len(frame) + 4
            if kind == KIND_CTRL and tag == wire.ERROR:
                raise TransportError(f"peer {src} failed: {payload}")
            if kind == KIND_CTRL and tag == until_ctrl:
                break
            n += 1
        return n

    # -- socket accounting ---------------------------------------------
    def reset_accounting(self) -> None:
        super().reset_accounting()
        with self._mirror_lock:
            self.tx_bytes.clear()
            self.rx_bytes.clear()
        self.send_seq.clear()
        self.last_seen.clear()
        self.metrics.clear()        # per-fit, like the byte counters

    @property
    def total_tx_bytes(self) -> int:
        with self._mirror_lock:
            return sum(self.tx_bytes.values())

    @property
    def total_rx_bytes(self) -> int:
        with self._mirror_lock:
            return sum(self.rx_bytes.values())

    def socket_summary(self) -> dict:
        with self._mirror_lock:
            tags = sorted(set(self.tx_bytes) | set(self.rx_bytes))
            return {t: {"tx": self.tx_bytes[t], "rx": self.rx_bytes[t]}
                    for t in tags}

    def close(self) -> None:
        self.stop_broker()
        for ep in self.peers.values():
            ep.close()


# ---------------------------------------------------------------------------
# guest-side handles
# ---------------------------------------------------------------------------

class RemoteHostHandle:
    """What the grower sees for a host living in another process: the
    guest's ``channel.send`` already shipped every guest->host message, so
    ``deliver`` is a no-op and ``collect`` blocks on the reply frame.
    Mirror of the in-process ``HostRuntime`` handle surface."""

    def __init__(self, channel: TransportChannel, hid: int):
        self.channel = channel
        self.hid = hid

    @property
    def table(self) -> dict:
        return {}           # host-private; never enters the guest process

    def bind(self, params, cipher, channel, stats) -> None:
        pass

    def deliver(self, tag: str, payload) -> None:
        pass

    def collect(self, tag: str):
        return self.channel.recv(f"host{self.hid}", tag)


class RemoteServingHost:
    """Serving-side handle: the host's PartyProcess computes its packed
    decision bits and answers the guest's ``predict_req``.

    ``serve_timeout`` bounds the reply wait: with ``serving_mode`` set on
    the channel, a down/late host surfaces as a typed
    :class:`~repro.core.party.PartyUnavailable` for THIS batch — never a
    hang, and never a partial-bits answer (the engine discards the whole
    batch on any party failure)."""

    def __init__(self, channel: TransportChannel, hid: int, k: int,
                 serve_timeout: float | None = None):
        self.channel = channel
        self.hid = hid
        self.k = int(k)
        self.serve_timeout = serve_timeout

    def predict_bits(self):
        return self.channel.recv(f"host{self.hid}", wire.PREDICT_BITS,
                                 self.serve_timeout)


# ---------------------------------------------------------------------------
# the party process (host side)
# ---------------------------------------------------------------------------

def _strip_private_key(cipher):
    """Reduce a cipher object to what a passive host may hold.

    The repro's cipher classes bundle keygen and BOTH key halves for the
    in-process simulation (key distribution here is a simulation
    shortcut: the host derives the shared parameters from the run config
    instead of a key-exchange handshake).  A host party only ever needs
    the public/evaluation surface — modulus, Barrett context, lazy
    reduce/sub, compress shifts — so the private material is deleted the
    moment the object exists: any host-side code path that reached for
    decrypt (or the affine scheme's symmetric encrypt) dies with an
    AttributeError instead of silently voiding the privacy boundary.
    ``plain`` is the keyless debugging cipher; nothing to strip.
    """
    for attr in ("T_dec", "T_enc", "a_inv_int", "a_int", "_lam", "_mu"):
        if hasattr(cipher, attr):
            delattr(cipher, attr)
    return cipher


class PartyProcess:
    """One host party, driven entirely by decoded frames.

    Training frames (``enc_gh`` / ``assign_sync`` / ``chosen_sid``) run the
    same :class:`~repro.core.tree.HostRuntime` handlers the in-process
    simulation runs — replies leave through this party's
    :class:`TransportChannel`.  Serving is set up by a ``serve_setup``
    control frame: the host builds its :class:`HostHalf` from its private
    per-tree tables + the guest-published bit-column key order, exports it
    to ``export_dir``, RELOADS it, and answers ``predict_req`` from the
    reloaded half (the per-party export is the process boundary).
    """

    def __init__(self, hid: int, params, X_host, channel: TransportChannel,
                 export_dir: str | None = None,
                 state_dir: str | None = None,
                 own_process: bool = False):
        from ..core.binning import (BinnedData, bin_features,
                                    bin_features_stream)
        from ..data.pipeline import RowBlocks
        self.hid = hid
        self.params = params
        self.channel = channel
        self.export_dir = export_dir
        self.state_dir = state_dir
        self.stats = Stats()
        if getattr(params, "trace", False):
            # one tracer per party; in a spawned host process it is ALSO
            # installed as the process default so chaos endpoints — which
            # wrap the transport before this channel existed — land their
            # injection instants here.  A loopback party shares the
            # GUEST's process, so it must never touch the default: the
            # enabled tracer would outlive this run and leak into later
            # trace=False fits in the same process (chaos is never
            # injected over loopback, so nothing is lost).
            self.tracer = Tracer(f"host{hid}")
            channel.tracer = self.tracer
            if own_process:
                obs_trace.set_default(self.tracer)
        else:
            # inherit whatever the embedder attached (NULL by default) —
            # never clobber a benchmark's process-default tracer
            self.tracer = channel.tracer
        # out-of-core sources (§13): a pre-binned BinnedData (pickles lean —
        # no device buffers — so it crosses the spawn boundary) or a chunked
        # RowBlocks source skip the monolithic fit; raw serving rows then
        # arrive per batch via the serve_data frame
        if isinstance(X_host, BinnedData):
            self.data = X_host
            self.X_serve = np.zeros((0, self.data.bins.shape[1]))
        elif isinstance(X_host, RowBlocks):
            self.data = bin_features_stream(X_host, params.n_bins,
                                            sparse=params.sparse,
                                            use_pallas=params.use_pallas)
            self.X_serve = np.zeros((0, self.data.bins.shape[1]))
        else:
            self.data = bin_features(np.asarray(X_host), params.n_bins,
                                     sparse=params.sparse,
                                     use_pallas=params.use_pallas)
            self.X_serve = np.asarray(X_host)
        self.cipher = None
        self.hr = None              # current tree's HostRuntime
        self.tables: dict = {}      # tree_idx -> {nid: (fid, bid)}
        self.server = None          # PartyBits after serve_setup
        self._serve_k = 0
        self._current_tree = None   # in-flight (possibly partial) tree
        self._complete: set = set()    # trees whose table is final
        self._tree_snaps: dict = {}    # tree -> channel snapshot at its
                                       # enc_gh boundary (replay rollback)
        self._tree_span: dict = {}     # base tree -> member count (round-
                                       # forest: one enc_gh covers k trees)
        # pipelined mode: a future tree's enc_gh arrives while the current
        # tree is still splitting — its runtime is built eagerly (cipher-
        # texts land device-resident) and staged here until the first
        # assign_sync that references the new tree activates it
        from ..core.frontier import FrontierBuffer
        self._staged = FrontierBuffer()
        self.staged_activations = 0    # trees that went through the
                                       # stage->activate path (pipelining
                                       # actually overlapped; test hook)
        # handle() runs from the serve loop AND (loopback pipelining) from
        # the guest's encrypt-pump thread via on_deliver: one frame's
        # protocol mutation at a time, in arrival order
        self._handle_lock = threading.RLock()
        self._load_state()

    # -- durable state (what a party persists to rejoin, DESIGN.md §11) -
    def _state_path(self) -> str | None:
        return (os.path.join(self.state_dir, f"host{self.hid}.state")
                if self.state_dir else None)

    def _persist_state(self) -> None:
        """Written at every enc_gh boundary: completed split tables +
        the channel's accounting/seq state AS OF that boundary.  A
        respawned process reloads this, the guest replays the one
        in-flight tree, and both ledgers converge — without it a crashed
        host would have to replay the whole run."""
        path = self._state_path()
        if path is None:
            return
        state = {"complete": sorted(self._complete),
                 "tables": {int(t): {int(nid): (int(f), int(b))
                                     for nid, (f, b) in
                                     self.tables[t].items()}
                            for t in self._complete},
                 "channel": self.channel.state_dump(),
                 "stats": self.stats.as_dict()}
        tmp = path + ".tmp"
        os.makedirs(os.path.dirname(tmp) or ".", exist_ok=True)
        with open(tmp, "wb") as f:
            f.write(encode_payload(state))
        os.replace(tmp, path)       # atomic: a crash mid-write keeps the
                                    # previous boundary's state

    def _load_state(self) -> None:
        path = self._state_path()
        if path is None or not os.path.exists(path):
            return
        with open(path, "rb") as f:
            state = decode_payload(f.read())
        self._complete = set(int(t) for t in state["complete"])
        self.tables = {int(t): {int(nid): (int(f), int(b))
                                for nid, (f, b) in tbl.items()}
                       for t, tbl in state["tables"].items()}
        self.channel.state_load(state["channel"])
        self.stats = Stats()
        self.stats.merge_counts(state["stats"])

    def resume_info(self) -> dict:
        """Handshake payload: how far this party's durable state reaches
        (the guest resumes from the MINIMUM across parties)."""
        return {"n_complete": len(self._complete),
                "last_seen": {f"{s}|{t}": int(v) for (s, t), v
                              in self.channel.last_seen.items()},
                "send_seq": {f"{d}|{t}": int(v) for (d, t), v
                             in self.channel.send_seq.items()}}

    # -- frame dispatch -------------------------------------------------
    def serve_forever(self) -> None:
        while True:
            kind, tag, payload = self.channel.recv_any("guest")
            try:
                cont = self.handle(kind, tag, payload)
            except Exception as e:             # noqa: BLE001
                # ship the real failure to the guest before dying: the
                # alternative is an opaque 'peer closed the connection'
                # on the guest's next recv
                try:
                    self.channel.control_send(
                        "guest", wire.ERROR,
                        f"host{self.hid} {type(e).__name__}: {e}")
                except Exception:              # noqa: BLE001
                    pass
                raise
            if not cont:
                return

    def pump(self) -> None:
        """Drain pending frames (loopback inline mode)."""
        with self._handle_lock:
            while True:
                got = self.channel.try_recv_any("guest")
                if got is None:
                    return
                self.handle(*got)

    def handle(self, kind: int, tag: str, payload) -> bool:
        with self._handle_lock:
            return self._handle(kind, tag, payload)

    def _handle(self, kind: int, tag: str, payload) -> bool:
        if kind == KIND_CTRL:
            return self._control(tag, payload)
        if tag == wire.ENC_GH:
            self._begin_tree(payload)
        elif tag in (wire.ASSIGN_SYNC, wire.CHOSEN_SID):
            tree = (payload.get("tree") if isinstance(payload, dict)
                    else None)
            if (tree is not None and self._current_tree is not None
                    and int(tree) != self._current_tree):
                # first frame of the NEXT pipelined tree: the staged
                # runtime takes over, the previous tree is final
                self._activate_tree(int(tree))
            self.hr.deliver(tag, payload)
            self.hr._outbox.clear()     # replies already shipped
        elif tag == wire.PREDICT_REQ:
            self._predict(payload)
        else:
            raise TransportError(f"host{self.hid}: unknown protocol tag "
                                 f"{tag!r}")
        return True

    # -- training -------------------------------------------------------
    def _complete_tree(self, base: int) -> None:
        """The tree (or whole round-forest span) rooted at ``base`` saw
        its last update: it joins the durable floor a respawn can resume
        from."""
        for t in range(base, base + self._tree_span.get(base, 1)):
            self._complete.add(t)

    def _build_runtime(self, payload):
        """Fresh engine + HostRuntime adopting this enc_gh batch — the
        ciphertexts land device-resident here.  Round-forest batches
        (``forest`` = k > 1) additionally get per-member split-table
        mirrors so serving export sees k member trees with local nids."""
        from ..core.histogram import CipherHistogram
        from ..core.tree import HostRuntime
        tree = int(payload["tree"])
        k = int(payload.get("forest", 0) or 0)
        if self.cipher is None:
            from ..core.boosting import cipher_kwargs
            from ..core.he import get_cipher
            self.cipher = _strip_private_key(
                get_cipher(self.params.cipher,
                           **cipher_kwargs(self.params)))
        engine = CipherHistogram(self.cipher, self.params.n_bins,
                                 sparse=self.params.sparse,
                                 use_pallas=self.params.use_pallas,
                                 stats=self.stats, tracer=self.tracer)
        hr = HostRuntime(hid=self.hid, data=self.data, engine=engine)
        hr.bind(self.params, self.cipher, self.channel, self.stats)
        hr.deliver(wire.ENC_GH, payload)
        if k > 1:
            sinks = {m: {} for m in range(k)}
            hr.table_sinks = sinks
            for m in range(k):
                self.tables[tree + m] = sinks[m]
            self._tree_span[tree] = k
        else:
            self.tables[tree] = hr.table
            self._tree_span[tree] = 1
        return hr

    def _begin_tree(self, payload) -> None:
        tree = int(payload["tree"])
        if isinstance(payload, dict) and int(payload.get("blk", 0) or 0) > 0:
            # later block of a chunked enc_gh (DESIGN.md §13): route to the
            # runtime already assembling this tree — active, or
            # pipelined-staged — with NO boundary actions; blk 0 was the
            # tree boundary (snapshot/persist/stage happened there).  A
            # block for a tree we are not assembling is a stale
            # re-delivery after a replay restart (the replay anchor
            # re-ships from blk 0): drop it.
            if self._staged.staged(tree):
                self._staged.peek(tree).deliver(wire.ENC_GH, payload)
            elif self._current_tree == tree and self.hr is not None:
                self.hr.deliver(wire.ENC_GH, payload)
            return
        if (getattr(self.params, "pipeline", False)
                and self._current_tree is not None
                and self._current_tree != tree):
            # pipelined prefetch: this tree's ciphertexts arrived while
            # the current tree is still splitting.  Build its runtime
            # eagerly — wire+decode+device placement hidden behind the
            # in-flight tree's compute — but do NOT disturb the active
            # state; the first assign_sync naming this tree activates it.
            self._staged.stage(tree, self._build_runtime(payload))
            return
        if self._current_tree is not None and self._current_tree != tree:
            # the previous tree's table saw its last update: it is now
            # part of the durable floor a respawn can resume from
            self._complete_tree(self._current_tree)
        if tree in self._tree_snaps:
            # a REPLAYED tree (the guest rolled back to this boundary
            # after a fault): roll our accounting and seq counters back
            # too, so the replay's frames are counted fresh, exactly once
            self.channel.restore(self._tree_snaps[tree])
            for t in range(tree, tree + self._tree_span.get(tree, 1)):
                self._complete.discard(t)
        self._current_tree = tree
        self._persist_state()       # durable state AS OF this boundary
        self._tree_snaps[tree] = self.channel.snapshot()
        self.hr = self._build_runtime(payload)

    def _activate_tree(self, tree: int) -> None:
        if not self._staged.staged(tree):
            raise TransportError(
                f"host{self.hid}: assign_sync references tree {tree} but "
                f"no staged enc_gh (current {self._current_tree}) — "
                f"protocol desync")
        if self._current_tree is not None:
            self._complete_tree(self._current_tree)
        self._current_tree = tree
        self._persist_state()
        self._tree_snaps[tree] = self.channel.snapshot()
        self.hr = self._staged.activate(tree)
        self.staged_activations += 1

    # -- serving --------------------------------------------------------
    def _serve_setup(self, payload) -> None:
        from ..kernels.common import default_interpret
        from ..serving.engine import PartyBits
        from ..serving.export import export_host, load_host
        from ..serving.packed import host_half_from_keys
        if self._current_tree is not None:
            # training is over: the in-flight tree's table is final —
            # make it durable before serving depends on it
            self._complete_tree(self._current_tree)
            self._current_tree = None
            self._persist_state()
        keys = [(int(ti), int(nid)) for ti, nid in payload["keys"]]
        half = host_half_from_keys(self.hid, keys, self.tables,
                                   self.data.thresholds, self.params.n_bins)
        # the guest names the export root in the setup frame so one
        # serve() call produces ONE coherent per-party tree; the
        # constructor's export_dir is only the fallback
        export_dir = payload.get("export_dir", self.export_dir)
        if export_dir:
            out = export_host(half, os.path.join(export_dir,
                                                 f"host{self.hid}"))
            half = load_host(out)   # serve from the RELOADED export
        use_pallas = self.params.use_pallas and not default_interpret()
        self._serve_k = half.table.k
        self.server = (PartyBits(half.table, half.thresholds, half.n_bins,
                                 use_pallas)
                       if half.table.k else None)
        self.channel.control_send("guest", wire.SERVE_READY,
                                  {"k": self._serve_k})

    def _predict(self, req) -> None:
        ids = np.asarray(req["ids"])
        n = len(ids)
        n_pad = int(req["n_pad"])
        if n and int(ids.max()) >= len(self.X_serve):
            # application-level rejection (RemoteError): the party is
            # alive and answering, so serving must NOT type this as
            # PartyUnavailable or burn reconnect retries on it
            raise RemoteError(
                f"host{self.hid}: predict_req references row "
                f"{int(ids.max())} but only {len(self.X_serve)} rows are "
                f"staged — ship this batch's host rows first "
                f"(MultiHostRun.stage_host_data / the serve_data frame)")
        pb = self.server.packed_from_X(self.X_serve[ids], n_pad)
        # round-trips are counted ONCE, at the guest's collect site (the
        # same place the in-process engine counts them) — not here, or
        # merged_stats would double-count every batch
        self.channel.send(f"host{self.hid}", "guest", wire.PREDICT_BITS, pb,
                          self._serve_k * ((n + 7) // 8))

    # -- introspection --------------------------------------------------
    def status(self) -> dict:
        """Live snapshot of this party: Stats, training metrics,
        transport metrics, ledger, trace occupancy, protocol position.
        The ``status`` control frame returns exactly this dict."""
        return {"hid": self.hid,
                "stats": self.stats.as_dict(),
                "metrics": self.stats.metrics.snapshot(),
                "transport": self.channel.metrics.snapshot(),
                "ledger": self.channel.summary(),
                "socket": self.channel.socket_summary(),
                "trace": {"enabled": bool(self.tracer.enabled),
                          "events": len(self.tracer),
                          "dropped": int(self.tracer.dropped)},
                "current_tree": (int(self._current_tree)
                                 if self._current_tree is not None
                                 else None),
                "n_complete": len(self._complete)}

    # -- control --------------------------------------------------------
    def _control(self, tag: str, payload) -> bool:
        if tag == wire.SERVE_SETUP:
            self._serve_setup(payload)
        elif tag == wire.SERVE_DATA:
            # out-of-band data staging: in a real deployment each party
            # pulls the batch's rows from its OWN source; the control
            # plane simulates that arrival.  predict_req still carries
            # only instance ids.
            self.X_serve = np.asarray(payload["X"])
        elif tag == wire.RESET_STATS:
            # a refit starts: fresh Stats (the next enc_gh's engine binds
            # to it) and fresh per-fit wire accounting, mirroring the
            # fresh model the guest constructs
            self.stats = Stats()
            self.channel.reset_accounting()
            self.tracer.clear()     # per-fit, like the ledger
        elif tag == wire.GET_STATS:
            self.channel.control_send(
                "guest", wire.STATS,
                {"stats": self.stats.as_dict(),
                 "ledger": self.channel.summary(),
                 "socket": self.channel.socket_summary()})
        elif tag == wire.STATUS:
            self.channel.control_send("guest", wire.STATUS_REPLY,
                                      self.status())
        elif tag == wire.TRACE_SYNC:
            # ship this party's trace ring to the guest, stamped with our
            # perf_counter_ns clock: the guest's send/recv times around
            # this round-trip give one NTP-style offset sample (min-RTT
            # across these + heartbeat samples wins, obs/export.py)
            self.channel.control_send(
                "guest", wire.TRACE_DUMP,
                {"hid": self.hid,
                 "clock": time.perf_counter_ns(),
                 "events": self.tracer.export_events(),
                 "dropped": int(self.tracer.dropped)})
            if isinstance(payload, dict) and payload.get("clear"):
                self.tracer.clear()
        elif tag == wire.PING:
            self.channel.control_send("guest", wire.PONG, payload)
        elif tag == wire.HB:
            # liveness probe from the guest's supervisor thread: the ack
            # is skimmed by the guest's recv loop, never blocking the
            # protocol (a wedged host simply never reaches this branch).
            # Echo the payload and add our monotonic clock — each ack is
            # a free clock-offset sample for trace merging.
            ack = dict(payload) if isinstance(payload, dict) else {}
            ack["clock"] = time.perf_counter_ns()
            self.channel.control_send("guest", wire.HB_ACK, ack)
        elif tag == wire.RESYNC:
            # reconnect barrier: by the time this frame is processed,
            # every reply this host owed for earlier frames has already
            # been written to the stream (frames are handled in order) —
            # the guest drains until this ack and the stream is clean
            self.channel.control_send("guest", wire.RESYNC_ACK, payload)
        elif tag == wire.BYE:
            return False
        else:
            raise TransportError(f"host{self.hid}: unknown control tag "
                                 f"{tag!r}")
        return True


def _wrap_fault(ep, fault_plan):
    if fault_plan is None:
        return ep
    from .chaos import FaultyEndpoint
    return FaultyEndpoint(ep, fault_plan)


def host_main(port: int, hid: int, params, X_host,
              export_dir: str | None = None,
              state_dir: str | None = None, run_id: str = "",
              fault_plan=None, timeout: float = 600.0,
              max_redials: int = 8, redial_backoff: float = 0.1) -> None:
    """Entry point of a spawned host process: connect to the guest's
    listener, perform the session handshake (run id, party id, resume
    floor), serve frames until ``bye``.  On connection loss the process
    RE-DIALS with exponential backoff + jitter and carries on — its
    in-memory state (tables, ledger, seq counters) survives, and the
    guest's tree replay brings the protocol back in step.  Only a process
    death loses memory state, which is what ``state_dir`` is for."""
    jitter = _random.Random((hid + 1) * 7919)
    pp = None
    channel = None
    redials = 0
    fault_plan = fault_plan.fresh() if fault_plan is not None else None
    while True:
        try:
            sock = _socket.create_connection(("127.0.0.1", port),
                                             timeout=timeout)
            ep = _wrap_fault(SocketEndpoint(sock), fault_plan)
        except OSError as e:
            redials += 1
            if redials > max_redials:
                raise TransportError(
                    f"host{hid}: guest unreachable after "
                    f"{max_redials} dials: {e}") from e
            time.sleep(redial_backoff * (2 ** (redials - 1))
                       + jitter.uniform(0, redial_backoff))
            continue
        if pp is None:
            channel = TransportChannel(f"host{hid}", {"guest": ep},
                                       timeout)
            pp = PartyProcess(hid, params, X_host, channel,
                              export_dir=export_dir, state_dir=state_dir,
                              own_process=True)
        else:
            channel.peers["guest"] = ep
        if getattr(params, "pipeline", False):
            # async inbox (DESIGN.md §12): accept the pipelined guest's
            # next-round enc_gh off the wire while this round computes.
            # Restarted per connection — a re-dial leaves the previous
            # broker poisoned on the dead endpoint.
            channel.start_broker("guest")
        channel.control_send(
            "guest", wire.HELLO,
            {"hid": hid, "run_id": run_id, "resume": pp.resume_info()})
        try:
            pp.serve_forever()
            ep.close()
            return
        except TransportError:
            # connection-level failure (drop, mid-frame timeout, corrupt
            # frame): close, back off, re-dial, resume.  Anything else is
            # a real host-side crash and must kill the process — the
            # guest respawns it from durable state.
            ep.close()
            redials += 1
            if redials > max_redials:
                raise
            time.sleep(redial_backoff * (2 ** (redials - 1))
                       + jitter.uniform(0, redial_backoff))


# ---------------------------------------------------------------------------
# guest-side orchestration
# ---------------------------------------------------------------------------

class MultiHostRun:
    """Drive a process-per-party run from the guest side.

    ``transport="socket"`` spawns one OS process per host (multiprocessing
    ``spawn`` — a fresh interpreter, so jax state is never forked) talking
    length-prefixed TCP on localhost.  ``transport="loopback"`` builds the
    host PartyProcess objects in this process on in-memory endpoints with
    the identical framing — same codec, same ledgers, no sockets — which
    is what CI uses where spawning is too slow and what the benchmark
    falls back to in sandboxes.

        run = MultiHostRun(params, [X_host])
        model = run.fit(X_guest, y)         # training over the transport
        run.serve(out_dir)                  # per-party exports, reloaded
        score = run.predict_score(X_eval_guest)
        run.close()
    """

    def __init__(self, params, X_hosts: list, transport: str = "socket",
                 export_dir: str | None = None, timeout: float = 600.0,
                 state_dir: str | None = None, fault_plans: dict | None = None,
                 liveness_interval: float | None = None,
                 liveness_timeout: float = 10.0,
                 serve_timeout: float | None = None):
        if getattr(params, "mesh", None) is not None:
            raise ValueError("multi-host runtime: params.mesh must be None "
                             "(per-process meshes are per-party state)")
        self.params = params
        self.n_hosts = len(X_hosts)
        self.export_dir = export_dir
        self.state_dir = state_dir
        self.fault_plans = fault_plans or {}
        self.transport = transport
        self.timeout = timeout
        self.liveness_interval = liveness_interval
        self.liveness_timeout = liveness_timeout
        self.serve_timeout = serve_timeout
        self.procs: list = []
        self.parties: list = []         # loopback PartyProcess objects
        self._listener = None
        self._port = None
        self.model = None
        self.predictor = None
        self.run_id = f"run-{os.getpid()}-{os.urandom(4).hex()}"
        self.restarts = 0               # host processes respawned
        self.redials = 0                # connections re-accepted (host
                                        # process survived, socket didn't)
        self.wedged_restarts = 0        # supervisor-initiated restarts
        self.slow_hosts: set = set()    # straggling, NOT restarted
        self._degraded: set = set()     # serving: hosts awaiting rejoin
        self._host_resume: dict = {}    # hid -> last hello resume info
        self._host_keys = None          # serve_setup keys (for re-setup)
        self._round_snaps: dict = {}    # round -> guest channel snapshot
        self._mp_ctx = None
        from ..core.binning import BinnedData
        from ..data.pipeline import RowBlocks
        # pre-binned / chunked host sources pass through untouched (§13);
        # note RowBlocks carries a closure, so socket spawn requires raw
        # arrays or a (picklable, device-buffer-free) BinnedData
        self._X_hosts = [X if isinstance(X, (BinnedData, RowBlocks))
                         else np.asarray(X) for X in X_hosts]
        self._supervisor = None
        self._straggler = {}
        self._clock_samples = {}    # hid -> [(t_send, peer_clock, t_recv)]
                                    # in guest perf_counter_ns (trace merge)

        self.channel = TransportChannel("guest", {}, timeout)
        if transport == "socket":
            import multiprocessing as mp
            self._mp_ctx = mp.get_context("spawn")
            self._listener = _socket.socket()
            try:
                self._listener.bind(("127.0.0.1", 0))
                self._listener.listen(self.n_hosts + 2)
                self._port = self._listener.getsockname()[1]
                for hid in range(self.n_hosts):
                    self.procs.append(self._spawn(hid, first=True))
                self._accept_hosts(set(range(self.n_hosts)), timeout)
            except BaseException:
                # __init__ failed: the caller never gets an object to
                # close(), so reap children and sockets here
                for ep in self.channel.peers.values():
                    ep.close()
                for p in self.procs:
                    if p.is_alive():
                        p.terminate()
                self._listener.close()
                raise
        elif transport == "loopback":
            for hid, X in enumerate(self._X_hosts):
                guest_end, host_end = LoopbackEndpoint.pair()
                hch = TransportChannel(f"host{hid}", {"guest": host_end},
                                       timeout)
                pp = PartyProcess(hid, params, X, hch,
                                  export_dir=export_dir,
                                  state_dir=state_dir)
                host_end.on_deliver = pp.pump
                self.channel.peers[f"host{hid}"] = guest_end
                self.parties.append(pp)
        else:
            raise ValueError(f"unknown transport {transport!r}")

    # -- spawn / accept / reacquire -------------------------------------
    def _spawn(self, hid: int, first: bool = False):
        """Start (or restart) host ``hid``.  Fault plans are injected
        only into the FIRST generation: a respawned process runs clean,
        or a deterministic kill-at-(tree, layer) rule would re-fire on
        every replay and the run could never converge."""
        plan = self.fault_plans.get(hid) if first else None
        p = self._mp_ctx.Process(
            target=host_main,
            args=(self._port, hid, self.params, self._X_hosts[hid],
                  self.export_dir, self.state_dir, self.run_id, plan,
                  self.timeout),
            daemon=True)
        p.start()
        return p

    def _accept_hosts(self, want: set, deadline_s: float) -> None:
        """Accept re-/connections until every hid in ``want`` has a live
        endpoint.  Any host may dial in (a re-dialing survivor arrives
        interleaved with the respawn we are waiting for) — each hello is
        routed to its own hid slot and the freshest connection wins."""
        deadline = time.monotonic() + deadline_s
        while want:
            budget = deadline - time.monotonic()
            if budget <= 0:
                dead = [p.pid for p in self.procs if not p.is_alive()]
                raise TransportError(
                    f"host(s) {sorted(want)} never (re)connected within "
                    f"{deadline_s}s (dead processes: {dead or 'none'})")
            self._listener.settimeout(min(budget, 1.0))
            try:
                sock, _ = self._listener.accept()
            except _socket.timeout:
                for hid in sorted(want):    # crashed before connecting?
                    if hid < len(self.procs) \
                            and not self.procs[hid].is_alive():
                        self.procs[hid] = self._spawn(hid)
                        self.restarts += 1
                continue
            ep = SocketEndpoint(sock)
            try:
                frame = ep.recv_bytes(min(max(budget, 1.0), 10.0))
                _, _, _, tag, _, _, hello = decode_frame(frame)
            except TransportError:
                ep.close()
                continue
            if tag != wire.HELLO or hello.get("run_id") != self.run_id:
                ep.close()          # stale dialer from a previous run
                continue
            hid = int(hello["hid"])
            old = self.channel.peers.get(f"host{hid}")
            if old is not None:
                old.close()
            self.channel.peers[f"host{hid}"] = ep
            with self.channel._mirror_lock:
                self.channel.rx_bytes[wire.HELLO] += len(frame) + 4
            self._host_resume[hid] = hello.get("resume") or {}
            want.discard(hid)

    def _reacquire(self, peer: str) -> None:
        """Reconnect hook: a send/recv to ``peer`` failed.  Respawn the
        process if it died, re-accept its dial-in, then raise
        :class:`PeerRestarted` — the peer's in-flight tree state is gone
        (or unsynchronized), so the resilient loop must replay from the
        last boundary rather than retry the failed frame."""
        if self.transport != "socket" or not peer.startswith("host"):
            return
        hid = int(peer[4:])
        respawned = False
        if not self.procs[hid].is_alive():
            self.procs[hid].join(timeout=1)
            self.procs[hid] = self._spawn(hid)
            self.restarts += 1
            respawned = True
        else:
            self.redials += 1
        self._accept_hosts({hid}, self.timeout)
        raise PeerRestarted(
            f"{peer} {'respawned' if respawned else 'reconnected'}: "
            f"replay from the last tree boundary")

    def _recover_and_resync(self) -> None:
        """Bring every peer to a known-clean stream state before a
        replay: respawn/reaccept anything broken, then run the resync
        barrier against every host — stale in-flight replies from the
        aborted attempt are drained unmirrored (the rolled-back snapshot
        already forgot their requests)."""
        t_ns = (time.perf_counter_ns() if self.channel.tracer.enabled
                else 0)
        hook, self.channel.reconnect = self.channel.reconnect, None
        try:
            if self.transport == "socket":
                broken = {hid for hid in range(self.n_hosts)
                          if not self.procs[hid].is_alive()
                          or getattr(self.channel.peers.get(f"host{hid}"),
                                     "dead", False)}
                for hid in sorted(broken):
                    if not self.procs[hid].is_alive():
                        self.procs[hid].join(timeout=1)
                        self.procs[hid] = self._spawn(hid)
                        self.restarts += 1
                if broken:
                    self._accept_hosts(broken, self.timeout)
            for hid in range(self.n_hosts):
                for attempt in (0, 1):
                    try:
                        self.channel.control_send(f"host{hid}", wire.RESYNC,
                                                  {"run": self.run_id})
                        self.channel.drain(f"host{hid}",
                                           until_ctrl=wire.RESYNC_ACK,
                                           timeout=self.timeout)
                        break
                    except TransportError:
                        if attempt or self.transport != "socket":
                            raise
                        # connection died between the hook firing and
                        # now: one more respawn/accept round, then give
                        # up to the outer retry budget
                        if not self.procs[hid].is_alive():
                            self.procs[hid].join(timeout=1)
                            self.procs[hid] = self._spawn(hid)
                            self.restarts += 1
                        self._accept_hosts({hid}, self.timeout)
        finally:
            self.channel.reconnect = hook
            if self.channel.tracer.enabled:
                self.channel.tracer.complete(
                    "resync", t_ns, time.perf_counter_ns() - t_ns,
                    cat="transport", n_hosts=self.n_hosts)

    def _resume_floor(self) -> int | None:
        """Lowest boosting round any reconnected party can resume from,
        in ROUND units (None: nobody reported resume info)."""
        if not self._host_resume or self.model is None:
            return None
        tpr = self.model.trees_per_round
        floors = [int(r.get("n_complete", 0)) // tpr
                  for r in self._host_resume.values() if r is not None]
        return min(floors) if floors else None

    # -- training -------------------------------------------------------
    def fit(self, X_guest, y, *, resilient: bool = False,
            ckpt_dir: str | None = None, save_every: int = 1,
            max_retries: int = 3, retry_backoff: float = 0.05):
        from ..core.boosting import VerticalBoosting
        if resilient and getattr(self.params, "pipeline", False):
            # the resilient loop's replay anchor is the enc_gh boundary
            # of ONE in-flight tree; pipelining keeps a second tree's
            # enc_gh in flight past that boundary, so a rollback could
            # not decide which staged state to discard.  Train pipelined
            # OR resilient, not both.
            raise ValueError("pipeline=True is incompatible with "
                             "resilient=True: the replay boundary admits "
                             "a single in-flight tree")
        # per-fit accounting on BOTH sides of the wire: the model's Stats
        # are fresh, so the channel ledgers and host Stats must be too,
        # or a refit on a long-lived run double-counts
        self.channel.serving_mode = False
        self.channel.reset_accounting()
        for hid in range(self.n_hosts):
            self.channel.control_send(f"host{hid}", wire.RESET_STATS, None)
        model = VerticalBoosting(self.params)
        model.channel = self.channel
        model.remote_hosts = [RemoteHostHandle(self.channel, hid)
                              for hid in range(self.n_hosts)]
        self.model = model
        self.predictor = None           # stale after refit
        if not resilient:
            model.fit(X_guest, y, [])
            return model
        if ckpt_dir is None:
            raise ValueError("resilient fit needs ckpt_dir: the per-round "
                             "score is restored through the checkpoint "
                             "machinery on replay")
        self._fit_resilient(model, X_guest, y, ckpt_dir, save_every,
                            max_retries, retry_backoff)
        return model

    def _fit_resilient(self, model, X_guest, y, ckpt_dir: str,
                       save_every: int, max_retries: int,
                       retry_backoff: float) -> None:
        """The per-tree resume boundary: each boosting round runs inside
        a :class:`~repro.runtime.fault.ResilientLoop` step.  On any
        failure the loop restores the last round boundary — score from
        the checkpoint, trees truncated in memory, ledger/seq state from
        the round snapshot — re-syncs every peer, and replays.  The
        replayed round is bit-identical (GOSS/shuffle streams are keyed
        by absolute tree index; the affine/Paillier pipelines decrypt
        identically) and the converged ledgers match the fault-free
        oracle (duplicates deduped by seq, aborted attempts rolled back)."""
        from ..checkpoint import checkpoint as _ckpt
        from .fault import ResilientLoop
        score0 = model.begin_fit(X_guest, y, [])
        shape, dtype = score0.shape, score0.dtype
        self._round_snaps = {0: self.channel.snapshot()}
        self._host_resume = {}
        self._start_supervisor()
        try:
            self.channel.reconnect = self._reacquire
            self.channel.on_rtt = self._observe_rtt

            def step_fn(score, t):
                self._round_snaps[t] = self.channel.snapshot()
                return model.boost_round(t, score)

            def save_fn(step, score):
                _ckpt.save(ckpt_dir, step, {"score": np.asarray(score)})

            def restore_fn():
                self._recover_and_resync()
                avail = _ckpt.latest_step(ckpt_dir)
                step = avail if avail is not None else 0
                floor = self._resume_floor()
                if floor is not None:
                    step = min(step, floor)
                self._host_resume = {}
                if avail is not None and step > 0:
                    # restore_any, not restore: the jax path would
                    # canonicalize the float64 score to float32 and the
                    # replayed rounds would drift off bit-identity
                    score = np.asarray(
                        _ckpt.restore_any(ckpt_dir, step)["score"])
                    assert score.shape == shape and score.dtype == dtype
                else:
                    step, score = 0, score0.copy()
                model.rollback_to_round(step)
                self.channel.restore(self._round_snaps[step])
                return step, score

            loop = ResilientLoop(step_fn, save_fn, restore_fn,
                                 next_batch=lambda t: t,
                                 save_every=save_every,
                                 max_retries=max_retries,
                                 backoff=retry_backoff)
            self.failures = 0
            _, score = loop.run(score0, 0, self.params.n_trees)
            self.failures = loop.failures
            model.finish_fit(score)
        finally:
            self.channel.reconnect = None
            self.channel.on_rtt = None
            self._stop_supervisor()

    def _observe_rtt(self, src: str, tag: str, seconds: float) -> None:
        """Per-layer round-trip times feed the straggler policy: a SLOW
        host is marked (``slow_hosts``) but never restarted — restarting
        it would lose real progress for no correctness gain.  Only the
        liveness supervisor (no hb_ack at all) restarts a host."""
        if tag != wire.SPLIT_INFOS:
            return
        from .fault import StragglerPolicy
        pol = self._straggler.get(src)
        if pol is None:
            pol = self._straggler[src] = StragglerPolicy()
        if pol.check(seconds):
            self.slow_hosts.add(src)

    # -- liveness supervisor --------------------------------------------
    def _start_supervisor(self) -> None:
        if self.liveness_interval is None or self.transport != "socket":
            return
        self._last_ack = {hid: time.monotonic()
                          for hid in range(self.n_hosts)}
        self.channel.on_ctrl = self._skim_ctrl
        self._sup_stop = threading.Event()
        self._supervisor = threading.Thread(target=self._supervise,
                                            daemon=True)
        self._supervisor.start()

    def _stop_supervisor(self) -> None:
        if self._supervisor is not None:
            self._sup_stop.set()
            self._supervisor.join(timeout=5)
            self._supervisor = None
        self.channel.on_ctrl = None

    def _skim_ctrl(self, src: str, tag: str, payload) -> bool:
        """Recv-loop hook: heartbeat acks arrive interleaved with
        protocol replies (the supervisor pings while the training thread
        owns the socket reads) — record and swallow them."""
        if tag == wire.HB_ACK:
            try:
                hid = int(src[4:])
                self._last_ack[hid] = time.monotonic()
                if isinstance(payload, dict) and "clock" in payload \
                        and "t_ns" in payload:
                    # one NTP-style offset sample per ack (min-RTT sample
                    # wins at merge time); bounded — samples only improve
                    # while RTT keeps making new minimums anyway
                    samples = self._clock_samples.setdefault(hid, [])
                    if len(samples) < 256:
                        samples.append((int(payload["t_ns"]),
                                        int(payload["clock"]),
                                        time.perf_counter_ns()))
            except (ValueError, AttributeError):
                pass
            return True
        return False

    def _supervise(self) -> None:
        """Wedged-vs-slow triage.  A SLOW host still answers heartbeats
        (and shows up in ``slow_hosts`` via the straggler policy on
        per-layer RTTs): left alone.  A WEDGED host answers nothing for
        ``liveness_timeout``: kill it — the training thread's blocked
        recv fails over the closed socket, the reconnect hook respawns
        from durable state, and the resilient loop replays the tree."""
        while not self._sup_stop.wait(self.liveness_interval):
            now = time.monotonic()
            for hid in range(self.n_hosts):
                try:
                    self.channel.control_send(
                        f"host{hid}", wire.HB,
                        {"t": now, "t_ns": time.perf_counter_ns()})
                except Exception:                        # noqa: BLE001
                    continue        # training thread handles reconnects
                if now - self._last_ack[hid] > self.liveness_timeout:
                    p = self.procs[hid]
                    if p.is_alive():
                        p.kill()
                    ep = self.channel.peers.get(f"host{hid}")
                    if ep is not None:
                        ep.close()
                    self.wedged_restarts += 1
                    self._last_ack[hid] = now   # one kill per silence

    # -- serving --------------------------------------------------------
    def serve(self, out_dir: str | None = None):
        """Export per-party halves (guest here, each host in its own
        process), reload them, and wire a predictor over the transport.
        Returns the :class:`FederatedPredictor`."""
        from ..serving.engine import FederatedPredictor
        from ..serving.export import export_guest, load_guest
        from ..serving.packed import pack_guest
        if self.model is None:
            raise RuntimeError("serve() needs a fitted model: call fit()")
        out_dir = out_dir or self.export_dir
        guest_half, host_keys = pack_guest(self.model)
        self._host_keys = host_keys
        self._serve_out_dir = out_dir
        if out_dir:
            gdir = export_guest(guest_half,
                                os.path.join(out_dir, "guest"))
            guest_half = load_guest(gdir)   # serve from the reloaded half
        for hid in range(self.n_hosts):
            self._serve_setup_host(hid)
        remote = []
        for hid in range(self.n_hosts):
            ack = self.channel.control_recv(f"host{hid}", wire.SERVE_READY)
            remote.append(RemoteServingHost(self.channel, hid,
                                            int(ack["k"]),
                                            self.serve_timeout))
        self.predictor = FederatedPredictor(
            guest_half, remote, channel=self.channel,
            stats=self.model.stats)
        # from here on a transport failure on a host is a per-batch,
        # typed PartyUnavailable — never a hang, never partial bits
        self.channel.serving_mode = True
        return self.predictor

    def _serve_setup_host(self, hid: int) -> None:
        self.channel.control_send(
            f"host{hid}", wire.SERVE_SETUP,
            {"keys": [list(k) for k in self._host_keys[hid]],
             "export_dir": self._serve_out_dir})

    def _heal_serving(self) -> None:
        """Rejoin degraded hosts before the next batch: accept the
        re-dial (respawning first if the process died), replay the
        serving setup, and clear the mark.  If a host is still down the
        typed error surfaces again — per batch, never a hang."""
        from ..core.party import PartyUnavailable
        for hid in sorted(self._degraded):
            peer = f"host{hid}"
            try:
                if not self.procs[hid].is_alive():
                    self.procs[hid].join(timeout=1)
                    self.procs[hid] = self._spawn(hid)
                    self.restarts += 1
                self._accept_hosts({hid}, self.timeout)
                self._align_seqs(hid)
                self._serve_setup_host(hid)
                ack = self.channel.control_recv(peer, wire.SERVE_READY)
            except PartyUnavailable:
                raise
            except (TransportError, OSError) as e:
                raise PartyUnavailable(peer, f"rejoin failed: {e}") from e
            if int(ack["k"]) != self.predictor.hosts[hid].k:
                raise PartyUnavailable(
                    peer, f"rejoined with {int(ack['k'])} serving nodes, "
                          f"expected {self.predictor.hosts[hid].k}")
            self._degraded.discard(hid)

    def _align_seqs(self, hid: int) -> None:
        """Converge per-tag seq counters with a rejoined party.  Its
        stream state restarts from the persisted floor (which has no
        serving tags at all), while the guest's counters are wherever
        the dead generation left them — without alignment the fresh
        host's first ``predict_bits`` (seq 1) looks like a replayed
        duplicate and is silently discarded, wedging the batch."""
        peer = f"host{hid}"
        resume = self._host_resume.get(hid) or {}
        for key in [k for k in self.channel.send_seq if k[0] == peer]:
            del self.channel.send_seq[key]
        for key in [k for k in self.channel.last_seen if k[0] == peer]:
            del self.channel.last_seen[key]
        # our next send must be numbered one past what the host has seen
        for st, v in (resume.get("last_seen") or {}).items():
            src, tag = st.split("|", 1)
            if src == self.channel.party:
                self.channel.send_seq[(peer, tag)] = int(v)
        # and its next send will be numbered one past what it has sent
        for dt, v in (resume.get("send_seq") or {}).items():
            dst, tag = dt.split("|", 1)
            if dst == self.channel.party:
                self.channel.last_seen[(peer, tag)] = int(v)

    def stage_host_data(self, X_hosts: list) -> None:
        """Ship each host its OWN feature rows for the upcoming batch —
        the out-of-band data arrival every party sees in a real
        deployment (the serving protocol itself still moves only
        instance ids and bit blocks)."""
        for hid, X in enumerate(X_hosts):
            self.channel.control_send(f"host{hid}", wire.SERVE_DATA,
                                      {"X": np.asarray(X)})

    def predict_score(self, X_guest, X_hosts: list | None = None, *,
                      staged: bool = False) -> np.ndarray:
        """Serve one batch.  Pass ``X_hosts`` to stage each host's rows
        for THIS batch, or ``staged=True`` to assert the hosts already
        hold the right rows (initially their training matrices).  With
        neither, raise: a guest batch silently scored against stale host
        rows mixes features from different instances with no error."""
        from ..core.party import PartyUnavailable
        if self.predictor is None:
            self.serve()
        if self._degraded:
            self._heal_serving()        # raises PartyUnavailable if a
                                        # marked host has not rejoined
        try:
            if X_hosts is not None:
                self.stage_host_data(X_hosts)
            elif not staged:
                raise ValueError(
                    "host rows for this batch are not staged: pass X_hosts "
                    "(ships each host its rows) or staged=True (the hosts' "
                    "currently staged matrices ARE this batch's rows)")
            return self.predictor.predict_score(X_guest,
                                                [None] * self.n_hosts)
        except PartyUnavailable as e:
            # this batch is lost (typed, whole-batch — the engine already
            # consumed every healthy host's reply, so the streams stay
            # clean); the NEXT batch triggers the rejoin path above
            self._degraded.add(int(e.party[4:]))
            raise

    # -- diagnostics ----------------------------------------------------
    def host_stats(self) -> list:
        """Each host's Stats/ledger/socket counters (control round-trip)."""
        out = []
        for hid in range(self.n_hosts):
            self.channel.control_send(f"host{hid}", wire.GET_STATS, None)
            out.append(self.channel.control_recv(f"host{hid}", wire.STATS))
        return out

    def merged_stats(self) -> Stats:
        """Guest stats + every host's counters folded in: comparable to
        the single shared Stats of an in-process run."""
        merged = Stats()
        merged.merge_counts(self.model.stats.as_dict())
        for hs in self.host_stats():
            merged.merge_counts(hs["stats"])
        return merged

    def party_status(self, hid: int = 0) -> dict:
        """Live introspection of one host party over the control plane:
        Stats, training + transport metric snapshots, ledger, trace
        occupancy, protocol position (``PartyProcess.status``)."""
        self.channel.control_send(f"host{hid}", wire.STATUS, None)
        return self.channel.control_recv(f"host{hid}", wire.STATUS_REPLY)

    def collect_traces(self, clear: bool = False) -> list:
        """One ``trace_sync`` round-trip per host.  Returns
        ``[{hid, events, dropped, samples}]`` where ``samples`` are
        ``(t_send, peer_clock, t_recv)`` clock-offset observations on
        the guest clock — the sync round-trip itself always contributes
        one; supervisor heartbeat acks (when liveness is on) add more."""
        out = []
        for hid in range(self.n_hosts):
            t0 = time.perf_counter_ns()
            self.channel.control_send(f"host{hid}", wire.TRACE_SYNC,
                                      {"clear": bool(clear)})
            dump = self.channel.control_recv(f"host{hid}", wire.TRACE_DUMP)
            t1 = time.perf_counter_ns()
            samples = list(self._clock_samples.get(hid, ()))
            samples.append((t0, int(dump["clock"]), t1))
            out.append({"hid": hid, "events": dump["events"],
                        "dropped": int(dump["dropped"]),
                        "samples": samples})
        return out

    def trace(self, path: str | None = None) -> list:
        """Merge the guest's trace with every host's (clock-aligned onto
        the guest timeline) and optionally write Perfetto ``trace.json``
        at ``path``.  Returns the merged, time-sorted event list."""
        from ..obs.export import (estimate_offset, merge_traces,
                                  write_perfetto)
        parties = []
        gt = getattr(self.model, "tracer", None) if self.model else None
        if gt is not None and gt.enabled:
            parties.append({"party": "guest", "pid": 0,
                            "events": gt.export_events(), "offset_ns": 0})
        for dump in self.collect_traces():
            off, _ = estimate_offset(dump["samples"])
            parties.append({"party": f"host{dump['hid']}",
                            "pid": dump["hid"] + 1,
                            "events": dump["events"],
                            "offset_ns": off})
        merged = merge_traces(parties)
        if path:
            write_perfetto(path, merged, parties)
        return merged

    def ping(self, hid: int = 0) -> float:
        """One control round-trip, seconds."""
        t0 = time.perf_counter()
        self.channel.control_send(f"host{hid}", wire.PING, {"t": t0})
        self.channel.control_recv(f"host{hid}", wire.PONG)
        return time.perf_counter() - t0

    def close(self, join_timeout: float = 30.0) -> None:
        self._stop_supervisor()
        self.channel.serving_mode = False   # byes must not come back as
                                            # typed PartyUnavailable
        for hid in range(self.n_hosts):
            try:
                self.channel.control_send(f"host{hid}", wire.BYE, None)
            except (TransportError, OSError):
                pass        # peer already dead (crashed host, reset pipe)
        # join -> terminate -> join -> kill: a host wedged in a blocking
        # recv (or one that traps SIGTERM) must not outlive the run —
        # SIGKILL is the floor of the escalation, and the final join
        # reaps the zombie so the process table stays clean
        for p in self.procs:
            p.join(timeout=join_timeout)
        for p in self.procs:
            if p.is_alive():
                p.terminate()
        for p in self.procs:
            if p.is_alive():
                p.join(timeout=5)
                if p.is_alive():
                    p.kill()
                    p.join(timeout=5)
        self.channel.close()
        if self._listener is not None:
            self._listener.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
