"""Multi-host party runtime: a real transport behind the Channel contract
(DESIGN.md §10).

The whole protocol — training (per-layer ``assign_sync`` -> ``split_infos``
-> batched decrypt, §8) and serving (one ``predict_bits`` round-trip per
host per batch, §9) — already flows through tagged, serializable messages
(``core/tree.py``, ``serving/engine.py``).  This module gives those
messages a wire:

* a **payload codec**: numpy/limb tensors, python-int object arrays
  (Paillier ciphertexts), ints/floats/strs/bytes and nested
  tuples/lists/dicts <-> length-prefixed binary.  No pickle anywhere on
  the wire.
* **framed endpoints**: a length-prefixed TCP socket transport and an
  in-memory loopback with the identical framing (the loopback pumps the
  peer inline — single-threaded, deterministic, still exercising the full
  encode/decode path).
* :class:`TransportChannel` — a :class:`~repro.core.party.Channel` whose
  ``send`` *ships* outgoing frames and whose ``recv`` records incoming
  ones, so each party's ledger converges to the same per-tag byte totals
  as the in-process shared ledger (the oracle).  Actual framed socket
  bytes are tallied separately (``tx_bytes``/``rx_bytes``) so the
  analytic wire model (paper eqs 10/16) can be compared against what the
  socket really moved.
* :class:`PartyProcess` — hosts ONE party per OS process for both
  training (drives the party's :class:`~repro.core.tree.HostRuntime`) and
  serving (a :class:`~repro.serving.engine.PartyBits` evaluator built
  from the host's own reloaded export half).
* :class:`MultiHostRun` — guest-side orchestration: spawn host processes,
  train over the sockets, export per-party halves, serve from the
  reloaded halves.

A forced-2-process run is bit-identical to the in-process ``Channel`` run
with identical per-tag ledgers and round-trip counts (asserted in
``tests/test_transport.py``).
"""

from __future__ import annotations

import os
import socket as _socket
import struct
import time
from collections import Counter, deque

import numpy as np

from ..core.party import Channel, Stats

KIND_PROTO = 0          # protocol message: enters the wire-byte ledger
KIND_CTRL = 1           # runtime control (hello/serve_setup/stats/bye):
                        # real socket traffic, never ledger bytes

_U32 = struct.Struct("!I")
_I64 = struct.Struct("!q")
_F64 = struct.Struct("!d")


class TransportError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# payload codec (no pickle on the wire)
# ---------------------------------------------------------------------------

def _enc_str(out: bytearray, s: str) -> None:
    b = s.encode("utf-8")
    out += _U32.pack(len(b))
    out += b


def _enc_bigint(out: bytearray, x: int) -> None:
    sign = 1 if x < 0 else 0
    raw = abs(x).to_bytes((abs(x).bit_length() + 7) // 8 or 1, "big")
    out += bytes([sign])
    out += _U32.pack(len(raw))
    out += raw


def _encode(obj, out: bytearray) -> None:
    if obj is None:
        out += b"N"
    elif isinstance(obj, (bool, np.bool_)):
        out += (b"T" if obj else b"F")
    elif isinstance(obj, (int, np.integer)):
        x = int(obj)
        if -(2 ** 63) <= x < 2 ** 63:
            out += b"i"
            out += _I64.pack(x)
        else:
            out += b"I"
            _enc_bigint(out, x)
    elif isinstance(obj, (float, np.floating)):
        out += b"f"
        out += _F64.pack(float(obj))
    elif isinstance(obj, str):
        out += b"s"
        _enc_str(out, obj)
    elif isinstance(obj, (bytes, bytearray)):
        out += b"b"
        out += _U32.pack(len(obj))
        out += bytes(obj)
    elif isinstance(obj, tuple):
        out += b"u"
        out += _U32.pack(len(obj))
        for it in obj:
            _encode(it, out)
    elif isinstance(obj, list):
        out += b"l"
        out += _U32.pack(len(obj))
        for it in obj:
            _encode(it, out)
    elif isinstance(obj, dict):
        out += b"d"
        out += _U32.pack(len(obj))
        for k, v in obj.items():
            _encode(k, out)
            _encode(v, out)
    else:
        if not isinstance(obj, np.ndarray) and hasattr(obj, "__array__"):
            obj = np.asarray(obj)       # jax arrays land here (sync point)
        if not isinstance(obj, np.ndarray):
            raise TransportError(f"unserializable payload type "
                                 f"{type(obj).__name__}")
        if obj.dtype == object:
            # Paillier ciphertexts / decrypted ints: python bigints
            out += b"O"
            out += bytes([obj.ndim])
            for d in obj.shape:
                out += _I64.pack(d)
            for x in obj.reshape(-1).tolist():
                if not isinstance(x, int):
                    raise TransportError(
                        f"object arrays may only carry python ints, got "
                        f"{type(x).__name__}")
                _enc_bigint(out, x)
        else:
            out += b"a"
            _enc_str(out, str(obj.dtype))
            out += bytes([obj.ndim])
            for d in obj.shape:
                out += _I64.pack(d)
            out += np.ascontiguousarray(obj).tobytes()


def encode_payload(obj) -> bytes:
    out = bytearray()
    _encode(obj, out)
    return bytes(out)


class _Reader:
    def __init__(self, buf: bytes):
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        b = self.buf[self.pos: self.pos + n]
        if len(b) != n:
            raise TransportError("truncated payload")
        self.pos += n
        return b

    def u32(self) -> int:
        return _U32.unpack(self.take(4))[0]

    def i64(self) -> int:
        return _I64.unpack(self.take(8))[0]

    def string(self) -> str:
        return self.take(self.u32()).decode("utf-8")

    def bigint(self) -> int:
        sign = self.take(1)[0]
        raw = self.take(self.u32())
        x = int.from_bytes(raw, "big")
        return -x if sign else x


def _decode(r: _Reader):
    t = r.take(1)
    if t == b"N":
        return None
    if t == b"T":
        return True
    if t == b"F":
        return False
    if t == b"i":
        return r.i64()
    if t == b"I":
        return r.bigint()
    if t == b"f":
        return _F64.unpack(r.take(8))[0]
    if t == b"s":
        return r.string()
    if t == b"b":
        return r.take(r.u32())
    if t == b"u":
        return tuple(_decode(r) for _ in range(r.u32()))
    if t == b"l":
        return [_decode(r) for _ in range(r.u32())]
    if t == b"d":
        return {_decode(r): _decode(r) for _ in range(r.u32())}
    if t == b"a":
        dtype = np.dtype(r.string())
        shape = tuple(r.i64() for _ in range(r.take(1)[0]))
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        arr = np.frombuffer(r.take(n * dtype.itemsize), dtype=dtype)
        return arr.reshape(shape).copy()
    if t == b"O":
        shape = tuple(r.i64() for _ in range(r.take(1)[0]))
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        arr = np.empty(n, dtype=object)
        for i in range(n):
            arr[i] = r.bigint()
        return arr.reshape(shape)
    raise TransportError(f"bad payload type byte {t!r}")


def decode_payload(buf: bytes):
    r = _Reader(buf)
    obj = _decode(r)
    if r.pos != len(buf):
        raise TransportError("trailing bytes in payload")
    return obj


# ---------------------------------------------------------------------------
# framing + endpoints
# ---------------------------------------------------------------------------

def encode_frame(kind: int, src: str, dst: str, tag: str, nbytes: int,
                 payload, payload_bytes: bytes | None = None) -> bytes:
    out = bytearray([kind])
    _enc_str(out, src)
    _enc_str(out, dst)
    _enc_str(out, tag)
    out += _I64.pack(int(nbytes))
    out += (payload_bytes if payload_bytes is not None
            else encode_payload(payload))
    return bytes(out)


def decode_frame(buf: bytes) -> tuple:
    r = _Reader(buf)
    kind = r.take(1)[0]
    src, dst, tag = r.string(), r.string(), r.string()
    nbytes = r.i64()
    payload = decode_payload(buf[r.pos:])
    return kind, src, dst, tag, nbytes, payload


class SocketEndpoint:
    """Length-prefixed frames over one TCP connection (TCP_NODELAY: the
    protocol is strict request/reply, Nagle only adds latency)."""

    def __init__(self, sock: _socket.socket):
        sock.setsockopt(_socket.IPPROTO_TCP, _socket.TCP_NODELAY, 1)
        self.sock = sock

    def send_bytes(self, frame: bytes) -> None:
        self.sock.sendall(_U32.pack(len(frame)) + frame)

    def _read_exact(self, n: int) -> bytes:
        buf = bytearray(n)
        view = memoryview(buf)
        got = 0
        while got < n:
            r = self.sock.recv_into(view[got:], n - got)
            if r == 0:
                raise TransportError("peer closed the connection")
            got += r
        return bytes(buf)

    def recv_bytes(self, timeout: float | None = None) -> bytes:
        self.sock.settimeout(timeout)
        try:
            n = _U32.unpack(self._read_exact(4))[0]
            return self._read_exact(n)
        except _socket.timeout as e:
            raise TransportError(f"recv timed out after {timeout}s") from e

    def poll(self) -> bool:
        import select
        return bool(select.select([self.sock], [], [], 0)[0])

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


class LoopbackEndpoint:
    """In-memory endpoint with the same framed interface.  ``on_deliver``
    (when set on the *receiving* end) is invoked after each delivery —
    the inline pump that lets a PartyProcess handle frames synchronously
    inside the sender's call, single-threaded and deterministic."""

    def __init__(self):
        self.inbox: deque = deque()
        self.peer: "LoopbackEndpoint | None" = None
        self.on_deliver = None
        self.closed = False

    @classmethod
    def pair(cls) -> tuple:
        a, b = cls(), cls()
        a.peer, b.peer = b, a
        return a, b

    def send_bytes(self, frame: bytes) -> None:
        if self.peer is None or self.peer.closed:
            raise TransportError("loopback peer closed")
        self.peer.inbox.append(frame)
        if self.peer.on_deliver is not None:
            self.peer.on_deliver()

    def recv_bytes(self, timeout: float | None = None) -> bytes:
        if not self.inbox:
            raise TransportError("loopback recv on empty inbox (protocol "
                                 "desync: no pending frame)")
        return self.inbox.popleft()

    def poll(self) -> bool:
        return bool(self.inbox)

    def close(self) -> None:
        self.closed = True


# ---------------------------------------------------------------------------
# the channel over a transport
# ---------------------------------------------------------------------------

class TransportChannel(Channel):
    """The Channel contract over real endpoints.

    ``send`` keeps the exact in-process accounting (same tags, same
    analytic nbytes) and additionally ships the frame when ``dst`` is a
    remote peer; ``recv`` decodes one incoming frame and records it in
    the ledger, so a 2-party conversation yields the same per-tag ledger
    on each side as the single in-process ledger does.  Framed bytes that
    actually crossed the transport are counted per tag in
    ``tx_bytes``/``rx_bytes`` (control frames included): the gap between
    those and the ledger is the protocol-vs-socket overhead the
    transport benchmark reports.
    """

    def __init__(self, party: str, peers: dict, timeout: float = 600.0):
        super().__init__()
        self.party = party
        self.peers = peers
        self.timeout = timeout
        self.tx_bytes = Counter()       # tag -> framed bytes shipped
        self.rx_bytes = Counter()       # tag -> framed bytes received
        self._enc_memo = (object(), b"")    # one-slot broadcast memo
                                            # (sentinel: matches nothing)

    # -- outgoing -------------------------------------------------------
    def send(self, src: str, dst: str, tag: str, payload, nbytes: int):
        super().send(src, dst, tag, payload, nbytes)
        if dst != self.party:
            self._ship(KIND_PROTO, src, dst, tag, nbytes, payload)
        return payload

    def control_send(self, dst: str, tag: str, payload) -> None:
        self._ship(KIND_CTRL, self.party, dst, tag, 0, payload)

    def _ship(self, kind, src, dst, tag, nbytes, payload) -> None:
        ep = self.peers.get(dst)
        if ep is None:
            raise TransportError(f"{self.party}: no endpoint for {dst!r}")
        # broadcast memo: the guest sends the SAME payload object to every
        # host back to back (enc_gh ciphertext batch, layer plans) — encode
        # it once, not once per destination (the enc_gh encode includes a
        # jax device sync)
        memo_obj, payload_bytes = self._enc_memo
        if payload is not memo_obj:
            payload_bytes = encode_payload(payload)
            self._enc_memo = (payload, payload_bytes)
        frame = encode_frame(kind, src, dst, tag, nbytes, None,
                             payload_bytes=payload_bytes)
        self.tx_bytes[tag] += len(frame) + 4        # + length prefix
        ep.send_bytes(frame)

    # -- incoming -------------------------------------------------------
    def _read(self, src: str, timeout: float | None = None):
        ep = self.peers.get(src)
        if ep is None:
            raise TransportError(f"{self.party}: no endpoint for {src!r}")
        frame = ep.recv_bytes(self.timeout if timeout is None else timeout)
        kind, fsrc, fdst, tag, nbytes, payload = decode_frame(frame)
        self.rx_bytes[tag] += len(frame) + 4
        if kind == KIND_CTRL and tag == "error":
            # a peer's dying words: surface its actual failure instead of
            # a tag mismatch now / 'peer closed' later
            raise TransportError(f"peer {fsrc} failed: {payload}")
        if kind == KIND_PROTO:
            # mirror the sender's ledger entry (analytic nbytes travels in
            # the frame header) so each side's per-tag totals converge to
            # the in-process shared ledger
            Channel.send(self, fsrc, fdst, tag, payload, nbytes)
        return kind, fsrc, fdst, tag, payload

    def recv(self, src: str, tag: str):
        """Blocking receive of one PROTOCOL frame from ``src``; the tag
        must match (the protocol is strict request/reply — anything else
        is a desync worth crashing on)."""
        kind, _, _, ftag, payload = self._read(src)
        if kind != KIND_PROTO or ftag != tag:
            raise TransportError(f"{self.party}: expected protocol frame "
                                 f"{tag!r} from {src}, got "
                                 f"{'ctrl' if kind else 'proto'}:{ftag!r}")
        return payload

    def control_recv(self, src: str, tag: str):
        kind, _, _, ftag, payload = self._read(src)
        if kind != KIND_CTRL or ftag != tag:
            raise TransportError(f"{self.party}: expected control frame "
                                 f"{tag!r} from {src}, got "
                                 f"{'ctrl' if kind else 'proto'}:{ftag!r}")
        return payload

    def recv_any(self, src: str) -> tuple:
        """(kind, tag, payload) of the next frame from ``src`` — the
        PartyProcess serve loop."""
        kind, _, _, tag, payload = self._read(src)
        return kind, tag, payload

    def try_recv_any(self, src: str):
        ep = self.peers.get(src)
        if ep is None or not ep.poll():
            return None
        return self.recv_any(src)

    # -- socket accounting ---------------------------------------------
    def reset_accounting(self) -> None:
        super().reset_accounting()
        self.tx_bytes.clear()
        self.rx_bytes.clear()

    @property
    def total_tx_bytes(self) -> int:
        return sum(self.tx_bytes.values())

    @property
    def total_rx_bytes(self) -> int:
        return sum(self.rx_bytes.values())

    def socket_summary(self) -> dict:
        tags = sorted(set(self.tx_bytes) | set(self.rx_bytes))
        return {t: {"tx": self.tx_bytes[t], "rx": self.rx_bytes[t]}
                for t in tags}

    def close(self) -> None:
        for ep in self.peers.values():
            ep.close()


# ---------------------------------------------------------------------------
# guest-side handles
# ---------------------------------------------------------------------------

class RemoteHostHandle:
    """What the grower sees for a host living in another process: the
    guest's ``channel.send`` already shipped every guest->host message, so
    ``deliver`` is a no-op and ``collect`` blocks on the reply frame.
    Mirror of the in-process ``HostRuntime`` handle surface."""

    def __init__(self, channel: TransportChannel, hid: int):
        self.channel = channel
        self.hid = hid

    @property
    def table(self) -> dict:
        return {}           # host-private; never enters the guest process

    def bind(self, params, cipher, channel, stats) -> None:
        pass

    def deliver(self, tag: str, payload) -> None:
        pass

    def collect(self, tag: str):
        return self.channel.recv(f"host{self.hid}", tag)


class RemoteServingHost:
    """Serving-side handle: the host's PartyProcess computes its packed
    decision bits and answers the guest's ``predict_req``."""

    def __init__(self, channel: TransportChannel, hid: int, k: int):
        self.channel = channel
        self.hid = hid
        self.k = int(k)

    def predict_bits(self):
        return self.channel.recv(f"host{self.hid}", "predict_bits")


# ---------------------------------------------------------------------------
# the party process (host side)
# ---------------------------------------------------------------------------

def _strip_private_key(cipher):
    """Reduce a cipher object to what a passive host may hold.

    The repro's cipher classes bundle keygen and BOTH key halves for the
    in-process simulation (key distribution here is a simulation
    shortcut: the host derives the shared parameters from the run config
    instead of a key-exchange handshake).  A host party only ever needs
    the public/evaluation surface — modulus, Barrett context, lazy
    reduce/sub, compress shifts — so the private material is deleted the
    moment the object exists: any host-side code path that reached for
    decrypt (or the affine scheme's symmetric encrypt) dies with an
    AttributeError instead of silently voiding the privacy boundary.
    ``plain`` is the keyless debugging cipher; nothing to strip.
    """
    for attr in ("T_dec", "T_enc", "a_inv_int", "a_int", "_lam", "_mu"):
        if hasattr(cipher, attr):
            delattr(cipher, attr)
    return cipher


class PartyProcess:
    """One host party, driven entirely by decoded frames.

    Training frames (``enc_gh`` / ``assign_sync`` / ``chosen_sid``) run the
    same :class:`~repro.core.tree.HostRuntime` handlers the in-process
    simulation runs — replies leave through this party's
    :class:`TransportChannel`.  Serving is set up by a ``serve_setup``
    control frame: the host builds its :class:`HostHalf` from its private
    per-tree tables + the guest-published bit-column key order, exports it
    to ``export_dir``, RELOADS it, and answers ``predict_req`` from the
    reloaded half (the per-party export is the process boundary).
    """

    def __init__(self, hid: int, params, X_host, channel: TransportChannel,
                 export_dir: str | None = None):
        from ..core.binning import bin_features
        self.hid = hid
        self.params = params
        self.channel = channel
        self.export_dir = export_dir
        self.stats = Stats()
        self.data = bin_features(np.asarray(X_host), params.n_bins,
                                 sparse=params.sparse,
                                 use_pallas=params.use_pallas)
        self.X_serve = np.asarray(X_host)
        self.cipher = None
        self.hr = None              # current tree's HostRuntime
        self.tables: dict = {}      # tree_idx -> {nid: (fid, bid)}
        self.server = None          # PartyBits after serve_setup
        self._serve_k = 0

    # -- frame dispatch -------------------------------------------------
    def serve_forever(self) -> None:
        while True:
            kind, tag, payload = self.channel.recv_any("guest")
            try:
                cont = self.handle(kind, tag, payload)
            except Exception as e:             # noqa: BLE001
                # ship the real failure to the guest before dying: the
                # alternative is an opaque 'peer closed the connection'
                # on the guest's next recv
                try:
                    self.channel.control_send(
                        "guest", "error",
                        f"host{self.hid} {type(e).__name__}: {e}")
                except Exception:              # noqa: BLE001
                    pass
                raise
            if not cont:
                return

    def pump(self) -> None:
        """Drain pending frames (loopback inline mode)."""
        while True:
            got = self.channel.try_recv_any("guest")
            if got is None:
                return
            self.handle(*got)

    def handle(self, kind: int, tag: str, payload) -> bool:
        if kind == KIND_CTRL:
            return self._control(tag, payload)
        if tag == "enc_gh":
            self._begin_tree(payload)
        elif tag in ("assign_sync", "chosen_sid"):
            self.hr.deliver(tag, payload)
            self.hr._outbox.clear()     # replies already shipped
        elif tag == "predict_req":
            self._predict(payload)
        else:
            raise TransportError(f"host{self.hid}: unknown protocol tag "
                                 f"{tag!r}")
        return True

    # -- training -------------------------------------------------------
    def _begin_tree(self, payload) -> None:
        from ..core.histogram import CipherHistogram
        from ..core.tree import HostRuntime
        if self.cipher is None:
            from ..core.boosting import cipher_kwargs
            from ..core.he import get_cipher
            self.cipher = _strip_private_key(
                get_cipher(self.params.cipher,
                           **cipher_kwargs(self.params)))
        engine = CipherHistogram(self.cipher, self.params.n_bins,
                                 sparse=self.params.sparse,
                                 use_pallas=self.params.use_pallas,
                                 stats=self.stats)
        self.hr = HostRuntime(hid=self.hid, data=self.data, engine=engine)
        self.hr.bind(self.params, self.cipher, self.channel, self.stats)
        self.hr.deliver("enc_gh", payload)
        self.tables[int(payload["tree"])] = self.hr.table

    # -- serving --------------------------------------------------------
    def _serve_setup(self, payload) -> None:
        from ..kernels.common import default_interpret
        from ..serving.engine import PartyBits
        from ..serving.export import export_host, load_host
        from ..serving.packed import host_half_from_keys
        keys = [(int(ti), int(nid)) for ti, nid in payload["keys"]]
        half = host_half_from_keys(self.hid, keys, self.tables,
                                   self.data.thresholds, self.params.n_bins)
        # the guest names the export root in the setup frame so one
        # serve() call produces ONE coherent per-party tree; the
        # constructor's export_dir is only the fallback
        export_dir = payload.get("export_dir", self.export_dir)
        if export_dir:
            out = export_host(half, os.path.join(export_dir,
                                                 f"host{self.hid}"))
            half = load_host(out)   # serve from the RELOADED export
        use_pallas = self.params.use_pallas and not default_interpret()
        self._serve_k = half.table.k
        self.server = (PartyBits(half.table, half.thresholds, half.n_bins,
                                 use_pallas)
                       if half.table.k else None)
        self.channel.control_send("guest", "serve_ready",
                                  {"k": self._serve_k})

    def _predict(self, req) -> None:
        ids = np.asarray(req["ids"])
        n = len(ids)
        n_pad = int(req["n_pad"])
        if n and int(ids.max()) >= len(self.X_serve):
            raise TransportError(
                f"host{self.hid}: predict_req references row "
                f"{int(ids.max())} but only {len(self.X_serve)} rows are "
                f"staged — ship this batch's host rows first "
                f"(MultiHostRun.stage_host_data / the serve_data frame)")
        pb = self.server.packed_from_X(self.X_serve[ids], n_pad)
        # round-trips are counted ONCE, at the guest's collect site (the
        # same place the in-process engine counts them) — not here, or
        # merged_stats would double-count every batch
        self.channel.send(f"host{self.hid}", "guest", "predict_bits", pb,
                          self._serve_k * ((n + 7) // 8))

    # -- control --------------------------------------------------------
    def _control(self, tag: str, payload) -> bool:
        if tag == "serve_setup":
            self._serve_setup(payload)
        elif tag == "serve_data":
            # out-of-band data staging: in a real deployment each party
            # pulls the batch's rows from its OWN source; the control
            # plane simulates that arrival.  predict_req still carries
            # only instance ids.
            self.X_serve = np.asarray(payload["X"])
        elif tag == "reset_stats":
            # a refit starts: fresh Stats (the next enc_gh's engine binds
            # to it) and fresh per-fit wire accounting, mirroring the
            # fresh model the guest constructs
            self.stats = Stats()
            self.channel.reset_accounting()
        elif tag == "get_stats":
            self.channel.control_send(
                "guest", "stats",
                {"stats": self.stats.as_dict(),
                 "ledger": self.channel.summary(),
                 "socket": self.channel.socket_summary()})
        elif tag == "ping":
            self.channel.control_send("guest", "pong", payload)
        elif tag == "bye":
            return False
        else:
            raise TransportError(f"host{self.hid}: unknown control tag "
                                 f"{tag!r}")
        return True


def host_main(port: int, hid: int, params, X_host,
              export_dir: str | None = None) -> None:
    """Entry point of a spawned host process: connect to the guest's
    listener, introduce ourselves, serve frames until ``bye``."""
    sock = _socket.create_connection(("127.0.0.1", port))
    ep = SocketEndpoint(sock)
    channel = TransportChannel(f"host{hid}", {"guest": ep})
    channel.control_send("guest", "hello", {"hid": hid})
    try:
        PartyProcess(hid, params, X_host, channel,
                     export_dir=export_dir).serve_forever()
    finally:
        ep.close()


# ---------------------------------------------------------------------------
# guest-side orchestration
# ---------------------------------------------------------------------------

class MultiHostRun:
    """Drive a process-per-party run from the guest side.

    ``transport="socket"`` spawns one OS process per host (multiprocessing
    ``spawn`` — a fresh interpreter, so jax state is never forked) talking
    length-prefixed TCP on localhost.  ``transport="loopback"`` builds the
    host PartyProcess objects in this process on in-memory endpoints with
    the identical framing — same codec, same ledgers, no sockets — which
    is what CI uses where spawning is too slow and what the benchmark
    falls back to in sandboxes.

        run = MultiHostRun(params, [X_host])
        model = run.fit(X_guest, y)         # training over the transport
        run.serve(out_dir)                  # per-party exports, reloaded
        score = run.predict_score(X_eval_guest)
        run.close()
    """

    def __init__(self, params, X_hosts: list, transport: str = "socket",
                 export_dir: str | None = None, timeout: float = 600.0):
        if getattr(params, "mesh", None) is not None:
            raise ValueError("multi-host runtime: params.mesh must be None "
                             "(per-process meshes are per-party state)")
        self.params = params
        self.n_hosts = len(X_hosts)
        self.export_dir = export_dir
        self.transport = transport
        self.procs: list = []
        self.parties: list = []         # loopback PartyProcess objects
        self._listener = None
        self.model = None
        self.predictor = None

        peers: dict = {}
        if transport == "socket":
            import multiprocessing as mp
            ctx = mp.get_context("spawn")
            self._listener = _socket.socket()
            try:
                self._listener.bind(("127.0.0.1", 0))
                self._listener.listen(self.n_hosts)
                port = self._listener.getsockname()[1]
                for hid, X in enumerate(X_hosts):
                    p = ctx.Process(target=host_main,
                                    args=(port, hid, params, np.asarray(X),
                                          export_dir),
                                    daemon=True)
                    p.start()
                    self.procs.append(p)
                self._listener.settimeout(timeout)
                hello_rx = 0        # read before the channel exists;
                                    # credited to rx_bytes below so each
                                    # side's framed-byte totals reconcile
                for _ in range(self.n_hosts):
                    try:
                        sock, _ = self._listener.accept()
                    except _socket.timeout as e:
                        dead = [p.pid for p in self.procs
                                if not p.is_alive()]
                        raise TransportError(
                            f"host process(es) never connected within "
                            f"{timeout}s (exited early: {dead or 'none'})"
                            ) from e
                    ep = SocketEndpoint(sock)
                    frame = ep.recv_bytes(timeout)
                    _, _, _, tag, _, hello = decode_frame(frame)
                    if tag != "hello":
                        raise TransportError(
                            f"expected hello, got {tag!r}")
                    hello_rx += len(frame) + 4
                    peers[f"host{int(hello['hid'])}"] = ep
            except BaseException:
                # __init__ failed: the caller never gets an object to
                # close(), so reap children and sockets here
                for ep in peers.values():
                    ep.close()
                for p in self.procs:
                    if p.is_alive():
                        p.terminate()
                self._listener.close()
                raise
        elif transport == "loopback":
            for hid, X in enumerate(X_hosts):
                guest_end, host_end = LoopbackEndpoint.pair()
                hch = TransportChannel(f"host{hid}", {"guest": host_end},
                                       timeout)
                pp = PartyProcess(hid, params, X, hch,
                                  export_dir=export_dir)
                host_end.on_deliver = pp.pump
                peers[f"host{hid}"] = guest_end
                self.parties.append(pp)
        else:
            raise ValueError(f"unknown transport {transport!r}")
        self.channel = TransportChannel("guest", peers, timeout)
        if transport == "socket":
            self.channel.rx_bytes["hello"] += hello_rx

    # -- training -------------------------------------------------------
    def fit(self, X_guest, y):
        from ..core.boosting import VerticalBoosting
        # per-fit accounting on BOTH sides of the wire: the model's Stats
        # are fresh, so the channel ledgers and host Stats must be too,
        # or a refit on a long-lived run double-counts
        self.channel.reset_accounting()
        for hid in range(self.n_hosts):
            self.channel.control_send(f"host{hid}", "reset_stats", None)
        model = VerticalBoosting(self.params)
        model.channel = self.channel
        model.remote_hosts = [RemoteHostHandle(self.channel, hid)
                              for hid in range(self.n_hosts)]
        model.fit(X_guest, y, [])
        self.model = model
        self.predictor = None           # stale after refit
        return model

    # -- serving --------------------------------------------------------
    def serve(self, out_dir: str | None = None):
        """Export per-party halves (guest here, each host in its own
        process), reload them, and wire a predictor over the transport.
        Returns the :class:`FederatedPredictor`."""
        from ..serving.engine import FederatedPredictor
        from ..serving.export import export_guest, load_guest
        from ..serving.packed import pack_guest
        if self.model is None:
            raise RuntimeError("serve() needs a fitted model: call fit()")
        out_dir = out_dir or self.export_dir
        guest_half, host_keys = pack_guest(self.model)
        if out_dir:
            gdir = export_guest(guest_half,
                                os.path.join(out_dir, "guest"))
            guest_half = load_guest(gdir)   # serve from the reloaded half
        for hid in range(self.n_hosts):
            self.channel.control_send(
                f"host{hid}", "serve_setup",
                {"keys": [list(k) for k in host_keys[hid]],
                 "export_dir": out_dir})
        remote = []
        for hid in range(self.n_hosts):
            ack = self.channel.control_recv(f"host{hid}", "serve_ready")
            remote.append(RemoteServingHost(self.channel, hid,
                                            int(ack["k"])))
        self.predictor = FederatedPredictor(
            guest_half, remote, channel=self.channel,
            stats=self.model.stats)
        return self.predictor

    def stage_host_data(self, X_hosts: list) -> None:
        """Ship each host its OWN feature rows for the upcoming batch —
        the out-of-band data arrival every party sees in a real
        deployment (the serving protocol itself still moves only
        instance ids and bit blocks)."""
        for hid, X in enumerate(X_hosts):
            self.channel.control_send(f"host{hid}", "serve_data",
                                      {"X": np.asarray(X)})

    def predict_score(self, X_guest, X_hosts: list | None = None, *,
                      staged: bool = False) -> np.ndarray:
        """Serve one batch.  Pass ``X_hosts`` to stage each host's rows
        for THIS batch, or ``staged=True`` to assert the hosts already
        hold the right rows (initially their training matrices).  With
        neither, raise: a guest batch silently scored against stale host
        rows mixes features from different instances with no error."""
        if self.predictor is None:
            self.serve()
        if X_hosts is not None:
            self.stage_host_data(X_hosts)
        elif not staged:
            raise ValueError(
                "host rows for this batch are not staged: pass X_hosts "
                "(ships each host its rows) or staged=True (the hosts' "
                "currently staged matrices ARE this batch's rows)")
        return self.predictor.predict_score(X_guest,
                                            [None] * self.n_hosts)

    # -- diagnostics ----------------------------------------------------
    def host_stats(self) -> list:
        """Each host's Stats/ledger/socket counters (control round-trip)."""
        out = []
        for hid in range(self.n_hosts):
            self.channel.control_send(f"host{hid}", "get_stats", None)
            out.append(self.channel.control_recv(f"host{hid}", "stats"))
        return out

    def merged_stats(self) -> Stats:
        """Guest stats + every host's counters folded in: comparable to
        the single shared Stats of an in-process run."""
        merged = Stats()
        merged.merge_counts(self.model.stats.as_dict())
        for hs in self.host_stats():
            merged.merge_counts(hs["stats"])
        return merged

    def ping(self, hid: int = 0) -> float:
        """One control round-trip, seconds."""
        t0 = time.perf_counter()
        self.channel.control_send(f"host{hid}", "ping", {"t": t0})
        self.channel.control_recv(f"host{hid}", "pong")
        return time.perf_counter() - t0

    def close(self) -> None:
        for hid in range(self.n_hosts):
            try:
                self.channel.control_send(f"host{hid}", "bye", None)
            except (TransportError, OSError):
                pass        # peer already dead (crashed host, reset pipe)
        for p in self.procs:
            p.join(timeout=30)
            if p.is_alive():
                p.terminate()
        self.channel.close()
        if self._listener is not None:
            self._listener.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
