"""Deterministic fault injection for the party runtime (DESIGN.md §11).

A :class:`FaultPlan` is a seeded, picklable schedule of faults; a
:class:`FaultyEndpoint` wraps a real endpoint (socket or loopback) and
applies the plan to the frames flowing through it.  Determinism is the
point: every fault fires at an exact (direction, tag, nth-occurrence) —
or (tree, layer) — coordinate, so a chaos test that fails replays
byte-for-byte under the same plan and seed.

Fault vocabulary (each rule fires ONCE, at its coordinate):

* :class:`Delay` — sleep before forwarding the frame (straggler).
* :class:`DropConn` — close the underlying transport and raise, as if the
  TCP connection died mid-protocol.  The host re-dials, the guest
  re-accepts, and the resilient loop replays the tree.
* :class:`Corrupt` — flip bytes in the frame body (seeded positions): the
  receiver's codec must answer with ``TransportError``, never garbage.
* :class:`Truncate` — forward only a prefix of the frame (framing stays
  consistent: the length prefix describes the truncated body, so this
  exercises payload decoding, not a wedged ``_read_exact``).
* :class:`Kill` — ``os._exit`` the process (host crash).  Coordinates may
  be (tag, nth) or (tree, layer): trees are counted by ``enc_gh`` frames
  seen, layers by ``assign_sync`` frames since the last ``enc_gh``.
* :class:`Wedge` — stop forwarding and sleep forever (a hung peer, NOT a
  dead one: the process stays alive and stops answering heartbeats —
  what the liveness supervisor exists to catch).  With
  ``ignore_sigterm`` the process also traps SIGTERM, which is the
  zombie-escalation scenario ``MultiHostRun.close`` must SIGKILL out of.

Faults never bypass accounting invariants: they perturb the WIRE, and
the retry/replay machinery must bring the run back to the fault-free
fixed point (bit-identical model, converged per-tag ledgers).
"""

from __future__ import annotations

import dataclasses
import os
import random
import time

from ..analysis import schema as wire
from ..obs import trace as obs_trace
from .transport import TransportError, peek_frame_header

SEND, RECV = "send", "recv"


@dataclasses.dataclass
class Rule:
    """Base coordinate: fire on the ``nth`` (1-based) frame with ``tag``
    moving in ``direction`` through the endpoint; or, for rules that
    support it, at a (tree, layer) point."""
    tag: str = ""
    nth: int = 1
    direction: str = RECV
    tree: int | None = None
    layer: int | None = None

    def matches(self, direction: str, tag: str, count: int,
                tree: int, layer: int) -> bool:
        if self.tree is not None:
            return (direction == self.direction and tree == self.tree
                    and (self.layer is None or layer == self.layer)
                    and (not self.tag or tag == self.tag))
        return (direction == self.direction and tag == self.tag
                and count == self.nth)


@dataclasses.dataclass
class Delay(Rule):
    seconds: float = 0.05


@dataclasses.dataclass
class DropConn(Rule):
    pass


@dataclasses.dataclass
class Corrupt(Rule):
    n_flips: int = 4


@dataclasses.dataclass
class Truncate(Rule):
    keep_fraction: float = 0.5


@dataclasses.dataclass
class Kill(Rule):
    exit_code: int = 13


@dataclasses.dataclass
class Wedge(Rule):
    ignore_sigterm: bool = False
    sleep_seconds: float = 3600.0


@dataclasses.dataclass
class FaultPlan:
    """A seeded list of one-shot fault rules.

    Picklable (it crosses the multiprocessing spawn boundary into host
    processes) and stateless until :meth:`fresh` is called in the target
    process — the returned copy owns the runtime counters, so the same
    plan object can parameterize any number of runs."""
    rules: list = dataclasses.field(default_factory=list)
    seed: int = 0

    def fresh(self) -> "FaultPlan":
        plan = FaultPlan(rules=[dataclasses.replace(r) for r in self.rules],
                        seed=self.seed)
        plan._armed = list(plan.rules)
        plan._rng = random.Random(plan.seed)
        return plan

    def pick(self, direction: str, tag: str, count: int, tree: int,
             layer: int):
        """Pop and return the first armed rule matching this frame."""
        for i, r in enumerate(getattr(self, "_armed", ())):
            if r.matches(direction, tag, count, tree, layer):
                return self._armed.pop(i)
        return None


class FaultyEndpoint:
    """Endpoint wrapper that applies a :class:`FaultPlan`.

    Tracks per-(direction, tag) occurrence counters and the protocol
    position (tree = ``enc_gh`` frames seen on recv, layer =
    ``assign_sync`` frames since) by peeking frame HEADERS only — chaos
    must not pay a payload decode that changes the very timing it
    perturbs.  ``dead`` / ``close`` semantics delegate to the wrapped
    endpoint, so the retry and reconnect machinery sees a FaultyEndpoint
    exactly as it sees a bare one.
    """

    def __init__(self, ep, plan: FaultPlan):
        self.ep = ep
        self.plan = plan if hasattr(plan, "_armed") else plan.fresh()
        self.counts: dict = {}          # (direction, tag) -> frames seen
        self.tree = -1                  # enc_gh frames observed - 1
        self.layer = -1                 # assign_sync since last enc_gh - 1
        self.injected: list = []        # (rule class name, tag, count)

    # -- bookkeeping ----------------------------------------------------
    def _observe(self, direction: str, frame: bytes) -> tuple:
        try:
            _, _, _, tag, _ = peek_frame_header(frame)
        except Exception:               # noqa: BLE001 -- already-corrupt
            tag = "?"                   # frame: count it, match nothing
        key = (direction, tag)
        self.counts[key] = self.counts.get(key, 0) + 1
        if tag == wire.ENC_GH:
            self.tree += 1
            self.layer = -1
        elif tag == wire.ASSIGN_SYNC:
            self.layer += 1
        return tag, self.counts[key]

    def _apply(self, direction: str, frame: bytes) -> bytes:
        tag, count = self._observe(direction, frame)
        rule = self.plan.pick(direction, tag, count, self.tree, self.layer)
        if rule is None:
            return frame
        self.injected.append((type(rule).__name__, tag, count))
        # chaos endpoints are created before the party's channel exists,
        # so injection events ride the PROCESS-default tracer (set by
        # PartyProcess / the guest once tracing is on)
        obs_trace.current().instant(
            "fault_injected", cat="chaos", rule=type(rule).__name__,
            tag=tag, count=int(count), direction=direction,
            tree=int(self.tree), layer=int(self.layer))
        if isinstance(rule, Delay):
            time.sleep(rule.seconds)
            return frame
        if isinstance(rule, DropConn):
            self.ep.close()
            raise TransportError(
                f"chaos: dropped connection at {direction} {tag}#{count}")
        if isinstance(rule, Corrupt):
            body = bytearray(frame)
            rng = self.plan._rng
            for _ in range(rule.n_flips):
                body[rng.randrange(len(body))] ^= 1 << rng.randrange(8)
            return bytes(body)
        if isinstance(rule, Truncate):
            keep = max(1, int(len(frame) * rule.keep_fraction))
            return frame[:keep]
        if isinstance(rule, Kill):
            os._exit(rule.exit_code)    # a crash does not say goodbye
        if isinstance(rule, Wedge):
            if rule.ignore_sigterm:
                import signal
                signal.signal(signal.SIGTERM, signal.SIG_IGN)
            time.sleep(rule.sleep_seconds)
            raise TransportError("chaos: wedge expired")
        raise TransportError(f"chaos: unknown rule {type(rule).__name__}")

    # -- endpoint surface -----------------------------------------------
    @property
    def dead(self) -> bool:
        return getattr(self.ep, "dead", False)

    def send_bytes(self, frame: bytes) -> None:
        self.ep.send_bytes(self._apply(SEND, frame))

    def recv_bytes(self, timeout: float | None = None) -> bytes:
        return self._apply(RECV, self.ep.recv_bytes(timeout))

    def poll(self) -> bool:
        return self.ep.poll()

    def close(self) -> None:
        self.ep.close()
