"""Pallas TPU kernel: quantile binning (bucketize against per-feature splits).

bin(i, f) = #{t : values[i, f] >= thresholds[f, t]} -- a broadcast compare +
reduction over the (small) threshold axis, tiled over (instances x features)
so each VMEM tile streams HBM once.  Thresholds are padded with +inf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import default_interpret, round_up

BLOCK_I = 512
BLOCK_F = 32


def _bucketize_kernel(vals_ref, thr_ref, out_ref):
    v = vals_ref[...]                        # (BI, BF)
    t = thr_ref[...]                         # (BF, T)
    ge = v[:, :, None] >= t[None, :, :]
    out_ref[...] = ge.sum(axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_i", "block_f"))
def bucketize_pallas(values: jnp.ndarray, thresholds: jnp.ndarray,
                     interpret: bool | None = None,
                     block_i: int = BLOCK_I,
                     block_f: int = BLOCK_F) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    n_i, n_f = values.shape
    n_t = thresholds.shape[-1]
    pi, pf = round_up(max(n_i, 1), block_i), round_up(max(n_f, 1), block_f)
    vals_p = jnp.zeros((pi, pf), jnp.float32).at[:n_i, :n_f].set(values)
    thr_p = jnp.full((pf, n_t), jnp.inf, jnp.float32).at[:n_f].set(thresholds)

    out = pl.pallas_call(
        _bucketize_kernel,
        grid=(pi // block_i, pf // block_f),
        in_specs=[
            pl.BlockSpec((block_i, block_f), lambda i, f: (i, f)),
            pl.BlockSpec((block_f, n_t), lambda i, f: (f, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, block_f), lambda i, f: (i, f)),
        out_shape=jax.ShapeDtypeStruct((pi, pf), jnp.int32),
        interpret=interpret,
    )(vals_p, thr_p)
    return out[:n_i, :n_f]
