"""Mergeable per-feature quantile sketches for streaming threshold fitting.

``fit_quantile_thresholds`` needs the full value matrix in memory; at the
paper's tens-of-millions-of-rows regime that is the first O(rows) wall.
The streaming path fits one sketch per row block (``fit_sketch``), merges
them associatively (``merge_sketch``), and extracts the split points from
the merged summary (``sketch_thresholds``).

The sketch is a per-feature sorted array of distinct float64 values with
int64 multiplicities -- i.e. an *exact* weighted empirical CDF.  As long
as the number of distinct values per feature stays within ``capacity``,
``sketch_thresholds`` reproduces ``fit_quantile_thresholds`` bit-for-bit:
it evaluates the same ``np.quantile`` linear-interpolation rule (including
numpy's symmetric ``_lerp`` branch at gamma >= 0.5) on weighted order
statistics instead of on the materialized sort.  Past ``capacity`` the
sketch compresses deterministically to rank-equi-spaced anchors, bounding
the quantile rank error by n/capacity (the classic GK-style trade; FATE's
``Quantile.convert_feature_to_bin`` makes the same exactness-for-memory
trade on its production path).
"""

from __future__ import annotations

import dataclasses

import numpy as np

DEFAULT_CAPACITY = 8192


@dataclasses.dataclass
class FeatureSketch:
    values: np.ndarray   # (k,) float64, sorted, distinct
    counts: np.ndarray   # (k,) int64, positive

    @property
    def n(self) -> int:
        return int(self.counts.sum())


@dataclasses.dataclass
class QuantileSketch:
    features: list        # list[FeatureSketch], one per feature
    n_rows: int

    @property
    def n_features(self) -> int:
        return len(self.features)


def _compress(v: np.ndarray, c: np.ndarray, capacity: int):
    """Deterministic rank-equi-spaced compression to <= capacity points.

    Each distinct value is bucketed by the (weighted) rank of its midpoint;
    within a bucket the last value absorbs the bucket's total count, so the
    result stays sorted/distinct and preserves the total row count.
    """
    if len(v) <= capacity:
        return v, c
    cum = np.cumsum(c)
    n = cum[-1]
    mid = cum - c / 2.0
    bucket = np.minimum((mid * capacity / n).astype(np.int64), capacity - 1)
    # last index of each bucket actually present
    last = np.nonzero(np.r_[bucket[1:] != bucket[:-1], True])[0]
    out_v = v[last]
    out_c = np.diff(np.r_[np.int64(0), cum[last]])
    return out_v, out_c


def fit_sketch(X_chunk: np.ndarray,
               capacity: int = DEFAULT_CAPACITY) -> QuantileSketch:
    """Sketch one row block: per-feature distinct float64 values + counts."""
    X = np.asarray(X_chunk, np.float64)
    feats = []
    for f in range(X.shape[1]):
        v, c = np.unique(X[:, f], return_counts=True)
        v, c = _compress(v, c.astype(np.int64), capacity)
        feats.append(FeatureSketch(values=v, counts=c))
    return QuantileSketch(features=feats, n_rows=X.shape[0])


def merge_sketch(a: QuantileSketch, b: QuantileSketch,
                 capacity: int = DEFAULT_CAPACITY) -> QuantileSketch:
    """Associative merge: sorted-merge the distinct values, add counts."""
    assert a.n_features == b.n_features
    feats = []
    for fa, fb in zip(a.features, b.features):
        v = np.concatenate([fa.values, fb.values])
        c = np.concatenate([fa.counts, fb.counts])
        order = np.argsort(v, kind="mergesort")
        v, c = v[order], c[order]
        keep = np.empty(len(v), bool)
        keep[0] = True
        keep[1:] = v[1:] != v[:-1]
        idx = np.cumsum(keep) - 1
        out_v = v[keep]
        out_c = np.zeros(len(out_v), np.int64)
        np.add.at(out_c, idx, c)
        out_v, out_c = _compress(out_v, out_c, capacity)
        feats.append(FeatureSketch(values=out_v, counts=out_c))
    return QuantileSketch(features=feats, n_rows=a.n_rows + b.n_rows)


def _weighted_quantiles(v: np.ndarray, c: np.ndarray,
                        qs: np.ndarray) -> np.ndarray:
    """np.quantile(.., method='linear') evaluated from an exact weighted
    CDF: same virtual index q*(n-1), same floor/gamma split, and the same
    symmetric lerp numpy uses (``b - diff*(1-t)`` when t >= 0.5) so the
    float64 result is bit-identical to the materialized sort."""
    cum = np.cumsum(c)
    n = int(cum[-1])
    virtual = qs * (n - 1)
    prev = np.floor(virtual)
    gamma = virtual - prev
    above = virtual >= n - 1
    prev_i = np.minimum(prev.astype(np.int64), n - 1)
    next_i = np.minimum(prev_i + 1, n - 1)
    lo = v[np.searchsorted(cum, prev_i, side="right")]
    hi = v[np.searchsorted(cum, next_i, side="right")]
    diff = hi - lo
    res = lo + diff * gamma
    res = np.where(gamma >= 0.5, hi - diff * (1.0 - gamma), res)
    return np.where(above, v[-1], res)


def sketch_thresholds(sk: QuantileSketch, n_bins: int) -> np.ndarray:
    """Split points from a (merged) sketch: (n_f, n_b-1) fp32, +inf padded
    -- the exact output contract of ``fit_quantile_thresholds``."""
    qs = np.linspace(0, 1, n_bins + 1)[1:-1]
    thr = np.empty((sk.n_features, len(qs)), np.float64)
    for f, fs in enumerate(sk.features):
        thr[f] = _weighted_quantiles(fs.values, fs.counts, qs)
    thr = thr.astype(np.float32)
    out = np.full_like(thr, np.inf)
    for f in range(thr.shape[0]):
        uniq = np.unique(thr[f])
        out[f, : len(uniq)] = uniq
    return out
