"""Pure-jnp oracle for the quantile-binning (bucketize) kernel."""

from __future__ import annotations

import jax.numpy as jnp


def bucketize_ref(values: jnp.ndarray, thresholds: jnp.ndarray) -> jnp.ndarray:
    """values (n_i, n_f) fp32, thresholds (n_f, n_b-1) fp32 (+inf padded,
    ascending per feature) -> bin indices (n_i, n_f) int32 in [0, n_b)."""
    ge = values[:, :, None] >= thresholds[None, :, :]
    return ge.sum(axis=-1).astype(jnp.int32)
