from .ops import bucketize, fit_quantile_thresholds  # noqa: F401
from .ref import bucketize_ref  # noqa: F401
from .sketch import (DEFAULT_CAPACITY, QuantileSketch,  # noqa: F401
                     fit_sketch, merge_sketch, sketch_thresholds)
