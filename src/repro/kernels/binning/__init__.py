from .ops import bucketize, fit_quantile_thresholds  # noqa: F401
from .ref import bucketize_ref  # noqa: F401
