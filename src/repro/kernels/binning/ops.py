"""Public wrappers: quantile threshold fitting + kernelized bucketize."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .binning import bucketize_pallas
from .ref import bucketize_ref
from .sketch import (DEFAULT_CAPACITY, QuantileSketch, fit_sketch,  # noqa: F401
                     merge_sketch, sketch_thresholds)


def fit_quantile_thresholds(values: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature quantile split points: (n_f, n_b-1) fp32, +inf padded
    where a feature has fewer distinct quantiles (degenerate features)."""
    v = np.asarray(values, np.float64)
    qs = np.linspace(0, 1, n_bins + 1)[1:-1]
    thr = np.quantile(v, qs, axis=0).T.astype(np.float32)   # (n_f, n_b-1)
    # collapse duplicate thresholds to +inf so empty bins stay empty
    out = np.full_like(thr, np.inf)
    for f in range(thr.shape[0]):
        uniq = np.unique(thr[f])
        out[f, : len(uniq)] = uniq
    return out


def bucketize(values, thresholds, use_pallas: bool = True,
              interpret: bool | None = None) -> jnp.ndarray:
    values = jnp.asarray(values, jnp.float32)
    thresholds = jnp.asarray(thresholds, jnp.float32)
    if use_pallas:
        return bucketize_pallas(values, thresholds, interpret=interpret)
    return bucketize_ref(values, thresholds)
