"""Jit'd public wrappers around the ciphertext histogram kernel."""

from __future__ import annotations

import jax.numpy as jnp

from .histogram import hist_pallas
from .ref import hist_ref


def ciphertext_histogram(bins, cts, n_bins: int, use_pallas: bool = True,
                         interpret: bool | None = None) -> jnp.ndarray:
    """(n_i, n_f) bins x (n_i, L) limb ciphertexts -> (n_f, n_b, L) lazy sums.

    Lazy output: limb values are raw int32 sums; callers must carry-fix /
    modular-reduce (cipher.reduce) before decrypting.  Masked instances are
    marked with a negative bin index.
    """
    bins = jnp.asarray(bins, jnp.int32)
    cts = jnp.asarray(cts, jnp.int32)
    if use_pallas:
        return hist_pallas(bins, cts, n_bins, interpret=interpret)
    return hist_ref(bins, cts, n_bins)


def count_histogram(bins, n_bins: int) -> jnp.ndarray:
    """Plaintext per-bin instance counts: (n_f, n_b) int32."""
    oh = (bins[:, :, None] == jnp.arange(n_bins)[None, None, :])
    return oh.sum(axis=0).astype(jnp.int32)
