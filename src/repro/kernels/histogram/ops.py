"""Jit'd public wrappers around the ciphertext histogram kernel."""

from __future__ import annotations

import jax.numpy as jnp

from .histogram import hist_pallas, layer_hist_pallas
from .ref import hist_ref, layer_hist_ref


def ciphertext_histogram(bins, cts, n_bins: int, use_pallas: bool = True,
                         interpret: bool | None = None) -> jnp.ndarray:
    """(n_i, n_f) bins x (n_i, L) limb ciphertexts -> (n_f, n_b, L) lazy sums.

    Lazy output: limb values are raw int32 sums; callers must carry-fix /
    modular-reduce (cipher.reduce) before decrypting.  Masked instances are
    marked with a negative bin index.
    """
    bins = jnp.asarray(bins, jnp.int32)
    cts = jnp.asarray(cts, jnp.int32)
    if use_pallas:
        return hist_pallas(bins, cts, n_bins, interpret=interpret)
    return hist_ref(bins, cts, n_bins)


def count_histogram(bins, n_bins: int) -> jnp.ndarray:
    """Plaintext per-bin instance counts: (n_f, n_b) int32."""
    oh = (bins[:, :, None] == jnp.arange(n_bins)[None, None, :])
    return oh.sum(axis=0).astype(jnp.int32)


def layer_ciphertext_histogram(bins, node_slot, cts, n_nodes: int,
                               n_bins: int, use_pallas: bool = True,
                               interpret: bool | None = None) -> jnp.ndarray:
    """Node-batched histogram for one tree layer: (n_i, n_f) bins x (n_i,)
    node slots x (n_i, L) limb ciphertexts -> (n_nodes, n_f, n_b, L) lazy
    sums.  One launch covers every direct-mode frontier node; masking rules
    match :func:`ciphertext_histogram` (negative bin or slot = skipped).
    """
    bins = jnp.asarray(bins, jnp.int32)
    node_slot = jnp.asarray(node_slot, jnp.int32)
    cts = jnp.asarray(cts, jnp.int32)
    if use_pallas:
        return layer_hist_pallas(bins, node_slot, cts, n_nodes, n_bins,
                                 interpret=interpret)
    return layer_hist_ref(bins, node_slot, cts, n_nodes, n_bins)


def layer_count_histogram(bins, node_slot, n_nodes: int, n_bins: int):
    """Plaintext per-(node, feature, bin) instance counts:
    (n_nodes, n_f, n_b) int32.  Counts never touch the cipher domain, so
    this is a flat numpy bincount over the (feature, node, bin) composite
    index -- O(n_i * n_f) memory, no one-hot materialized."""
    import numpy as np
    bins = np.asarray(bins, np.int64)
    node_slot = np.asarray(node_slot, np.int64)
    n_f = bins.shape[1]
    comp = node_slot[:, None] * n_bins + bins       # (n_i, n_f)
    valid = (node_slot[:, None] >= 0) & (bins >= 0)
    f_idx = np.broadcast_to(np.arange(n_f)[None, :], comp.shape)
    flat = (f_idx * (n_nodes * n_bins) + comp)[valid]
    out = np.bincount(flat, minlength=n_f * n_nodes * n_bins)
    return out.astype(np.int32).reshape(n_f, n_nodes,
                                        n_bins).transpose(1, 0, 2)
