"""Jit'd public wrappers around the ciphertext histogram kernel.

Single-device dispatchers plus the mesh-sharded layer dispatch
(:func:`sharded_layer_ciphertext_histogram`, DESIGN.md §5/§7): instance
tiles shard over the "data" mesh axis, node blocks over "model", and the
cross-shard reduction is a *lazy-limb* int32 psum — carries stay deferred
across the collective, so one ``cipher.reduce`` after the psum yields a
result bit-identical to the single-device path (int32 addition is exact and
order-free; the in-tile fp32 dots are exact per §3 regardless of how
instances are tiled across shards).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..common import cdiv, default_interpret, round_up
from .histogram import forest_hist_pallas, hist_pallas, layer_hist_pallas
from .ref import forest_hist_ref, hist_ref, layer_hist_ref


def ciphertext_histogram(bins, cts, n_bins: int, use_pallas: bool = True,
                         interpret: bool | None = None) -> jnp.ndarray:
    """(n_i, n_f) bins x (n_i, L) limb ciphertexts -> (n_f, n_b, L) lazy sums.

    Lazy output: limb values are raw int32 sums; callers must carry-fix /
    modular-reduce (cipher.reduce) before decrypting.  Masked instances are
    marked with a negative bin index.
    """
    bins = jnp.asarray(bins, jnp.int32)
    cts = jnp.asarray(cts, jnp.int32)
    if use_pallas:
        return hist_pallas(bins, cts, n_bins, interpret=interpret)
    return hist_ref(bins, cts, n_bins)


def count_histogram(bins, n_bins: int) -> jnp.ndarray:
    """Plaintext per-bin instance counts: (n_f, n_b) int32."""
    oh = (bins[:, :, None] == jnp.arange(n_bins)[None, None, :])
    return oh.sum(axis=0).astype(jnp.int32)


def layer_ciphertext_histogram(bins, node_slot, cts, n_nodes: int,
                               n_bins: int, use_pallas: bool = True,
                               interpret: bool | None = None) -> jnp.ndarray:
    """Node-batched histogram for one tree layer: (n_i, n_f) bins x (n_i,)
    node slots x (n_i, L) limb ciphertexts -> (n_nodes, n_f, n_b, L) lazy
    sums.  One launch covers every direct-mode frontier node; masking rules
    match :func:`ciphertext_histogram` (negative bin or slot = skipped).
    """
    bins = jnp.asarray(bins, jnp.int32)
    node_slot = jnp.asarray(node_slot, jnp.int32)
    cts = jnp.asarray(cts, jnp.int32)
    if use_pallas:
        return layer_hist_pallas(bins, node_slot, cts, n_nodes, n_bins,
                                 interpret=interpret)
    return layer_hist_ref(bins, node_slot, cts, n_nodes, n_bins)


def forest_ciphertext_histogram(bins, node_slot, cts, n_nodes: int,
                                n_bins: int, use_pallas: bool = True,
                                interpret: bool | None = None) -> jnp.ndarray:
    """(tree, node)-batched histogram for one round-forest layer:
    (n_i, n_f) bins x (n_i, k) member-local node slots x (n_i, L) limb
    ciphertexts -> (k, n_nodes, n_f, n_b, L) lazy sums.  One launch covers
    every direct-mode frontier node of every member tree; masking rules
    match :func:`layer_ciphertext_histogram` per member column.
    """
    bins = jnp.asarray(bins, jnp.int32)
    node_slot = jnp.asarray(node_slot, jnp.int32)
    cts = jnp.asarray(cts, jnp.int32)
    if use_pallas:
        return forest_hist_pallas(bins, node_slot, cts, n_nodes, n_bins,
                                  interpret=interpret)
    return forest_hist_ref(bins, node_slot, cts, n_nodes, n_bins)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins", "mesh",
                                             "use_pallas", "interpret"))
def _sharded_layer_hist(bins, node_slot, cts, n_nodes: int, n_bins: int,
                        mesh, use_pallas: bool, interpret: bool):
    sizes = dict(mesh.shape)
    dd, mm = sizes.get("data", 1), sizes.get("model", 1)
    n_i, n_f = bins.shape
    L = cts.shape[-1]
    npm = cdiv(n_nodes, mm)              # node block per model shard
    pi = round_up(max(n_i, 1), dd)
    # pad rows land on the last data shard with node_slot = -1 (ignored)
    bins_p = jnp.full((pi, n_f), -1, jnp.int32).at[:n_i].set(bins)
    slot_p = jnp.full((pi,), -1, jnp.int32).at[:n_i].set(node_slot)
    cts_p = jnp.zeros((pi, L), jnp.int32).at[:n_i].set(cts)

    def local(b, s, c):
        m_idx = jax.lax.axis_index("model")
        ls = s - m_idx * npm             # slot within this model shard's block
        ls = jnp.where((ls >= 0) & (ls < npm), ls, -1)
        if use_pallas:
            h = layer_hist_pallas(b, ls, c, npm, n_bins, interpret=interpret)
        else:
            h = layer_hist_ref(b, ls, c, npm, n_bins)
        # lazy-limb all-reduce: int32 sums, carries still deferred (§3);
        # then gather the node blocks over "model" -- the split-finding path
        # consumes every node (layer cumsum + shuffled split_infos concat),
        # so this collective is inherent to the protocol.
        h = jax.lax.psum(h, "data")
        return jax.lax.all_gather(h, "model", axis=0, tiled=True)

    out = shard_map(local, mesh=mesh,
                    in_specs=(P("data", None), P("data"), P("data", None)),
                    out_specs=P(None, None, None, None),
                    check_rep=False)(bins_p, slot_p, cts_p)
    return out[:n_nodes]


def sharded_layer_ciphertext_histogram(bins, node_slot, cts, n_nodes: int,
                                       n_bins: int, mesh,
                                       use_pallas: bool = True,
                                       interpret: bool | None = None
                                       ) -> jnp.ndarray:
    """Mesh-sharded :func:`layer_ciphertext_histogram`.

    Each (data, model) shard runs the layer kernel on its local instance
    tile for its node block only, then the lazy int32 limb sums psum over
    "data" and the node blocks all-gather over "model".  Bit-identical to
    the single-device dispatch for any mesh factorization.  Returns the
    (n_nodes, n_f, n_bins, L) global array.
    """
    if interpret is None:
        interpret = default_interpret()
    bins = jnp.asarray(bins, jnp.int32)
    node_slot = jnp.asarray(node_slot, jnp.int32)
    cts = jnp.asarray(cts, jnp.int32)
    out = _sharded_layer_hist(bins, node_slot, cts, n_nodes, n_bins, mesh,
                              use_pallas, interpret)
    # Land the gathered result on one device.  Downstream protocol steps
    # (reduce / cumsum / shuffle) are small relative to accumulation and
    # would otherwise execute redundantly on every replica; single-device
    # placement also sidesteps a jax 0.4.37 CPU miscompile where eager ops
    # mixing a partially-replicated shard_map output with unsharded operands
    # sum the replicas (observed with jnp.concatenate: values silently
    # multiply by the data-axis extent).
    return jax.device_put(out, jax.devices()[0])


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins", "mesh",
                                             "use_pallas", "interpret"))
def _sharded_forest_hist(bins, node_slot, cts, n_nodes: int, n_bins: int,
                         mesh, use_pallas: bool, interpret: bool):
    sizes = dict(mesh.shape)
    dd, mm = sizes.get("data", 1), sizes.get("model", 1)
    n_i, n_f = bins.shape
    k = node_slot.shape[1]
    L = cts.shape[-1]
    npm = cdiv(n_nodes, mm)              # member-local node block per shard
    pi = round_up(max(n_i, 1), dd)
    bins_p = jnp.full((pi, n_f), -1, jnp.int32).at[:n_i].set(bins)
    slot_p = jnp.full((pi, k), -1, jnp.int32).at[:n_i].set(node_slot)
    cts_p = jnp.zeros((pi, L), jnp.int32).at[:n_i].set(cts)

    def local(b, s, c):
        m_idx = jax.lax.axis_index("model")
        ls = s - m_idx * npm             # member-local slot within this block
        ls = jnp.where((ls >= 0) & (ls < npm), ls, -1)
        if use_pallas:
            h = forest_hist_pallas(b, ls, c, npm, n_bins, interpret=interpret)
        else:
            h = forest_hist_ref(b, ls, c, npm, n_bins)
        h = jax.lax.psum(h, "data")
        # gather the member-local node blocks over "model" (axis 1 of the
        # (k, npm, n_f, n_b, L) local result)
        return jax.lax.all_gather(h, "model", axis=1, tiled=True)

    out = shard_map(local, mesh=mesh,
                    in_specs=(P("data", None), P("data", None),
                              P("data", None)),
                    out_specs=P(None, None, None, None, None),
                    check_rep=False)(bins_p, slot_p, cts_p)
    return out[:, :n_nodes]


def sharded_forest_ciphertext_histogram(bins, node_slot, cts, n_nodes: int,
                                        n_bins: int, mesh,
                                        use_pallas: bool = True,
                                        interpret: bool | None = None
                                        ) -> jnp.ndarray:
    """Mesh-sharded :func:`forest_ciphertext_histogram`: the forest kernel's
    member axis rides along unchanged while instance tiles shard over "data"
    and member-local node blocks over "model".  Bit-identical to the
    single-device dispatch.  Returns the (k, n_nodes, n_f, n_bins, L) global
    array landed on one device (same jax-0.4.37 workaround as the layer
    variant)."""
    if interpret is None:
        interpret = default_interpret()
    bins = jnp.asarray(bins, jnp.int32)
    node_slot = jnp.asarray(node_slot, jnp.int32)
    cts = jnp.asarray(cts, jnp.int32)
    out = _sharded_forest_hist(bins, node_slot, cts, n_nodes, n_bins, mesh,
                               use_pallas, interpret)
    return jax.device_put(out, jax.devices()[0])


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins"))
def _layer_hist_segsum(bins, node_slot, cts, n_nodes: int, n_bins: int):
    """Scatter-add accumulation of one row block: (n_nodes, n_f, n_b, L)
    lazy int32 sums via a feature-vmapped segment_sum.  Bit-identical to the
    kernel paths (int32 limb addition is exact and order-free) but with
    O(block · L) temporaries instead of the reference einsum's
    O(block · n_f · n_nodes · n_b) one-hot — the accumulation the streamed
    dispatch uses where Pallas would run in interpret mode."""
    nseg = n_nodes * n_bins

    def one_feature(bcol):
        ok = (node_slot >= 0) & (bcol >= 0)
        idx = jnp.where(ok, node_slot * n_bins + bcol, nseg)
        return jax.ops.segment_sum(cts, idx, num_segments=nseg + 1)[:nseg]

    h = jax.vmap(one_feature, in_axes=1)(bins)      # (n_f, nseg, L)
    return h.reshape(h.shape[0], n_nodes, n_bins,
                     h.shape[-1]).transpose(1, 0, 2, 3)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins"))
def _forest_hist_segsum(bins, node_slot, cts, n_nodes: int, n_bins: int):
    """Member-batched :func:`_layer_hist_segsum`: node_slot is (n_i, k);
    returns (k, n_nodes, n_f, n_b, L) lazy sums."""
    return jax.vmap(
        lambda scol: _layer_hist_segsum(bins, scol, cts, n_nodes, n_bins),
        in_axes=1)(node_slot)


def streamed_layer_ciphertext_histogram(blocks, n_nodes: int, n_bins: int,
                                        forest: int = 0, mesh=None,
                                        use_pallas: bool = True,
                                        interpret: bool | None = None,
                                        on_block=None) -> jnp.ndarray:
    """Out-of-core layer accumulation (DESIGN.md §13): iterate
    ``(bins_blk, node_slot_blk, cts_blk)`` row blocks and sum their lazy
    int32 partial histograms; the caller runs ONE ``cipher.reduce`` on the
    result, exactly as for the monolithic dispatch.

    Bit-identity is the §3 psum-then-carry algebra applied over *time*
    instead of over devices: int32 limb addition is exact and order-free,
    so per-block partial sums + one deferred carry-fix equal the monolithic
    launch wherever the monolithic launch is itself exact (the cross-block
    accumulator has the same ~2^31 per-(node, feature, bin, limb) headroom
    as the kernel's own cross-tile accumulator).  Peak device memory is
    O(block + nodes) per launch, not O(rows).

    Per block the accumulation runs the mesh-sharded dispatch (when a
    multi-device mesh is given), the Pallas kernel (compiled backends), or
    the segment-sum path (CPU, where Pallas would interpret).  ``on_block``
    is an accounting hook receiving the device bytes uploaded per launch.
    """
    if interpret is None:
        interpret = default_interpret()
    multi = mesh is not None and mesh.devices.size > 1
    acc = None
    for bins_blk, slot_blk, cts_blk in blocks:
        bins_blk = jnp.asarray(bins_blk, jnp.int32)
        slot_blk = jnp.asarray(slot_blk, jnp.int32)
        cts_blk = jnp.asarray(cts_blk, jnp.int32)
        if on_block is not None:
            on_block(bins_blk.nbytes + slot_blk.nbytes + cts_blk.nbytes)
        if multi:
            if forest:
                h = sharded_forest_ciphertext_histogram(
                    bins_blk, slot_blk, cts_blk, n_nodes, n_bins, mesh,
                    use_pallas=use_pallas, interpret=interpret)
            else:
                h = sharded_layer_ciphertext_histogram(
                    bins_blk, slot_blk, cts_blk, n_nodes, n_bins, mesh,
                    use_pallas=use_pallas, interpret=interpret)
        elif use_pallas and not interpret:
            if forest:
                h = forest_hist_pallas(bins_blk, slot_blk, cts_blk, n_nodes,
                                       n_bins, interpret=interpret)
            else:
                h = layer_hist_pallas(bins_blk, slot_blk, cts_blk, n_nodes,
                                      n_bins, interpret=interpret)
        else:
            if forest:
                h = _forest_hist_segsum(bins_blk, slot_blk, cts_blk, n_nodes,
                                        n_bins)
            else:
                h = _layer_hist_segsum(bins_blk, slot_blk, cts_blk, n_nodes,
                                       n_bins)
        acc = h if acc is None else acc + h
    return acc


def psum_wire_bytes(mesh, shard_bytes: int) -> int:
    """Analytic intra-party collective cost of the layer psum: a ring
    all-reduce over the ``data`` axis moves 2·(d-1)/d · S bytes per device
    for a per-shard payload of S bytes; there is one independent ring per
    ``model`` coordinate, so the mesh-wide total is m · 2·(d-1)·S."""
    sizes = dict(mesh.shape)
    d = sizes.get("data", 1)
    m = sizes.get("model", 1)
    return m * 2 * (d - 1) * int(shard_bytes)


def allgather_wire_bytes(mesh, global_bytes: int) -> int:
    """Analytic cost of replicating the node-sharded layer histogram over
    "model": each device receives (m-1)/m of the global array, summed over
    all devices in the mesh."""
    sizes = dict(mesh.shape)
    m = sizes.get("model", 1)
    n_dev = int(np.prod(list(sizes.values())))
    return (m - 1) * n_dev * (int(global_bytes) // max(m, 1))


def layer_count_histogram(bins, node_slot, n_nodes: int, n_bins: int):
    """Plaintext per-(node, feature, bin) instance counts:
    (n_nodes, n_f, n_b) int32.  Counts never touch the cipher domain, so
    this is a flat numpy bincount over the (feature, node, bin) composite
    index -- O(n_i * n_f) memory, no one-hot materialized."""
    bins = np.asarray(bins, np.int64)
    node_slot = np.asarray(node_slot, np.int64)
    n_f = bins.shape[1]
    comp = node_slot[:, None] * n_bins + bins       # (n_i, n_f)
    valid = (node_slot[:, None] >= 0) & (bins >= 0)
    f_idx = np.broadcast_to(np.arange(n_f)[None, :], comp.shape)
    flat = (f_idx * (n_nodes * n_bins) + comp)[valid]
    out = np.bincount(flat, minlength=n_f * n_nodes * n_bins)
    return out.astype(np.int32).reshape(n_f, n_nodes,
                                        n_bins).transpose(1, 0, 2)
