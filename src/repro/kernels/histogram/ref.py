"""Pure-jnp oracle for the ciphertext histogram kernel."""

from __future__ import annotations

import jax.numpy as jnp


def hist_ref(bins: jnp.ndarray, cts: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """Reference ciphertext histogram.

    bins: (n_i, n_f) int32 bin index per (instance, feature); negative
          entries (padding / masked-out instances) contribute nothing.
    cts:  (n_i, L) int32 limb vectors (one packed-GH ciphertext per instance).
    returns (n_f, n_b, L) int32 lazy (un-carried) limb sums.
    """
    oh = (bins[:, :, None] == jnp.arange(n_bins)[None, None, :])
    out = jnp.einsum("ifb,il->fbl", oh.astype(jnp.float32),
                     cts.astype(jnp.float32))
    return out.astype(jnp.int32)
