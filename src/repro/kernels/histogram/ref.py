"""Pure-jnp oracle for the ciphertext histogram kernel."""

from __future__ import annotations

import jax.numpy as jnp


def hist_ref(bins: jnp.ndarray, cts: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """Reference ciphertext histogram.

    bins: (n_i, n_f) int32 bin index per (instance, feature); negative
          entries (padding / masked-out instances) contribute nothing.
    cts:  (n_i, L) int32 limb vectors (one packed-GH ciphertext per instance).
    returns (n_f, n_b, L) int32 lazy (un-carried) limb sums.
    """
    oh = (bins[:, :, None] == jnp.arange(n_bins)[None, None, :])
    out = jnp.einsum("ifb,il->fbl", oh.astype(jnp.float32),
                     cts.astype(jnp.float32))
    return out.astype(jnp.int32)


def layer_hist_ref(bins: jnp.ndarray, node_slot: jnp.ndarray,
                   cts: jnp.ndarray, n_nodes: int,
                   n_bins: int) -> jnp.ndarray:
    """Reference node-batched ciphertext histogram (one tree layer).

    bins:      (n_i, n_f) int32 bin per (instance, feature); negative = masked.
    node_slot: (n_i,) int32 frontier-node slot of each instance in
               [0, n_nodes); negative = instance not in any direct node.
    cts:       (n_i, L) int32 limb vectors.
    returns (n_nodes, n_f, n_b, L) int32 lazy (un-carried) limb sums: the
    composite one-hot ``node_slot[i] * n_bins + bins[i, f]`` folds the whole
    frontier into a single contraction.
    """
    comp = jnp.where((node_slot[:, None] >= 0) & (bins >= 0),
                     node_slot[:, None] * n_bins + bins, -1)
    oh = (comp[:, :, None] == jnp.arange(n_nodes * n_bins)[None, None, :])
    out = jnp.einsum("ifc,il->fcl", oh.astype(jnp.float32),
                     cts.astype(jnp.float32))
    out = out.reshape(bins.shape[1], n_nodes, n_bins, cts.shape[-1])
    return out.transpose(1, 0, 2, 3).astype(jnp.int32)


def forest_hist_ref(bins: jnp.ndarray, node_slot: jnp.ndarray,
                    cts: jnp.ndarray, n_nodes: int,
                    n_bins: int) -> jnp.ndarray:
    """Reference (tree, node)-batched histogram (one round-forest layer).

    Round-forest mode grows k bagged trees per boosting round off ONE shared
    ``enc_gh``; a row can sit in up to one direct frontier node *per member
    tree*, so the slot input gains a member axis.

    bins:      (n_i, n_f) int32 bin per (instance, feature); negative = masked.
    node_slot: (n_i, k) int32 member-local frontier slot of each instance in
               [0, n_nodes) for each of the k member trees; negative =
               instance not in any direct node of that member.
    cts:       (n_i, L) int32 limb vectors.
    returns (k, n_nodes, n_f, n_b, L) int32 lazy (un-carried) limb sums.
    """
    comp = jnp.where((node_slot[:, :, None] >= 0) & (bins[:, None, :] >= 0),
                     node_slot[:, :, None] * n_bins + bins[:, None, :], -1)
    oh = (comp[..., None] == jnp.arange(n_nodes * n_bins)[None, None, None, :])
    out = jnp.einsum("ikfc,il->kfcl", oh.astype(jnp.float32),
                     cts.astype(jnp.float32))
    k = node_slot.shape[1]
    out = out.reshape(k, bins.shape[1], n_nodes, n_bins, cts.shape[-1])
    return out.transpose(0, 2, 1, 3, 4).astype(jnp.int32)
