"""Pallas TPU kernel: ciphertext histogram accumulation as one-hot matmul.

The hot loop of SecureBoost+ (Algorithm 1/5) is ``H[f][bid] += [[gh_i]]``: a
scatter-add of big integers into (feature, bin) cells.  On TPU we lower the
scatter as a *matmul* so it runs on the MXU:

    hist[f*n_b + b, l] = sum_i onehot(bins[i, f] == b) * cts[i, l]

per (feature-block x instance-block) tile.  Limbs are radix-2**8 so the
within-tile fp32 dot is exact (sums < 2**24 for tiles <= 2**15 rows larger
than any VMEM tile we use), and cross-tile accumulation happens in int32 in
the output block (lazy carry: the caller carry-fixes / Barrett-reduces once
per bin, not once per add -- see DESIGN.md §3).

Grid: (feature_blocks, instance_blocks); instance axis is the innermost
reduction axis, revisiting the same output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import cdiv, default_interpret, round_up

# VMEM budget at defaults (fp32): onehot 256x(8*32)=256KB, cts 256xLx4,
# out 8x32xLx4 -- comfortably < 16MB for L <= 512.
BLOCK_I = 256
BLOCK_F = 8
# layer-batched variant: onehot grows to 256x(BF*BN*n_b); at the defaults
# (BF=8, BN=8, n_b=32) that is 2MB fp32, out block 8x8x32xLx4.
BLOCK_N = 8


def _hist_kernel(bins_ref, cts_ref, out_ref, *, n_bins: int):
    i_blk = pl.program_id(1)

    @pl.when(i_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...]                       # (BI, BF) int32
    cts = cts_ref[...].astype(jnp.float32)     # (BI, L)
    oh = (bins[:, :, None] == jnp.arange(n_bins)[None, None, :])
    oh = oh.astype(jnp.float32).reshape(bins.shape[0], -1)   # (BI, BF*n_b)
    # (BF*n_b, L) = oh^T @ cts  -- contract the instance axis on the MXU
    part = jax.lax.dot_general(oh, cts, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    out_ref[...] += part.astype(jnp.int32).reshape(out_ref.shape)


@functools.partial(jax.jit, static_argnames=("n_bins", "interpret",
                                             "block_i", "block_f"))
def hist_pallas(bins: jnp.ndarray, cts: jnp.ndarray, n_bins: int,
                interpret: bool | None = None,
                block_i: int = BLOCK_I, block_f: int = BLOCK_F) -> jnp.ndarray:
    """Ciphertext histogram: see ref.hist_ref for semantics.

    bins: (n_i, n_f) int32 (negative = masked), cts: (n_i, L) int32.
    Returns (n_f, n_bins, L) int32 lazy limb sums.
    """
    if interpret is None:
        interpret = default_interpret()
    n_i, n_f = bins.shape
    L = cts.shape[-1]
    pi, pf = round_up(max(n_i, 1), block_i), round_up(max(n_f, 1), block_f)
    bins_p = jnp.full((pi, pf), -1, jnp.int32).at[:n_i, :n_f].set(bins)
    cts_p = jnp.zeros((pi, L), jnp.int32).at[:n_i].set(cts)

    grid = (pf // block_f, pi // block_i)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, n_bins=n_bins),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, block_f), lambda f, i: (i, f)),
            pl.BlockSpec((block_i, L), lambda f, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_f, n_bins, L), lambda f, i: (f, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((pf, n_bins, L), jnp.int32),
        interpret=interpret,
    )(bins_p, cts_p)
    return out[:n_f]


def _layer_hist_kernel(bins_ref, node_ref, cts_ref, out_ref, *, n_bins: int,
                       block_n: int):
    n_blk = pl.program_id(0)
    i_blk = pl.program_id(2)

    @pl.when(i_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...]                       # (BI, BF) int32
    local = node_ref[...][:, 0] - n_blk * block_n   # (BI,) slot within block
    in_blk = (local >= 0) & (local < block_n)
    comp = jnp.where(in_blk[:, None] & (bins >= 0),
                     local[:, None] * n_bins + bins, -1)
    oh = (comp[:, :, None] == jnp.arange(block_n * n_bins)[None, None, :])
    oh = oh.astype(jnp.float32).reshape(bins.shape[0], -1)  # (BI, BF*BN*n_b)
    cts = cts_ref[...].astype(jnp.float32)     # (BI, L)
    # (BF*BN*n_b, L) = oh^T @ cts  -- contract the instance axis on the MXU
    part = jax.lax.dot_general(oh, cts, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    out_ref[...] += part.astype(jnp.int32).reshape(out_ref.shape)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins", "interpret",
                                             "block_i", "block_f", "block_n"))
def layer_hist_pallas(bins: jnp.ndarray, node_slot: jnp.ndarray,
                      cts: jnp.ndarray, n_nodes: int, n_bins: int,
                      interpret: bool | None = None,
                      block_i: int = BLOCK_I, block_f: int = BLOCK_F,
                      block_n: int = BLOCK_N) -> jnp.ndarray:
    """Layer-batched ciphertext histogram: see ref.layer_hist_ref.

    One launch accumulates every direct-mode frontier node of a tree layer:
    the one-hot axis is the composite ``node_slot[i] * n_bins + bins[i, f]``,
    tiled over (node_block, feature_block, instance_block) with the instance
    axis innermost (revisiting the same output block).

    bins: (n_i, n_f) int32 (negative = masked), node_slot: (n_i,) int32
    (negative = row not in any direct node), cts: (n_i, L) int32.
    Returns (n_nodes, n_f, n_bins, L) int32 lazy limb sums.
    """
    if interpret is None:
        interpret = default_interpret()
    n_i, n_f = bins.shape
    L = cts.shape[-1]
    block_n = min(block_n, round_up(max(n_nodes, 1), 2))
    pi = round_up(max(n_i, 1), block_i)
    pf = round_up(max(n_f, 1), block_f)
    pn = round_up(max(n_nodes, 1), block_n)
    bins_p = jnp.full((pi, pf), -1, jnp.int32).at[:n_i, :n_f].set(bins)
    node_p = jnp.full((pi, 1), -1, jnp.int32).at[:n_i, 0].set(node_slot)
    cts_p = jnp.zeros((pi, L), jnp.int32).at[:n_i].set(cts)

    grid = (pn // block_n, pf // block_f, pi // block_i)
    out = pl.pallas_call(
        functools.partial(_layer_hist_kernel, n_bins=n_bins, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, block_f), lambda n, f, i: (i, f)),
            pl.BlockSpec((block_i, 1), lambda n, f, i: (i, 0)),
            pl.BlockSpec((block_i, L), lambda n, f, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_f, block_n, n_bins, L),
                               lambda n, f, i: (f, n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((pf, pn, n_bins, L), jnp.int32),
        interpret=interpret,
    )(bins_p, node_p, cts_p)
    return out[:n_f, :n_nodes].transpose(1, 0, 2, 3)


def _forest_hist_kernel(bins_ref, slot_ref, cts_ref, out_ref, *, n_bins: int,
                        block_n: int):
    # grid (member, node_blocks, feature_blocks, instance_blocks); the
    # member axis selects one column of the (n_i, k) slot matrix via the
    # BlockSpec, so the body is the layer kernel verbatim.
    n_blk = pl.program_id(1)
    i_blk = pl.program_id(3)

    @pl.when(i_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...]                       # (BI, BF) int32
    local = slot_ref[...][:, 0] - n_blk * block_n   # (BI,) slot within block
    in_blk = (local >= 0) & (local < block_n)
    comp = jnp.where(in_blk[:, None] & (bins >= 0),
                     local[:, None] * n_bins + bins, -1)
    oh = (comp[:, :, None] == jnp.arange(block_n * n_bins)[None, None, :])
    oh = oh.astype(jnp.float32).reshape(bins.shape[0], -1)  # (BI, BF*BN*n_b)
    cts = cts_ref[...].astype(jnp.float32)     # (BI, L)
    part = jax.lax.dot_general(oh, cts, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    out_ref[...] += part.astype(jnp.int32).reshape(out_ref.shape)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins", "interpret",
                                             "block_i", "block_f", "block_n"))
def forest_hist_pallas(bins: jnp.ndarray, node_slot: jnp.ndarray,
                       cts: jnp.ndarray, n_nodes: int, n_bins: int,
                       interpret: bool | None = None,
                       block_i: int = BLOCK_I, block_f: int = BLOCK_F,
                       block_n: int = BLOCK_N) -> jnp.ndarray:
    """(tree, node)-batched ciphertext histogram: see ref.forest_hist_ref.

    One launch accumulates every direct-mode frontier node of every member
    tree of a round-forest layer.  The grid gains a leading member axis; the
    slot BlockSpec carves out member t's column of the (n_i, k) slot matrix,
    and each (t, f, n) output block is visited contiguously over the
    innermost instance axis.

    bins: (n_i, n_f) int32 (negative = masked), node_slot: (n_i, k) int32
    member-local slots (negative = row not in any direct node of that
    member), cts: (n_i, L) int32.
    Returns (k, n_nodes, n_f, n_bins, L) int32 lazy limb sums.
    """
    if interpret is None:
        interpret = default_interpret()
    n_i, n_f = bins.shape
    k = node_slot.shape[1]
    L = cts.shape[-1]
    block_n = min(block_n, round_up(max(n_nodes, 1), 2))
    pi = round_up(max(n_i, 1), block_i)
    pf = round_up(max(n_f, 1), block_f)
    pn = round_up(max(n_nodes, 1), block_n)
    bins_p = jnp.full((pi, pf), -1, jnp.int32).at[:n_i, :n_f].set(bins)
    slot_p = jnp.full((pi, k), -1, jnp.int32).at[:n_i].set(node_slot)
    cts_p = jnp.zeros((pi, L), jnp.int32).at[:n_i].set(cts)

    grid = (k, pn // block_n, pf // block_f, pi // block_i)
    out = pl.pallas_call(
        functools.partial(_forest_hist_kernel, n_bins=n_bins,
                          block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, block_f), lambda t, n, f, i: (i, f)),
            pl.BlockSpec((block_i, 1), lambda t, n, f, i: (i, t)),
            pl.BlockSpec((block_i, L), lambda t, n, f, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_f, block_n, n_bins, L),
                               lambda t, n, f, i: (t, f, n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, pf, pn, n_bins, L), jnp.int32),
        interpret=interpret,
    )(bins_p, slot_p, cts_p)
    return out[:, :n_f, :n_nodes].transpose(0, 2, 1, 3, 4)
