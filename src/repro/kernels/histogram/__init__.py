from .ops import ciphertext_histogram, count_histogram  # noqa: F401
from .ref import hist_ref  # noqa: F401
