from .ops import (ciphertext_histogram, count_histogram,  # noqa: F401
                  layer_ciphertext_histogram, layer_count_histogram)
from .ref import hist_ref, layer_hist_ref  # noqa: F401
