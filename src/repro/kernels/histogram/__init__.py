from .ops import (allgather_wire_bytes, ciphertext_histogram,  # noqa: F401
                  count_histogram, forest_ciphertext_histogram,
                  layer_ciphertext_histogram, layer_count_histogram,
                  psum_wire_bytes, sharded_forest_ciphertext_histogram,
                  sharded_layer_ciphertext_histogram,
                  streamed_layer_ciphertext_histogram)
from .ref import forest_hist_ref, hist_ref, layer_hist_ref  # noqa: F401
