"""Pure-jnp oracle for the fixed-multiplier big-int multiply kernel."""

from __future__ import annotations

import jax.numpy as jnp

from ...core.he import limbs


def mul_fixed_ref(x: jnp.ndarray, T: jnp.ndarray) -> jnp.ndarray:
    """x (N, Lx) canonical limbs, T (Lx, Lo) Toeplitz of a fixed big int
    -> (N, Lo) canonical limbs of x*b mod 2**(8*Lo)."""
    return limbs.mul_fixed(jnp.asarray(x, jnp.int32), jnp.asarray(T, jnp.int32))
