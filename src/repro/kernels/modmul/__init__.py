from .ops import (decrypt_batch, encrypt_batch, modmul_fixed,  # noqa: F401
                  modmul_fixed_sharded)
from .ref import mul_fixed_ref  # noqa: F401
