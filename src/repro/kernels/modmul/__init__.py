from .ops import decrypt_batch, encrypt_batch, modmul_fixed  # noqa: F401
from .ref import mul_fixed_ref  # noqa: F401
