"""Pallas TPU kernel: batch big-int multiply by a fixed constant.

Multiplying N ciphertexts by one fixed big integer b (the affine encryption
key a, its inverse for decryption, Barrett's mu / n during reduction) is a
matmul with b's Toeplitz limb matrix:

    y[i, :] = carry_fix( x[i, :] @ T_b )      T_b[j, j+k] = b_limbs[k]

Radix-2**8 keeps the fp32 MXU dot exact (products < 2**16, <= 2**8-ish terms
per output limb at 1024-bit operands -> sums < 2**24).  Carry propagation
runs in-kernel on the VMEM tile with a while_loop (converges in <= 4 passes
for these magnitudes plus a short ripple).

One kernel serves encryption, decryption, and cipher-compress scaling; the
ops.py wrapper composes three calls into a full Barrett modmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import default_interpret, round_up

BLOCK_N = 256
_LIMB_MASK = 255
_RADIX_BITS = 8


def _mul_fixed_kernel(x_ref, t_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)       # (BN, Lx)
    t = t_ref[...].astype(jnp.float32)       # (Lx, Lo)
    y = jax.lax.dot_general(x, t, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y.astype(jnp.int32)

    def cond(v):
        return jnp.any(v > _LIMB_MASK)

    def body(v):
        lo = v & _LIMB_MASK
        hi = v >> _RADIX_BITS
        hi = jnp.pad(hi, ((0, 0), (1, 0)))[:, :-1]   # carry into next limb
        return lo + hi

    out_ref[...] = jax.lax.while_loop(cond, body, y)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def mul_fixed_pallas(x: jnp.ndarray, T: jnp.ndarray,
                     interpret: bool | None = None,
                     block_n: int = BLOCK_N) -> jnp.ndarray:
    """x (N, Lx) canonical limbs -> (N, Lo) canonical limbs of x*b mod 2^(8Lo)."""
    if interpret is None:
        interpret = default_interpret()
    n, Lx = x.shape
    Lo = T.shape[-1]
    # shrink the row block for small batches (e.g. per-shard slices of the
    # mesh-sharded encrypt/decrypt path): same per-row arithmetic, less pad
    block_n = max(8, min(block_n, round_up(max(n, 1), 8)))
    pn = round_up(max(n, 1), block_n)
    x_p = jnp.zeros((pn, Lx), jnp.int32).at[:n].set(x)

    out = pl.pallas_call(
        _mul_fixed_kernel,
        grid=(pn // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, Lx), lambda i: (i, 0)),
            pl.BlockSpec((Lx, Lo), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, Lo), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pn, Lo), jnp.int32),
        interpret=interpret,
    )(x_p, jnp.asarray(T, jnp.int32))
    return out[:n]
