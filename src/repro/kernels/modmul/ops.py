"""Jit'd wrappers: kernelized modular multiply / encrypt / decrypt batches.

Composes the mul_fixed Pallas kernel with jnp glue to realize a full Barrett
modular multiplication by a fixed constant: all three O(L^2) products (x*b,
q1*mu, q3*n) run on the MXU; shifts/masks/conditional subtracts are O(L).

Mesh path (DESIGN.md §8): modular multiplication by a fixed constant is
embarrassingly parallel over rows, so when a (data, model) mesh is passed
the batch shards over "data" via ``shard_map`` — each shard runs the same
three Pallas kernels on its row block with NO collective, and the result is
bit-identical to the single-device path (per-row arithmetic is untouched by
the partitioning).  ``encrypt_batch`` can additionally width-pad the output
*inside* the shard (``out_width``) so ciphertexts are born at the
histogram accumulator width with their at-rest sharding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ...analysis.registry import declassifies
from ...core.he import limbs
from ..common import round_up
from .modmul import mul_fixed_pallas


def modmul_fixed(x: jnp.ndarray, T_b: jnp.ndarray, bctx: limbs.BarrettCtx,
                 interpret: bool | None = None) -> jnp.ndarray:
    # NOTE: not @jit at this level -- BarrettCtx.Ln drives static slicing;
    # the three mul_fixed_pallas calls below are individually jitted.
    """(x * b) mod n for a batch x (N, Ln) of canonical limbs, b fixed."""
    Ln = bctx.Ln
    prod = mul_fixed_pallas(x, T_b, interpret=interpret)[..., : 2 * Ln]
    # Barrett with kernelized q1*mu and q3*n
    q1 = limbs.shift_right_limbs(prod, Ln - 1)[..., : Ln + 2]
    q2 = mul_fixed_pallas(q1, bctx.T_mu, interpret=interpret)
    q3 = limbs.shift_right_limbs(q2, Ln + 1)[..., : Ln + 2]
    r1 = limbs.mask_bits(prod[..., : Ln + 2], (Ln + 1) * limbs.RADIX_BITS)
    q3n = mul_fixed_pallas(q3, bctx.T_n, interpret=interpret)[..., : Ln + 2]
    q3n = limbs.mask_bits(q3n, (Ln + 1) * limbs.RADIX_BITS)
    t = r1 - q3n
    t = t.at[..., Ln + 1].add(1)
    t = limbs.borrow_fix(t)
    r = t.at[..., Ln + 1].set(0)
    n_wide = jnp.pad(bctx.n, (0, 2))
    r = limbs.cond_sub(r, n_wide)
    r = limbs.cond_sub(r, n_wide)
    return r[..., :Ln]


@functools.partial(jax.jit, static_argnames=("mesh", "Ln", "interpret",
                                             "out_width"))
def _sharded_modmul(x, T_b, n_l, T_mu, T_n, *, mesh, Ln: int,
                    interpret: bool | None, out_width: int | None):
    # module-level jit so repeated calls hit the compilation cache (keyed on
    # shapes + statics) instead of re-staging the shard_map per call
    def local(xs, T, nl, Tmu, Tn):
        b = limbs.BarrettCtx(n=nl, T_mu=Tmu, T_n=Tn, Ln=Ln)
        flat = xs.reshape(-1, xs.shape[-1])
        r = modmul_fixed(flat, T, b, interpret=interpret)
        if out_width is not None and r.shape[-1] < out_width:
            r = jnp.pad(r, ((0, 0), (0, out_width - r.shape[-1])))
        return r.reshape(xs.shape[:-1] + (r.shape[-1],))

    spec_x = P(*(("data",) + (None,) * (x.ndim - 1)))
    return shard_map(
        local, mesh=mesh,
        in_specs=(spec_x, P(None, None), P(None), P(None, None),
                  P(None, None)),
        out_specs=spec_x, check_rep=False,
    )(x, T_b, n_l, T_mu, T_n)


def modmul_fixed_sharded(x: jnp.ndarray, T_b: jnp.ndarray,
                         bctx: limbs.BarrettCtx, mesh,
                         interpret: bool | None = None,
                         out_width: int | None = None) -> jnp.ndarray:
    """Row-sharded :func:`modmul_fixed` over the mesh's "data" axis.

    x: (N, ..., Lx) canonical limbs; leading axis shards over "data" (rows
    padded to divisibility with zeros — E/D of 0 is 0 for the fixed-constant
    multiply — and kept, see below), remaining axes replicate.  Each shard
    runs the per-shard Pallas kernels with no collective, so the result is
    bit-identical to the single-device path row-for-row.

    Returns the FULL padded batch (``data_pad`` rows included) so callers
    that keep state device-resident (the frontier engine pads its instance
    axis by the same rule) never reshard; slice ``[:N]`` host-side when the
    pad rows are unwanted.  ``out_width`` zero-pads the trailing limb axis
    inside the shard (ciphertexts born at histogram width).

    Like the §7 layer dispatch, this assumes the 2-axis (data, model) GBDT
    mesh of ``launch.mesh.make_gbdt_mesh`` — a multi-pod ("pod", "data",
    "model") mesh is out of contract for the frontier engine.
    """
    n = x.shape[0]
    sizes = dict(mesh.shape)
    dd = sizes.get("data", 1)
    pn = round_up(max(n, 1), dd)
    if pn != n:
        x = jnp.pad(x, [(0, pn - n)] + [(0, 0)] * (x.ndim - 1))
    return _sharded_modmul(x, T_b, bctx.n, bctx.T_mu, bctx.T_n, mesh=mesh,
                           Ln=bctx.Ln, interpret=interpret,
                           out_width=out_width)


def _mesh_active(mesh) -> bool:
    return mesh is not None and mesh.devices.size > 1


@declassifies("kernelized affine encryption: ciphertext limbs only")
def encrypt_batch(cipher, plaintext_limbs, interpret: bool | None = None,
                  mesh=None, out_width: int | None = None):
    """Kernelized affine encryption of a (N, ..., Lp) plaintext batch.

    With ``mesh``, rows shard over "data" (no collective) and the returned
    batch keeps its pad rows and born sharding — pre-pad the input with
    ``parallel.sharding.data_pad`` rows to control the padded extent.
    ``out_width`` pads ciphertext limbs to the histogram accumulator width
    shard-locally (no eager pad on the shard_map output)."""
    x = jnp.asarray(plaintext_limbs, jnp.int32)
    if x.shape[-1] < cipher.Ln:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, cipher.Ln - x.shape[-1])])
    elif x.shape[-1] > cipher.Ln:
        raise ValueError("plaintext wider than modulus")
    # same range guard as AffineCipher.encrypt_limbs: values >= n would wrap
    # silently through the Barrett pipeline and decrypt to garbage
    if bool(jnp.any(limbs.geq(x, jnp.broadcast_to(cipher.bctx.n, x.shape)))):
        raise ValueError("plaintext out of range (>= modulus n)")
    if _mesh_active(mesh):
        return modmul_fixed_sharded(x, cipher.T_enc, cipher.bctx, mesh,
                                    interpret=interpret, out_width=out_width)
    out = modmul_fixed(x.reshape(-1, x.shape[-1]), cipher.T_enc, cipher.bctx,
                       interpret=interpret)
    if out_width is not None and out.shape[-1] < out_width:
        out = jnp.pad(out, ((0, 0), (0, out_width - out.shape[-1])))
    return out.reshape(x.shape[:-1] + (out.shape[-1],))


def decrypt_batch(cipher, ct, interpret: bool | None = None, mesh=None):
    """Kernelized affine decryption -> plaintext limbs (N, Ln).

    With ``mesh``, the candidate rows shard over "data"; internal pad rows
    (decrypt(0) = 0) are sliced back off so the single-device contract — one
    output row per input row — is unchanged."""
    x = jnp.asarray(ct, jnp.int32)
    if _mesh_active(mesh):
        out = modmul_fixed_sharded(x, cipher.T_dec, cipher.bctx, mesh,
                                   interpret=interpret)
        return out[: x.shape[0]]
    return modmul_fixed(x, cipher.T_dec, cipher.bctx, interpret=interpret)
