"""Jit'd wrappers: kernelized modular multiply / encrypt / decrypt batches.

Composes the mul_fixed Pallas kernel with jnp glue to realize a full Barrett
modular multiplication by a fixed constant: all three O(L^2) products (x*b,
q1*mu, q3*n) run on the MXU; shifts/masks/conditional subtracts are O(L).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.he import limbs
from .modmul import mul_fixed_pallas


def modmul_fixed(x: jnp.ndarray, T_b: jnp.ndarray, bctx: limbs.BarrettCtx,
                 interpret: bool | None = None) -> jnp.ndarray:
    # NOTE: not @jit at this level -- BarrettCtx.Ln drives static slicing;
    # the three mul_fixed_pallas calls below are individually jitted.
    """(x * b) mod n for a batch x (N, Ln) of canonical limbs, b fixed."""
    Ln = bctx.Ln
    prod = mul_fixed_pallas(x, T_b, interpret=interpret)[..., : 2 * Ln]
    # Barrett with kernelized q1*mu and q3*n
    q1 = limbs.shift_right_limbs(prod, Ln - 1)[..., : Ln + 2]
    q2 = mul_fixed_pallas(q1, bctx.T_mu, interpret=interpret)
    q3 = limbs.shift_right_limbs(q2, Ln + 1)[..., : Ln + 2]
    r1 = limbs.mask_bits(prod[..., : Ln + 2], (Ln + 1) * limbs.RADIX_BITS)
    q3n = mul_fixed_pallas(q3, bctx.T_n, interpret=interpret)[..., : Ln + 2]
    q3n = limbs.mask_bits(q3n, (Ln + 1) * limbs.RADIX_BITS)
    t = r1 - q3n
    t = t.at[..., Ln + 1].add(1)
    t = limbs.borrow_fix(t)
    r = t.at[..., Ln + 1].set(0)
    n_wide = jnp.pad(bctx.n, (0, 2))
    r = limbs.cond_sub(r, n_wide)
    r = limbs.cond_sub(r, n_wide)
    return r[..., :Ln]


def encrypt_batch(cipher, plaintext_limbs, interpret: bool | None = None):
    """Kernelized affine encryption of a (N, Lp) plaintext batch."""
    x = jnp.asarray(plaintext_limbs, jnp.int32)
    if x.shape[-1] < cipher.Ln:
        x = jnp.pad(x, ((0, 0), (0, cipher.Ln - x.shape[-1])))
    return modmul_fixed(x, cipher.T_enc, cipher.bctx, interpret=interpret)


def decrypt_batch(cipher, ct, interpret: bool | None = None):
    """Kernelized affine decryption -> plaintext limbs (N, Ln)."""
    return modmul_fixed(jnp.asarray(ct, jnp.int32), cipher.T_dec, cipher.bctx,
                        interpret=interpret)
