"""Shared helpers for Pallas TPU kernels.

All kernels in this package target TPU (pl.pallas_call + BlockSpec VMEM
tiling) and are validated on CPU via ``interpret=True``, which executes the
kernel body in Python.  ``default_interpret()`` picks interpret mode
automatically when no TPU is present so tests/benches run anywhere.
"""

from __future__ import annotations

import jax


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
