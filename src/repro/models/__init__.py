from .common import ModelConfig, set_mesh, shard  # noqa: F401
from .lm import LM  # noqa: F401
