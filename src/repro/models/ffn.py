"""Dense SwiGLU FFN and MoE (shared + routed top-k experts).

MoE uses GShard-style capacity dispatch realized with scatter/gather (fully
differentiable, memory-linear): tokens sharded over "data", experts over
"model" (EP) -- GSPMD inserts the all-to-all at the dispatch/combine
boundary.  Capacity C = ceil(T * top_k * capacity_factor / E); overflowing
tokens drop (standard GShard semantics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense, shard


def init_ffn(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "wi": init_dense(ks[0], d_model, d_ff, dtype),
        "wg": init_dense(ks[1], d_model, d_ff, dtype),
        "wo": init_dense(ks[2], d_ff, d_model, dtype),
    }


def ffn(p, x):
    """SwiGLU; accepts (B, S, D) or flattened (T, D) activations."""
    mid = [None] * (x.ndim - 2)
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    h = shard(h, "data", *mid, "model")
    return shard(h @ p["wo"], "data", *mid, None)


def init_moe(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    fe = cfg.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": init_dense(ks[0], d, cfg.n_experts, jnp.float32),
        "wi": (jax.random.normal(ks[1], (cfg.n_experts, d, fe), jnp.float32)
               / d ** 0.5).astype(cfg.dtype),
        "wg": (jax.random.normal(ks[2], (cfg.n_experts, d, fe), jnp.float32)
               / d ** 0.5).astype(cfg.dtype),
        "wo": (jax.random.normal(ks[3], (cfg.n_experts, fe, d), jnp.float32)
               / fe ** 0.5).astype(cfg.dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_ffn(ks[4], d, fe * cfg.n_shared_experts, cfg.dtype)
    return p


def moe(p, cfg: ModelConfig, x):
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    gates, eids = jax.lax.top_k(jax.nn.softmax(logits, -1), k)   # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    cap = int(max(1, (T * k * cfg.capacity_factor) // E))
    # position of each (token, choice) within its expert queue, via SORT
    # ranking rather than a (T*k, E) one-hot cumsum: XLA lowers the big
    # cumsum as a quadratic reduce-window (measured 125x FLOP bloat at
    # deepseek-moe scale); argsort + searchsorted is O(n log n) and has no
    # prefix scan at all.  Slot assignment within an expert differs from
    # arrival order, which GShard capacity semantics don't require.
    flat_e = eids.reshape(-1)                                # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E))  # (E,)
    rank_sorted = jnp.arange(T * k) - group_start[sorted_e]
    slot = jnp.zeros_like(flat_e).at[order].set(rank_sorted)
    keep = slot < cap

    # dispatch: (E, cap, D)
    xe = jnp.zeros((E, cap, D), x.dtype)
    src = jnp.repeat(xt, k, axis=0)                          # (T*k, D)
    xe = xe.at[flat_e, jnp.where(keep, slot, 0)].add(
        src * keep[:, None].astype(x.dtype))
    xe = shard(xe, "model", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])              # (E, cap, D)
    ye = shard(ye, "model", None, None)

    # combine
    yt = ye[flat_e, jnp.where(keep, slot, 0)]                # (T*k, D)
    yt = yt * (gates.reshape(-1, 1) * keep[:, None]).astype(x.dtype)
    y = yt.reshape(T, k, D).sum(axis=1)

    if cfg.n_shared_experts:
        y = y + ffn(p["shared"], xt)
    return shard(y.reshape(B, S, D), "data", None, None)
