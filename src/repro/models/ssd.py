"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Training uses the chunked SSD algorithm: within-chunk attention-like
matmuls plus an across-chunk state recurrence carried by ``lax.scan`` --
the matmul-dominant formulation that suits the MXU.  Decode is the exact
single-step SSM update with constant state (B, H, hd, N), which is why the
ssm arch runs the 524k-context cell.

Layout follows Mamba-2: d_inner = expand * d_model, heads = d_inner /
head_dim, scalar A per head, B/C shared across heads (n_groups = 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense, shard


def _dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_head_dim
    return di, nh, cfg.ssm_head_dim, cfg.ssm_state


def init_ssd_block(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, nh, hd, N = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_dense(ks[0], d, 2 * di + 2 * N + nh, cfg.dtype),
        "out_proj": init_dense(ks[1], di, d, cfg.dtype),
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, di + 2 * N),
                                   jnp.float32) * 0.02).astype(cfg.dtype),
        "A_log": jnp.log(jax.random.uniform(ks[3], (nh,), jnp.float32, 1., 16.)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((di,), cfg.dtype),
    }


def _split_proj(p, cfg, x):
    di, nh, hd, N = _dims(cfg)
    z_xbc_dt = x @ p["in_proj"]
    z = z_xbc_dt[..., :di]
    xbc = z_xbc_dt[..., di: 2 * di + 2 * N]
    dt = jax.nn.softplus(
        z_xbc_dt[..., 2 * di + 2 * N:].astype(jnp.float32) + p["dt_bias"])
    return z, xbc, dt


def _conv(x, w):
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(pad[:, i: i + x.shape[1], :] * w[i] for i in range(K))


def ssd_train(p, cfg: ModelConfig, x):
    """x: (B, S, D) -> (B, S, D); S must be a multiple of ssm_chunk."""
    Bsz, S, _ = x.shape
    di, nh, hd, N = _dims(cfg)
    Q = cfg.ssm_chunk
    nc = S // Q
    z, xbc, dt = _split_proj(p, cfg, x)
    xbc = jax.nn.silu(_conv(xbc, p["conv"]))
    xs = xbc[..., :di].reshape(Bsz, S, nh, hd)
    Bv = xbc[..., di: di + N]                                # (B, S, N)
    Cv = xbc[..., di + N:]                                   # (B, S, N)

    A = -jnp.exp(p["A_log"])                                 # (nh,) < 0
    dA = dt * A                                              # (B, S, nh)
    xs_dt = (xs.astype(jnp.float32) * dt[..., None])

    # chunk views
    dA_c = dA.reshape(Bsz, nc, Q, nh)
    cums = jnp.cumsum(dA_c, axis=2)                          # within-chunk
    x_c = xs_dt.reshape(Bsz, nc, Q, nh, hd)
    B_c = Bv.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    C_c = Cv.reshape(Bsz, nc, Q, N).astype(jnp.float32)

    # (1) within-chunk (diagonal block): causal decay kernel.
    # Mask BEFORE exp: future positions have positive exponents that
    # overflow, and where(mask, exp(x), 0) still propagates NaN grads.
    seg = cums[:, :, :, None, :] - cums[:, :, None, :, :]    # (B,c,Q,Q,nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(causal, seg, -1e30))
    CB = jnp.einsum("bcqn,bckn->bcqk", C_c, B_c)
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", CB, L, x_c)

    # (2) chunk states + across-chunk recurrence
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)        # (B,c,Q,nh)
    states = jnp.einsum("bckn,bckh,bckhp->bchnp",
                        B_c, decay_to_end, x_c)              # (B,c,nh,N,hd)
    chunk_decay = jnp.exp(cums[:, :, -1, :])                 # (B,c,nh)

    def scan_fn(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h
    init = jnp.zeros((Bsz, nh, N, hd), jnp.float32)
    _, h_prev = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                 # (B,c,nh,N,hd)

    # (3) contribution of carried state to each position
    decay_from_start = jnp.exp(cums)                         # (B,c,Q,nh)
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                       C_c, decay_from_start, h_prev)

    y = (y_diag + y_off).reshape(Bsz, S, nh, hd)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)                                   # gated norm-ish
    from .common import rms_norm
    y = rms_norm(y, p["norm"])
    return shard(y @ p["out_proj"], "data", None, None)


def ssd_decode(p, cfg: ModelConfig, x, state):
    """One step.  state: {"h": (B, nh, N, hd) fp32, "conv": (B, K-1, di+2N)}."""
    di, nh, hd, N = _dims(cfg)
    z, xbc, dt = _split_proj(p, cfg, x)                      # (B,1,...)
    hist = jnp.concatenate([state["conv"], xbc], axis=1)
    xbc1 = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, p["conv"]))
    new_conv = hist[:, 1:, :]
    xs = xbc1[:, :di].reshape(-1, nh, hd).astype(jnp.float32)
    Bv = xbc1[:, di: di + N].astype(jnp.float32)
    Cv = xbc1[:, di + N:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0] * A)                               # (B, nh)
    h = state["h"] * dA[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhnp", Bv, xs, dt[:, 0])
    y = jnp.einsum("bn,bhnp->bhp", Cv, h)
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(-1, 1, di).astype(x.dtype) * jax.nn.silu(z)
    from .common import rms_norm
    y = rms_norm(y, p["norm"])
    return y @ p["out_proj"], {"h": h, "conv": new_conv}


def init_ssd_state(cfg: ModelConfig, batch: int) -> dict:
    di, nh, hd, N = _dims(cfg)
    return {"h": jnp.zeros((batch, nh, N, hd), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * N),
                              cfg.dtype)}
