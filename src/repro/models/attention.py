"""GQA attention: training (causal / sliding window), prefill, and decode.

Decode uses a flash-decoding-style *split-KV* merge so the sequence axis of
the KV cache can shard over the "model" mesh axis even when n_kv_heads <
model-parallel degree (common for GQA: kv=8 on a 16-way TP mesh).  Each
shard computes a partial (max, sumexp, out) over its KV slice; merging is a
tiny LSE combine -- GSPMD lowers it to an all-reduce of (B, H, 1)-sized
stats instead of all-gathering the 32k-long cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, init_dense, rms_norm, rotary, shard


def init_attention(key, cfg: ModelConfig, d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_dense(ks[0], d, cfg.n_heads * hd, cfg.dtype),
        "wk": init_dense(ks[1], d, cfg.n_kv_heads * hd, cfg.dtype),
        "wv": init_dense(ks[2], d, cfg.n_kv_heads * hd, cfg.dtype),
        "wo": init_dense(ks[3], cfg.n_heads * hd, d, cfg.dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros(hd, cfg.dtype)
        p["k_norm"] = jnp.zeros(hd, cfg.dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    sections = cfg.mrope_sections if cfg.mrope else None
    if positions is not None:
        q = rotary(q, positions, cfg.rope_theta, sections)
        k = rotary(k, positions, cfg.rope_theta, sections)
    q = shard(q, "data", None, "model", None)
    return q, k, v


def attention_train(p, cfg: ModelConfig, x, positions, causal: bool = True,
                    window: int = 0, kv: tuple | None = None):
    """Full-sequence attention.  kv overrides the keys/values source
    (cross-attention); window > 0 restricts to a local band."""
    B, S, _ = x.shape
    hd = cfg.hd
    q, k, v = _project_qkv(p, cfg, x, positions)
    if kv is not None:
        k, v = kv
    rep = cfg.n_heads // cfg.n_kv_heads
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    scores = scores.astype(jnp.float32)
    Sk = k.shape[1]
    if causal and kv is None:
        qi = jnp.arange(S)[:, None]
        ki = jnp.arange(Sk)[None, :]
        mask = qi >= ki
        if window:
            mask &= (qi - ki) < window
        scores = jnp.where(mask[None, None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, S, -1)
    out = out @ p["wo"]
    return shard(out, "data", None, None)


def attention_prefill(p, cfg: ModelConfig, x, positions, window: int = 0):
    """Causal attention that also returns the KV cache for decode."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = attention_train(p, cfg, x, positions, causal=True, window=window)
    return out, (k, v)


def attention_decode(p, cfg: ModelConfig, x, pos, cache, window: int = 0,
                     ring: bool = False):
    """One-token decode.  x: (B, 1, D); cache: (k, v) of (B, S_max, kv, hd);
    pos: (B,) current *absolute* position.  Returns (out, new_cache).

    ``ring=True`` treats the cache as a circular buffer of the last S_max
    tokens (windowed attention at 524k context: S_max = window).

    KV sequence axis is sharded over "model" (split-KV); the LSE merge makes
    the partial-softmax combine exact.
    """
    B = x.shape[0]
    hd = cfg.hd
    q, k_new, v_new = _project_qkv(p, cfg, x, pos[:, None])
    k_cache, v_cache = cache
    Smax = k_cache.shape[1]
    wpos = pos % jnp.int32(Smax) if ring else pos
    idx = wpos[:, None, None, None]
    onehot = (jnp.arange(Smax)[None, :, None, None] == idx)
    # Split-KV decode: the cache stays sharded over "model" on its SEQUENCE
    # axis end-to-end.  q and k_new/v_new are tiny -- constrain them
    # model-replicated so no op ever demands a head-sharded view of the
    # cache (which would all-gather 100s of GB; observed as SPMD
    # 'involuntary full rematerialization').  GQA is a grouped einsum, so
    # the heads/kv repeat is never materialized either.
    k_new = shard(k_new, "data", None, None, None)
    v_new = shard(v_new, "data", None, None, None)
    q = shard(q, "data", None, None, None)
    k_cache = jnp.where(onehot, k_new, k_cache)
    v_cache = jnp.where(onehot, v_new, v_cache)
    k_cache = shard(k_cache, "data", "model", None, None)
    v_cache = shard(v_cache, "data", "model", None, None)

    rep = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, rep, hd)       # (B, g, r, hd)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qg, k_cache) / np.sqrt(hd)
    scores = scores.astype(jnp.float32)              # (B, g, r, Smax)
    ki = jnp.arange(Smax)[None, None, None, :]
    if ring:
        # all slots valid once the ring is full; before that, only <= pos
        pb = pos[:, None, None, None]
        valid = (ki <= pb) | (pb >= Smax)
    else:
        pb = pos[:, None, None, None]
        valid = ki <= pb
        if window:
            valid &= (pb - ki) < window
    scores = jnp.where(valid, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bgrs,bsgd->bgrd", att, v_cache).reshape(B, 1, -1)
    out = out @ p["wo"]
    return out, (k_cache, v_cache)
