"""Shared model plumbing: config, norms, rotary embeddings, sharding helper.

Parameters are plain pytrees (nested dicts of jnp arrays).  Homogeneous layer
stacks are *stacked* along a leading layer axis and executed with
``jax.lax.scan`` so the lowered HLO stays one-layer-sized regardless of
depth (80-layer configs compile in seconds).

Sharding is expressed with ``shard(x, *axes)``: a no-op without a mesh (CPU
smoke tests), a ``with_sharding_constraint`` under the production mesh.
Axis vocabulary: "data" (batch; the pod axis is folded in for DP),
"model" (TP/EP), None (replicated).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: int = 0            # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False          # qwen2-vl M-RoPE (3-section rotary)
    mrope_sections: tuple = (16, 24, 24)
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # hybrid (RG-LRU + local attention, Griffin pattern: 2 recurrent : 1 attn)
    window: int = 0              # local attention window (0 = full causal)
    lru_width: int = 0
    conv_width: int = 4
    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str = "none"       # none | audio_stub | patch_stub
    # numerics
    dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_unroll: bool = False    # roofline mode: python-unroll layer stacks
                                 # so XLA cost_analysis counts every layer
    seq_shard: bool = False      # sequence-parallel residual stream: shard S
                                 # over "model" between blocks (TP collectives
                                 # become reduce-scatter/all-gather pairs)
    remat_policy: str = "full"   # full | dots (save matmul outputs, recompute
                                 # only cheap elementwise ops in the backward)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        d, f = self.d_model, self.d_ff
        emb = self.vocab * d
        if self.family == "ssm":
            di = self.ssm_expand * d
            nh = di // self.ssm_head_dim
            per = d * (2 * di + 2 * self.ssm_state + nh) + di * d \
                + self.conv_width * (di + 2 * self.ssm_state) + di
            return emb + self.n_layers * per + emb   # tied-ish head counted once
        att = d * self.hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.hd * d
        if self.family == "moe":
            fe = self.d_ff_expert or f
            ffn = (self.n_experts * 3 * d * fe
                   + self.n_shared_experts * 3 * d * fe
                   + d * self.n_experts)
        else:
            ffn = 3 * d * f
        per = att + ffn + 2 * d
        n_blocks = self.n_layers + (self.n_enc_layers if self.enc_dec else 0)
        cross = d * self.hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.hd * d if self.enc_dec else 0
        return emb + n_blocks * per + self.n_layers * cross + emb

    def n_active_params(self) -> int:
        """Active (per-token) params -- MoE counts top_k + shared experts."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        fe = self.d_ff_expert or self.d_ff
        att = d * self.hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.hd * d
        ffn = (self.top_k + self.n_shared_experts) * 3 * d * fe \
            + d * self.n_experts
        return 2 * self.vocab * d + self.n_layers * (att + ffn + 2 * d)


# ---------------------------------------------------------------------------
# sharding helper
# ---------------------------------------------------------------------------

_MESH: list = [None]     # active mesh (set by launch code)


def set_mesh(mesh) -> None:
    _MESH[0] = mesh


def get_mesh():
    return _MESH[0]


def shard(x, *axes):
    """Apply a sharding constraint if a mesh is active; else identity.

    ``axes`` name one mesh axis (or None) per array dim; "data" expands to
    ("pod", "data") when the mesh has a pod axis (DP across pods).
    """
    mesh = _MESH[0]
    if mesh is None:
        return x
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    spec = []
    for dim, a in zip(x.shape, axes):
        if a == "data" and "pod" in names:
            a = ("pod", "data")
        if a is not None:
            req = a if isinstance(a, tuple) else (a,)
            total = 1
            for r in req:
                total *= sizes.get(r, 1)
            if dim % total != 0:        # non-divisible: replicate this dim
                a = None
        spec.append(a)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_dense(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def rotary(x, positions, theta: float, sections: tuple | None = None):
    """Apply RoPE.  x: (B, S, H, hd); positions: (B, S) int32.

    With ``sections`` (M-RoPE stub), head_dim/2 frequency slots are split
    into (t, h, w) groups that would receive separate position streams; the
    stub feeds the same positions to all three (text-degenerate), which is
    exactly Qwen2-VL's behaviour on pure text.
    """
    hd = x.shape[-1]
    half = hd // 2
    freq_exp = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = theta ** -freq_exp
    ang = positions[..., None].astype(jnp.float32) * inv_freq   # (B,S,half)
    if sections is not None:
        # M-RoPE: same angles per section in the text-only stub
        ang = ang
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softmax_cross_entropy(logits, labels):
    """Token CE with fp32 logits; labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(mask.sum(), 1.0)
