"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    r_t = sigmoid(W_r x_t)            recurrence gate
    i_t = sigmoid(W_i x_t)            input gate
    a_t = a ^ (c * r_t)               a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training runs the diagonal recurrence with ``jax.lax.associative_scan``
(log-depth); decode is the single-step update -- constant state, which is
what makes the hybrid arch runnable at 524k context.  The full recurrent
block is Griffin's: conv1d(4) -> RG-LRU, gated by a GeLU branch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense, shard

_C = 8.0


def init_rglru_block(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "wx": init_dense(ks[0], d, w, cfg.dtype),        # input branch
        "wgate": init_dense(ks[1], d, w, cfg.dtype),     # GeLU gate branch
        "wo": init_dense(ks[2], w, d, cfg.dtype),
        "wr": init_dense(ks[3], w, w, cfg.dtype),
        "wi": init_dense(ks[4], w, w, cfg.dtype),
        "lam": jnp.asarray(
            jax.random.uniform(ks[5], (w,), jnp.float32, 2.0, 6.0)),
        "conv": (jax.random.normal(ks[6], (cfg.conv_width, w), jnp.float32)
                 * 0.02).astype(cfg.dtype),
    }


def _conv1d_causal(x, w):
    """Depthwise causal conv: x (B, S, W), w (K, W)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + x.shape[1], :] * w[i] for i in range(K))
    return out


def _gates(p, xb):
    r = jax.nn.sigmoid((xb @ p["wr"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xb @ p["wi"]).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lam"])          # log a_t  (<0)
    a = jnp.exp(log_a)
    gated_x = (i * xb.astype(jnp.float32)) * jnp.sqrt(
        jnp.maximum(1.0 - a * a, 1e-12))
    return a, gated_x


def rglru_train(p, cfg: ModelConfig, x):
    """x: (B, S, D) -> (B, S, D) via associative scan over S."""
    xb = _conv1d_causal(x @ p["wx"], p["conv"])
    a, gx = _gates(p, xb)                                # (B, S, W) fp32

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    h = h.astype(x.dtype)
    out = (h * jax.nn.gelu(x @ p["wgate"])) @ p["wo"]
    return shard(out, "data", None, None)


def rglru_decode(p, cfg: ModelConfig, x, state):
    """One step.  x: (B, 1, D); state: {"h": (B, W) fp32,
    "conv": (B, K-1, W)}.  Returns (out, new_state)."""
    xw = (x @ p["wx"])[:, 0, :]                          # (B, W)
    K = p["conv"].shape[0]
    hist = jnp.concatenate([state["conv"], xw[:, None, :]], axis=1)
    xb = jnp.einsum("bkw,kw->bw", hist, p["conv"])
    new_conv = hist[:, 1:, :]
    a, gx = _gates(p, xb[:, None, :])
    h = a[:, 0] * state["h"] + gx[:, 0]
    out = ((h.astype(x.dtype) * jax.nn.gelu(x[:, 0] @ p["wgate"]))
           @ p["wo"])[:, None, :]
    return out, {"h": h, "conv": new_conv}


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), cfg.dtype)}
