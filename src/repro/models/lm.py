"""Unified causal-LM assembly for all assigned architectures.

One class, :class:`LM`, builds dense / MoE / hybrid (RG-LRU) / SSM (SSD) /
enc-dec (whisper) / VLM-backbone stacks from a :class:`ModelConfig`.
Homogeneous stacks are scanned (``jax.lax.scan`` over stacked params) so the
HLO is one-layer-sized; the hybrid arch scans (rec, rec, attn) superblocks.
Blocks are remat-wrapped for training when ``cfg.remat``.

Entry points (all pure functions of pytrees -- pjit-able as-is):
  init(key) / abstract_init()             params
  loss(params, batch)                     train objective (CE, fp32 logits)
  prefill(params, batch)                  logits + decode cache
  decode_step(params, tokens, pos, cache) one-token serve step
  init_cache(batch, s_max)                cache pytree (KV / recurrent state)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .attention import (attention_decode, attention_train, init_attention)
from .common import (ModelConfig, init_dense, rms_norm, shard,
                     softmax_cross_entropy)
from .ffn import ffn, init_ffn, init_moe, moe
from .rglru import (init_rglru_block, init_rglru_state, rglru_decode,
                    rglru_train)
from .ssd import init_ssd_block, init_ssd_state, ssd_decode, ssd_train


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------

def _mixer_train(cfg, p, x, pos, window, kind):
    if kind == "attn":
        return attention_train(p["attn"], cfg, rms_norm(x, p["ln1"]), pos,
                               causal=True, window=window)
    if kind == "rec":
        return rglru_train(p["rec"], cfg, rms_norm(x, p["ln1"]))
    if kind == "ssd":
        return ssd_train(p["ssd"], cfg, rms_norm(x, p["ln1"]))
    raise ValueError(kind)


def _mixer_decode(cfg, p, x, pos, cache, window, kind, ring=False):
    if kind == "attn":
        return attention_decode(p["attn"], cfg, rms_norm(x, p["ln1"]), pos,
                                cache, window=window, ring=ring)
    if kind == "rec":
        return rglru_decode(p["rec"], cfg, rms_norm(x, p["ln1"]), cache)
    if kind == "ssd":
        return ssd_decode(p["ssd"], cfg, rms_norm(x, p["ln1"]), cache)
    raise ValueError(kind)


def _ffn_apply(cfg, p, x):
    if cfg.family == "moe" and "moe" in p:
        return moe(p["moe"], cfg, rms_norm(x, p["ln2"]))
    if "ffn" in p:
        return ffn(p["ffn"], rms_norm(x, p["ln2"]))
    return 0.0                     # ssd blocks have no separate FFN


def _block_train(cfg, p, x, pos, window, kind):
    x = x + _mixer_train(cfg, p, x, pos, window, kind)
    upd = _ffn_apply(cfg, p, x)
    x = x + upd if not isinstance(upd, float) else x
    if cfg.seq_shard:
        # sequence-parallel residual: the TP all-reduce decomposes into
        # reduce-scatter here + all-gather at the next block's matmuls
        x = shard(x, "data", "model", None)
    return x


def _block_decode(cfg, p, x, pos, cache, window, kind, ring=False):
    mix, cache = _mixer_decode(cfg, p, x, pos, cache, window, kind, ring)
    x = x + mix
    upd = _ffn_apply(cfg, p, x)
    return (x + upd if not isinstance(upd, float) else x), cache


def _init_block(key, cfg, kind, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros(cfg.d_model, cfg.dtype)}
    if kind == "attn":
        p["attn"] = init_attention(ks[0], cfg)
    elif kind == "rec":
        p["rec"] = init_rglru_block(ks[0], cfg)
    elif kind == "ssd":
        p["ssd"] = init_ssd_block(ks[0], cfg)
    if kind != "ssd":
        p["ln2"] = jnp.zeros(cfg.d_model, cfg.dtype)
        if cfg.family == "moe":
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype)
    if cross:
        p["ln_x"] = jnp.zeros(cfg.d_model, cfg.dtype)
        p["xattn"] = init_attention(ks[2], cfg)
    return p


def _stack(key, n: int, make):
    """Init n blocks and stack leaves on a leading layer axis."""
    keys = jax.random.split(key, max(n, 1))
    blocks = [make(keys[i]) for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks) if n else None


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        # hybrid pattern: Griffin's (rec, rec, attn) period
        if cfg.family == "hybrid":
            self.n_super = cfg.n_layers // 3
            self.n_tail = cfg.n_layers - 3 * self.n_super
        self._kind = {"ssm": "ssd"}.get(cfg.family, "attn")

    # -- init -----------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        p = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                        jnp.float32) * 0.02).astype(cfg.dtype),
            "norm_f": jnp.zeros(cfg.d_model, cfg.dtype),
            "lm_head": init_dense(ks[1], cfg.d_model, cfg.vocab, cfg.dtype),
        }
        if cfg.family == "hybrid":
            def make_super(k):
                k1, k2, k3 = jax.random.split(k, 3)
                return {"rec1": _init_block(k1, cfg, "rec"),
                        "rec2": _init_block(k2, cfg, "rec"),
                        "attn": _init_block(k3, cfg, "attn")}
            p["super"] = _stack(ks[2], self.n_super, make_super)
            if self.n_tail:
                p["tail"] = _stack(ks[3], self.n_tail,
                                   lambda k: _init_block(k, cfg, "rec"))
        elif cfg.enc_dec:
            p["enc"] = _stack(ks[2], cfg.n_enc_layers,
                              lambda k: _init_block(k, cfg, "attn"))
            p["dec"] = _stack(ks[3], cfg.n_layers,
                              lambda k: _init_block(k, cfg, "attn", cross=True))
            p["enc_norm"] = jnp.zeros(cfg.d_model, cfg.dtype)
        else:
            kind = self._kind
            p["blocks"] = _stack(ks[2], cfg.n_layers,
                                 lambda k: _init_block(k, cfg, kind))
        return p

    def abstract_init(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    # -- shared forward pieces -------------------------------------------
    def _scan_train(self, stacked, x, pos, fn):
        cfg = self.cfg
        body = fn
        if cfg.remat:
            if cfg.remat_policy == "dots":
                body = jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.dots_saveable)
            else:
                body = jax.checkpoint(fn)
        if cfg.scan_unroll:
            n = jax.tree.leaves(stacked)[0].shape[0]
            for i in range(n):
                x = body(jax.tree.map(lambda a: a[i], stacked), x)
            return x

        def step(carry, p):
            return body(p, carry), None

        x, _ = jax.lax.scan(step, x, stacked)
        return x

    def _embed(self, params, tokens):
        x = params["embed"][tokens]
        return shard(x.astype(self.cfg.dtype), "data", None, None)

    def _logits(self, params, x):
        out = x @ params["lm_head"]
        return shard(out, "data", None, "model")

    # -- training forward --------------------------------------------------
    def forward(self, params, batch):
        cfg = self.cfg
        if cfg.enc_dec:
            return self._forward_encdec(params, batch)
        tokens = batch["tokens"]
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x = self._embed(params, tokens)
        if "embeds" in batch:          # modality stub: prepend is implicit --
            x = x + batch["embeds"].astype(cfg.dtype)
        if cfg.family == "hybrid":
            def super_fn(p, h):
                h = _block_train(cfg, p["rec1"], h, pos, 0, "rec")
                h = _block_train(cfg, p["rec2"], h, pos, 0, "rec")
                return _block_train(cfg, p["attn"], h, pos, cfg.window, "attn")
            x = self._scan_train(params["super"], x, pos, super_fn)
            if self.n_tail:
                x = self._scan_train(
                    params["tail"], x, pos,
                    lambda p, h: _block_train(cfg, p, h, pos, 0, "rec"))
        else:
            kind = self._kind
            window = cfg.window if cfg.family == "hybrid" else 0
            x = self._scan_train(
                params["blocks"], x, pos,
                lambda p, h: _block_train(cfg, p, h, pos, window, kind))
        x = rms_norm(x, params["norm_f"])
        return self._logits(params, x)

    def encode(self, params, enc_embeds):
        """Encoder stack (enc-dec models): frame embeddings -> memory."""
        cfg = self.cfg
        enc_x = shard(enc_embeds.astype(cfg.dtype), "data", None, None)
        B, Se, _ = enc_x.shape
        enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None],
                                   (B, Se))

        def enc_fn(p, h):
            h = h + attention_train(p["attn"], cfg, rms_norm(h, p["ln1"]),
                                    enc_pos, causal=False)
            return h + ffn(p["ffn"], rms_norm(h, p["ln2"]))
        enc_out = self._scan_train(params["enc"], enc_x, enc_pos, enc_fn)
        return rms_norm(enc_out, params["enc_norm"])

    def _forward_encdec(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["enc_embeds"])
        B = enc_out.shape[0]
        tokens = batch["tokens"]
        S = tokens.shape[1]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x = self._embed(params, tokens)

        # cross K/V are recomputed per block inside the scan from enc_out
        def dec_fn(p, h):
            h = h + attention_train(p["attn"], cfg, rms_norm(h, p["ln1"]),
                                    pos, causal=True)
            from .attention import _project_qkv
            _, k, v = _project_qkv(p["xattn"], cfg, enc_out, None)
            h = h + attention_train(p["xattn"], cfg, rms_norm(h, p["ln_x"]),
                                    pos, causal=False, kv=(k, v))
            return h + ffn(p["ffn"], rms_norm(h, p["ln2"]))
        x = self._scan_train(params["dec"], x, pos, dec_fn)
        x = rms_norm(x, params["norm_f"])
        return self._logits(params, x)

    def loss(self, params, batch):
        logits = self.forward(params, batch)
        return softmax_cross_entropy(logits, batch["labels"])

    # -- serving ------------------------------------------------------------
    def init_cache(self, batch: int, s_max: int):
        cfg = self.cfg
        hd = cfg.hd if cfg.n_heads else 0

        def kv():
            shape = (batch, s_max, cfg.n_kv_heads, hd)
            return (jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))

        def stack_state(n, make):
            states = [make() for _ in range(n)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

        if cfg.family == "hybrid":
            win = min(cfg.window or s_max, s_max)
            cache = {"super": stack_state(self.n_super, lambda: {
                "rec1": init_rglru_state(cfg, batch),
                "rec2": init_rglru_state(cfg, batch),
                "attn": (jnp.zeros((batch, win, cfg.n_kv_heads, hd), cfg.dtype),
                         jnp.zeros((batch, win, cfg.n_kv_heads, hd), cfg.dtype)),
            })}
            if self.n_tail:
                cache["tail"] = stack_state(
                    self.n_tail, lambda: init_rglru_state(cfg, batch))
            return cache
        if cfg.family == "ssm":
            return {"blocks": stack_state(cfg.n_layers,
                                          lambda: init_ssd_state(cfg, batch))}
        if cfg.enc_dec:
            return {"dec": stack_state(cfg.n_layers, kv), "cross": None}
        return {"blocks": stack_state(cfg.n_layers, kv)}

    def decode_step(self, params, tokens, pos, cache, enc_out=None):
        """tokens: (B, 1) int32, pos: (B,) int32.  Returns (logits, cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens)

        def scan_decode(init, stacked_p, stacked_c, fn):
            if cfg.scan_unroll:
                n = jax.tree.leaves(stacked_p)[0].shape[0]
                h = init
                outs = []
                for i in range(n):
                    h, c2 = fn(jax.tree.map(lambda a: a[i], stacked_p), h,
                               jax.tree.map(lambda a: a[i], stacked_c))
                    outs.append(c2)
                return h, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

            def step(carry, pc):
                p, c = pc
                h, c2 = fn(p, carry, c)
                return h, c2
            return jax.lax.scan(step, init, (stacked_p, stacked_c))

        if cfg.family == "hybrid":
            def super_fn(p, h, c):
                h, c1 = _block_decode(cfg, p["rec1"], h, pos, c["rec1"], 0, "rec")
                h, c2 = _block_decode(cfg, p["rec2"], h, pos, c["rec2"], 0, "rec")
                h, c3 = _block_decode(cfg, p["attn"], h, pos, c["attn"],
                                      0, "attn", ring=True)
                return h, {"rec1": c1, "rec2": c2, "attn": c3}
            x, new_super = scan_decode(x, params["super"], cache["super"],
                                       super_fn)
            new_cache = {"super": new_super}
            if self.n_tail:
                x, new_tail = scan_decode(
                    x, params["tail"], cache["tail"],
                    lambda p, h, c: _block_decode(cfg, p, h, pos, c, 0, "rec"))
                new_cache["tail"] = new_tail
        elif cfg.enc_dec:
            def dec_fn(p, h, c):
                # order mirrors training: self-attn -> cross-attn -> FFN
                mix, c2 = _mixer_decode(cfg, p, h, pos, c, 0, "attn")
                h = h + mix
                from .attention import _project_qkv
                _, k, v = _project_qkv(p["xattn"], cfg, enc_out, None)
                h = h + attention_train(p["xattn"], cfg,
                                        rms_norm(h, p["ln_x"]), pos[:, None],
                                        causal=False, kv=(k, v))
                h = h + ffn(p["ffn"], rms_norm(h, p["ln2"]))
                return h, c2
            x, new_dec = scan_decode(x, params["dec"], cache["dec"], dec_fn)
            new_cache = {"dec": new_dec, "cross": None}
        else:
            kind = self._kind
            x, new_blocks = scan_decode(
                x, params["blocks"], cache["blocks"],
                lambda p, h, c: _block_decode(cfg, p, h, pos, c, 0, kind))
            new_cache = {"blocks": new_blocks}
        x = rms_norm(x, params["norm_f"])
        return self._logits(params, x), new_cache

    def prefill(self, params, batch):
        """Process a prompt; returns last-position logits.  (The dry-run
        lowers this as the prefill cell; cache construction for subsequent
        decode reuses forward's per-layer K/V via decode-step warmup in the
        serve example.)"""
        logits = self.forward(params, batch)
        return logits[:, -1:, :]
