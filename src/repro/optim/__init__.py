from .adamw import AdamWConfig, adamw_update, init_adamw  # noqa: F401
