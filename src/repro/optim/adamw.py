"""AdamW over parameter pytrees, with optional int8-quantized moments.

The int8 moment store is the paper's GH-packing idea transplanted to the
optimizer: quantize small values and pack them into narrow integers to cut
memory/bandwidth of a hot data structure.  Each moment tensor is stored as
(int8 q, fp32 per-tensor scale); dequantize-update-requantize per step.
At 400B params this saves ~2.4TB of optimizer HBM across a 512-chip job.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    quantize_moments: bool = False


def _q(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / 127.0
    return (jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8),
            scale.astype(jnp.float32))


def _dq(q, scale):
    return q.astype(jnp.float32) * scale


def init_adamw(params, cfg: AdamWConfig):
    def zero_like(p):
        if cfg.quantize_moments:
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "scale": jnp.zeros((), jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        if cfg.quantize_moments:
            m_f = _dq(m["q"], m["scale"])
            v_f = _dq(v["q"], v["scale"])
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        update = (m_f / c1) / (jnp.sqrt(v_f / c2) + cfg.eps)
        new_p = (p.astype(jnp.float32)
                 - cfg.lr * (update + cfg.weight_decay * p.astype(jnp.float32)))
        if cfg.quantize_moments:
            mq, ms = _q(m_f)
            vq, vs = _q(v_f)
            return new_p.astype(p.dtype), {"q": mq, "scale": ms}, \
                {"q": vq, "scale": vs}
        return new_p.astype(p.dtype), m_f, v_f

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}
