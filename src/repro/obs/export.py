"""Cross-party trace merge, Perfetto export, waterfall, ledger audit.

Each party records events against its OWN ``perf_counter_ns`` clock;
merging shifts every host event by a per-peer offset estimated NTP-style
from control round-trips: for a sample ``(t_send, peer_clock, t_recv)``
taken on the guest clock, ``offset = peer_clock - (t_send + t_recv)/2``;
among all samples (the ``trace_sync`` round-trip itself always provides
one; supervisor heartbeat acks add more) the MINIMUM-RTT sample wins —
its midpoint bounds the true offset tightest.  Guest events shift to
host clocks by subtracting, host events to the guest timeline likewise,
so the merged file has one timebase (the guest's).

The merged trace is *audited*, not decorative: every ``cat == "wire"``
instant carries the exact ``nbytes`` its ``Channel.send`` appended to
the per-tag ledger, so per party the wire-event byte sums must equal
that party's converged ledger totals (:func:`audit_wire_events`).
Transport-level framed spans use ``cat == "transport"`` and are
excluded — logical and physical views never double count.
"""

from __future__ import annotations

import json


def estimate_offset(samples) -> tuple:
    """``samples``: iterable of ``(t_send_ns, peer_clock_ns, t_recv_ns)``
    on the local clock.  Returns ``(offset_ns, rtt_ns)`` from the
    minimum-RTT sample, or ``(0, 0)`` with no samples (loopback parties
    share the process clock — zero offset is exact there)."""
    best = None
    for t0, peer, t1 in samples:
        rtt = t1 - t0
        off = peer - (t0 + t1) // 2
        if best is None or rtt < best[1]:
            best = (off, rtt)
    return best if best is not None else (0, 0)


def merge_traces(parties) -> list:
    """``parties``: list of dicts ``{party, pid, events, offset_ns}``
    (``offset_ns`` = party clock minus guest clock; 0 for the guest).
    Returns one flat, time-sorted list of normalized event dicts on the
    guest timeline:
    ``{party, pid, tid, ph, name, cat, ts_ns, dur_ns, attrs}``."""
    out = []
    for p in parties:
        off = int(p.get("offset_ns", 0))
        for ev in p["events"]:
            ph, name, cat, ts, dur, tid, attrs = ev
            out.append({"party": p["party"], "pid": int(p["pid"]),
                        "tid": int(tid), "ph": ph, "name": name,
                        "cat": cat, "ts_ns": int(ts) - off,
                        "dur_ns": int(dur), "attrs": dict(attrs or {})})
    out.sort(key=lambda e: e["ts_ns"])
    return out


def write_perfetto(path: str, merged: list, parties=None) -> None:
    """Write Chrome/Perfetto ``trace.json`` (``ui.perfetto.dev`` opens
    it directly).  ``ts``/``dur`` are microseconds."""
    events = []
    if parties:
        for p in parties:
            events.append({"ph": "M", "name": "process_name",
                           "pid": int(p["pid"]), "tid": 0,
                           "args": {"name": str(p["party"])}})
    for e in merged:
        ev = {"ph": e["ph"], "name": e["name"], "cat": e["cat"],
              "pid": e["pid"], "tid": e["tid"],
              "ts": e["ts_ns"] / 1e3, "args": e["attrs"]}
        if e["ph"] == "X":
            ev["dur"] = e["dur_ns"] / 1e3
        else:
            ev["s"] = "t"                   # thread-scoped instant
        events.append(ev)
    with open(path, "w") as f:
        json.dump({"traceEvents": events,
                   "displayTimeUnit": "ms"}, f)


def self_time(merged: list) -> dict:
    """Per-name self time (ns) over complete events: nested-interval
    attribution per (pid, tid) — a span's self time is its duration
    minus the durations of spans nested inside it."""
    by_track: dict = {}
    for e in merged:
        if e["ph"] == "X":
            by_track.setdefault((e["pid"], e["tid"]), []).append(e)
    out: dict = {}
    for track in by_track.values():
        track.sort(key=lambda e: (e["ts_ns"], -e["dur_ns"]))
        stack: list = []                    # (end_ns, name, [child_ns])
        for e in track:
            end = e["ts_ns"] + e["dur_ns"]
            while stack and stack[-1][0] <= e["ts_ns"]:
                done = stack.pop()
                out[done[1]] = out.get(done[1], 0) + done[3] - done[2][0]
            if stack:
                stack[-1][2][0] += e["dur_ns"]
            stack.append((end, e["name"], [0], e["dur_ns"]))
        while stack:
            done = stack.pop()
            out[done[1]] = out.get(done[1], 0) + done[3] - done[2][0]
    return out


def top_self_time(merged: list, k: int = 3) -> list:
    st = self_time(merged)
    top = sorted(st.items(), key=lambda kv: -kv[1])[:k]
    return [{"name": n, "self_ms": ns / 1e6} for n, ns in top]


def trace_summary(merged: list, dropped: int = 0, k: int = 3) -> dict:
    """Machine-readable digest for ``benchmarks/run.py --json``."""
    return {"events": len(merged), "dropped": int(dropped),
            "top_self_time": top_self_time(merged, k)}


def waterfall(merged: list) -> str:
    """Plain-text per-tree summary: for each ``tree`` attr seen on
    training spans, one line per (party, span name) with call count and
    total milliseconds, in first-seen order."""
    trees: dict = {}
    for e in merged:
        if e["ph"] != "X" or e["cat"] not in ("train", "serve"):
            continue
        t = e["attrs"].get("tree")
        if t is None:
            continue
        key = (e["party"], e["name"])
        agg = trees.setdefault(int(t), {})
        cnt, tot = agg.get(key, (0, 0))
        agg[key] = (cnt + 1, tot + e["dur_ns"])
    lines = []
    for t in sorted(trees):
        lines.append(f"tree {t}")
        for (party, name), (cnt, tot) in sorted(
                trees[t].items(), key=lambda kv: -kv[1][1]):
            lines.append(f"  {party:<8} {name:<16} x{cnt:<4} "
                         f"{tot / 1e6:9.3f} ms")
    return "\n".join(lines)


def wire_bytes_by_tag(events) -> dict:
    """Per-tag byte sums over one party's ``cat == "wire"`` events.
    Accepts raw tracer event tuples/lists or normalized dicts."""
    out: dict = {}
    for ev in events:
        if isinstance(ev, dict):
            cat, attrs = ev["cat"], ev["attrs"]
        else:
            cat, attrs = ev[2], ev[6]
        if cat != "wire":
            continue
        tag = attrs["tag"]
        out[tag] = out.get(tag, 0) + int(attrs["nbytes"])
    return out


def audit_wire_events(events, ledger_totals) -> dict:
    """Cross-check one party's wire events against its per-tag ledger
    totals.  Returns ``{tag: (traced_bytes, ledger_bytes)}`` for every
    mismatch — empty means the trace is exact.  Only meaningful on
    fault-free runs: ``Channel.restore`` rolls the ledger back but
    already-emitted events stay in the ring (DESIGN.md §14)."""
    traced = wire_bytes_by_tag(events)
    bad = {}
    for tag in set(traced) | {t for t, v in dict(ledger_totals).items() if v}:
        t, l = traced.get(tag, 0), int(dict(ledger_totals).get(tag, 0))
        if t != l:
            bad[tag] = (t, l)
    return bad
