"""Metrics registry: counter / gauge / histogram / series instruments.

The registry backs :class:`repro.core.party.Stats`: the timing fields
that used to be hand-threaded float/list dataclass fields now live here
as instruments, and ``Stats`` reads them back through generated
properties — so ``stats.encrypt_seconds += dt`` and
``stats.layer_overlap.append(x)`` keep working verbatim while new
instruments (per-tag RTT histograms, broker queue depth, retry counts)
register themselves on first touch.

Instruments are deliberately tiny:

* :class:`Counter` — monotone-ish accumulator (``add``; ``set`` exists
  so merge/rollback code can overwrite).  Merge semantics: add.
* :class:`Gauge`   — high-water mark (``observe`` keeps the max).
* :class:`Histogram` — count/sum/min/max under a lock (a compound
  update; the only instrument that needs one).
* :class:`Series`  — a plain list exposed as ``.data`` so existing
  ``append`` / ``extend`` / ``del lst[t:]`` call sites keep their exact
  behavior (including replay rollback).  Merge semantics: concat.

``snapshot()`` returns a codec-serializable nested dict for the
``status`` control tag and ``--json`` bench output.
"""

from __future__ import annotations

import math
import threading


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def add(self, v: float = 1.0) -> None:
        self.value += v

    def set(self, v: float) -> None:
        self.value = float(v)


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def observe(self, v: float) -> None:
        if v > self.value:
            self.value = float(v)

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    __slots__ = ("count", "total", "min", "max", "_lock")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def snapshot(self) -> dict:
        with self._lock:
            if not self.count:
                return {"count": 0, "sum": 0.0}
            return {"count": self.count, "sum": self.total,
                    "min": self.min, "max": self.max,
                    "mean": self.total / self.count}


class Series:
    __slots__ = ("data",)

    def __init__(self):
        self.data: list = []


class MetricsRegistry:
    """Get-or-create instrument registry, thread-safe on creation."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._gauges: dict = {}
        self._histograms: dict = {}
        self._series: dict = {}

    def _get(self, table: dict, name: str, cls):
        inst = table.get(name)
        if inst is None:
            with self._lock:
                inst = table.setdefault(name, cls())
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms, name, Histogram)

    def series(self, name: str) -> Series:
        return self._get(self._series, name, Series)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": {k: g.value for k, g in self._gauges.items()},
                "histograms": {k: h.snapshot()
                               for k, h in self._histograms.items()},
                "series": {k: list(s.data) for k, s in self._series.items()},
            }

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            self._series.clear()
