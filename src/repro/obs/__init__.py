"""Observability layer: structured tracing, metrics, cross-party merge.

See DESIGN.md §14.  ``trace`` records, ``metrics`` aggregates,
``export`` merges per-party buffers onto one clock-aligned timeline,
writes Perfetto ``trace.json``, and audits wire events against the
per-tag byte ledger.
"""

from .metrics import Counter, Gauge, Histogram, MetricsRegistry, Series
from .trace import NULL_TRACER, Tracer, current, set_default
from .export import (audit_wire_events, estimate_offset, merge_traces,
                     self_time, top_self_time, trace_summary, waterfall,
                     wire_bytes_by_tag, write_perfetto)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Series",
    "NULL_TRACER", "Tracer", "current", "set_default",
    "audit_wire_events", "estimate_offset", "merge_traces", "self_time",
    "top_self_time", "trace_summary", "waterfall", "wire_bytes_by_tag",
    "write_perfetto",
]
