"""Structured tracing: bounded ring-buffer span/instant events (DESIGN.md §14).

A :class:`Tracer` records events into a bounded per-process ring buffer
(`collections.deque(maxlen=capacity)` — overflow drops the OLDEST events
and counts them, never blocks the hot path).  Timestamps come from
``time.perf_counter_ns()``: the same monotonic clock as
``time.perf_counter()``, so code that already measured a phase with
float ``perf_counter()`` deltas can re-emit the interval exactly via
:meth:`Tracer.complete` with ``int(t * 1e9)``.

Zero-cost-when-disabled is the design contract: every emission site is
guarded by ``tracer.enabled`` (one attribute load + bool test), and the
module-level :data:`NULL_TRACER` singleton answers ``span()`` with a
shared no-op context manager, so disabled tracing adds no allocation,
no lock, no clock read.

Event tuples are ``(ph, name, cat, ts_ns, dur_ns, tid, attrs)`` with
``ph`` the Chrome-trace phase ("X" complete, "i" instant).  ``attrs``
must stay codec-serializable (str/int/float/bool) — host buffers cross
the wire over the ``trace_sync`` control tag.

Categories in use: ``train`` (round/tree/layer/encrypt/...), ``wire``
(one instant per :meth:`Channel.send` ledger append — the audited
category), ``transport`` (framed ship/recv/broker/retry — physical, NOT
audited, so the two views never double count), ``chaos``, ``serve``.
"""

from __future__ import annotations

import threading
import time
from collections import deque


class _NullSpan:
    """Shared no-op context manager for disabled tracers."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "cat", "tid", "attrs", "start_ns")

    def __init__(self, tracer, name, cat, tid, attrs):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.tid = tid
        self.attrs = attrs

    def __enter__(self):
        self.start_ns = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.perf_counter_ns() - self.start_ns
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        self.tracer._emit("X", self.name, self.cat, self.start_ns, dur,
                          self.tid, self.attrs)
        return False


class Tracer:
    """Thread-safe bounded event recorder for one party/process."""

    def __init__(self, party: str = "proc", capacity: int = 1 << 16,
                 enabled: bool = True):
        self.party = party
        self.capacity = capacity
        self.enabled = enabled
        self._events: deque = deque(maxlen=capacity)
        self._emitted = 0
        self._lock = threading.Lock()

    # -- emission -------------------------------------------------------
    def _emit(self, ph, name, cat, ts_ns, dur_ns, tid, attrs):
        if tid is None:
            tid = threading.get_ident() & 0x7FFFFFFF
        with self._lock:
            self._emitted += 1
            self._events.append((ph, name, cat, ts_ns, dur_ns, tid, attrs))

    def span(self, name: str, cat: str = "train", tid=None, **attrs):
        """``with tracer.span("layer", tree=t, depth=d): ...``"""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, tid, attrs)

    def instant(self, name: str, cat: str = "train", tid=None, **attrs):
        if not self.enabled:
            return
        self._emit("i", name, cat, time.perf_counter_ns(), 0, tid, attrs)

    def complete(self, name: str, start_ns: int, dur_ns: int,
                 cat: str = "train", tid=None, **attrs):
        """Emit an already-measured interval (reuses existing
        ``perf_counter()`` floats: pass ``int(t0 * 1e9)``)."""
        if not self.enabled:
            return
        self._emit("X", name, cat, int(start_ns), max(int(dur_ns), 0),
                   tid, attrs)

    # -- introspection --------------------------------------------------
    @property
    def dropped(self) -> int:
        with self._lock:
            return self._emitted - len(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def export_events(self) -> list:
        """Codec-serializable snapshot: list of 7-element lists."""
        with self._lock:
            return [[ph, name, cat, ts, dur, tid, dict(attrs)]
                    for ph, name, cat, ts, dur, tid, attrs in self._events]

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._emitted = 0


NULL_TRACER = Tracer(party="null", capacity=1, enabled=False)

# Process-default tracer: emission sites with no Channel in reach (chaos
# endpoints wrap the transport BEFORE the channel exists, fault-layer
# events, benchmark harness).  Per-party attribution everywhere else
# rides on the explicit ``Channel.tracer`` attribute instead, so the
# loopback single-process mode still attributes guest vs host correctly.
_default: Tracer = NULL_TRACER


def set_default(tracer: Tracer) -> Tracer:
    global _default
    prev = _default
    _default = tracer if tracer is not None else NULL_TRACER
    return prev


def current() -> Tracer:
    return _default
