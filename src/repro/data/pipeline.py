"""Data pipelines: synthetic token streams (LM) and tabular generators
(GBDT), with a bounded-prefetch loader for straggler isolation.

The token stream is deterministic-per-step (seeded by step index) so a
restore-and-replay after a failure reproduces the exact batch sequence --
a requirement for bitwise-reproducible recovery."""

from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticTokens:
    """Deterministic synthetic LM batches: batch(step) is a pure function."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(0, self.vocab, (self.batch, self.seq + 1),
                            dtype=np.int32)
        # mildly learnable structure: next token correlates with current
        toks[:, 1:] = (toks[:, :-1] * 31 + toks[:, 1:] % 7) % self.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def synthetic_tabular(n: int, d: int, seed: int = 0, task: str = "binary",
                      n_classes: int = 2, sparsity: float = 0.0):
    """Synthetic vertical-federated tabular data with a nonlinear target."""
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    if sparsity:
        X[rng.random(X.shape) < sparsity] = 0.0
    w = rng.normal(0, 1, d)
    s = X @ w + 0.5 * (X[:, 0] * X[:, min(1, d - 1)]) \
        + 0.3 * rng.normal(0, 1, n)
    if task == "binary":
        y = (s > np.median(s)).astype(np.float64)
    else:
        qs = np.quantile(s, np.linspace(0, 1, n_classes + 1)[1:-1])
        y = np.digitize(s, qs).astype(np.float64)
    return X, y


class PrefetchLoader:
    """Bounded background prefetch; a slow source can never queue more than
    ``depth`` batches behind (skip-slow-shard straggler isolation)."""

    def __init__(self, fn, depth: int = 2, start_step: int = 0):
        self.fn = fn
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            batch = self.fn(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.5)
                    break
                except queue.Full:
                    continue

    def __call__(self, step: int) -> dict:
        return self.q.get()

    def stop(self):
        self._stop.set()
