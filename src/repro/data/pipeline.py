"""Data pipelines: synthetic token streams (LM) and tabular generators
(GBDT), with a bounded-prefetch loader for straggler isolation.

The token stream is deterministic-per-step (seeded by step index) so a
restore-and-replay after a failure reproduces the exact batch sequence --
a requirement for bitwise-reproducible recovery."""

from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticTokens:
    """Deterministic synthetic LM batches: batch(step) is a pure function."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(0, self.vocab, (self.batch, self.seq + 1),
                            dtype=np.int32)
        # mildly learnable structure: next token correlates with current
        toks[:, 1:] = (toks[:, :-1] * 31 + toks[:, 1:] % 7) % self.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def synthetic_tabular(n: int, d: int, seed: int = 0, task: str = "binary",
                      n_classes: int = 2, sparsity: float = 0.0):
    """Synthetic vertical-federated tabular data with a nonlinear target."""
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    if sparsity:
        X[rng.random(X.shape) < sparsity] = 0.0
    w = rng.normal(0, 1, d)
    s = X @ w + 0.5 * (X[:, 0] * X[:, min(1, d - 1)]) \
        + 0.3 * rng.normal(0, 1, n)
    if task == "binary":
        y = (s > np.median(s)).astype(np.float64)
    else:
        qs = np.quantile(s, np.linspace(0, 1, n_classes + 1)[1:-1])
        y = np.digitize(s, qs).astype(np.float64)
    return X, y


class PrefetchLoader:
    """Bounded background prefetch; a slow source can never queue more than
    ``depth`` batches behind (skip-slow-shard straggler isolation).  With
    ``n_steps`` set the producer stops after that many batches -- the
    finite mode ``RowBlocks`` uses for one lookahead pass over a chunked
    source."""

    def __init__(self, fn, depth: int = 2, start_step: int = 0,
                 n_steps: int | None = None):
        self.fn = fn
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._n_steps = n_steps
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            if self._n_steps is not None and self._step >= self._n_steps:
                return
            batch = self.fn(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.5)
                    break
                except queue.Full:
                    continue

    def __call__(self, step: int) -> dict:
        return self.q.get()

    def stop(self):
        self._stop.set()


class RowBlocks:
    """Chunked row source: the out-of-core data path's one abstraction.

    Wraps a pure ``fn(block_idx) -> (rows, n_features) float32`` and yields
    ``(start_row, X_block)`` in order; every consumer (streaming binning,
    chunked encrypt, block-wise histograms) sees the same fixed-size blocks
    so nothing upstream ever holds the full matrix.  ``from_array`` adapts
    an in-memory matrix (zero-copy views) for parity testing against the
    monolithic path.  Iteration optionally runs the source through a
    finite ``PrefetchLoader`` so block b+1 is generated/read while block b
    is being consumed.
    """

    def __init__(self, fn, n_rows: int, n_features: int, block: int,
                 prefetch: int = 0):
        if block <= 0:
            raise ValueError("block must be positive")
        self.fn = fn
        self.n_rows = int(n_rows)
        self.n_features = int(n_features)
        self.block = int(block)
        self.prefetch = int(prefetch)

    @property
    def n_blocks(self) -> int:
        return -(-self.n_rows // self.block)

    def block_rows(self, b: int) -> tuple:
        start = b * self.block
        return start, min(start + self.block, self.n_rows)

    @classmethod
    def from_array(cls, X: np.ndarray, block: int,
                   prefetch: int = 0) -> "RowBlocks":
        X = np.asarray(X)
        def fn(b):
            return X[b * block: (b + 1) * block]
        return cls(fn, X.shape[0], X.shape[1], block, prefetch=prefetch)

    def select_columns(self, lo: int, hi: int) -> "RowBlocks":
        """Column-slice view sharing this source's fn — how one generated
        stream splits into per-party feature ranges (vertical split)."""
        fn = self.fn
        def cut(b):
            return fn(b)[:, lo:hi]
        return RowBlocks(cut, self.n_rows, hi - lo, self.block,
                         prefetch=self.prefetch)

    def __iter__(self):
        if self.prefetch > 0 and self.n_blocks > 1:
            loader = PrefetchLoader(self.fn, depth=self.prefetch,
                                    n_steps=self.n_blocks)
            try:
                for b in range(self.n_blocks):
                    yield b * self.block, loader(b)
            finally:
                loader.stop()
        else:
            for b in range(self.n_blocks):
                yield b * self.block, self.fn(b)


_GEN_CHUNK = 8192   # synthetic row-generation granularity: fixed so the
                    # dataset is a pure function of (n, d, seed) no matter
                    # what block size the consumer picks


def synthetic_tabular_stream(n: int, d: int, block: int, seed: int = 0,
                             task: str = "binary", n_classes: int = 2,
                             sparsity: float = 0.0):
    """Out-of-core twin of ``synthetic_tabular``: returns ``(blocks, y)``
    where ``blocks`` is a ``RowBlocks`` whose fn regenerates its rows from
    seeded micro-chunks on every pass -- X is never materialized.  Rows
    are drawn in fixed ``_GEN_CHUNK``-sized chunks keyed by chunk index,
    so two streams over the same (n, d, seed) yield identical data even
    with different block sizes.  The label needs the global
    median/quantiles of the score, so one cheap O(n) float64 score vector
    is collected up front (the only full-length array this generator
    keeps)."""
    rng = np.random.default_rng((seed, 10007))
    w = rng.normal(0, 1, d)

    def chunk(ci):
        crng = np.random.default_rng((seed, ci))
        r = min(_GEN_CHUNK, n - ci * _GEN_CHUNK)
        Xc = crng.normal(0, 1, (r, d)).astype(np.float32)
        if sparsity:
            Xc[crng.random(Xc.shape) < sparsity] = 0.0
        return Xc

    def gen(b):
        lo = b * block
        hi = min(lo + block, n)
        parts = []
        for ci in range(lo // _GEN_CHUNK, (hi - 1) // _GEN_CHUNK + 1):
            Xc = chunk(ci)
            cs = ci * _GEN_CHUNK
            parts.append(Xc[max(lo - cs, 0): hi - cs])
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    blocks = RowBlocks(gen, n, d, block, prefetch=2)
    s = np.empty(n, np.float64)
    n_chunks = -(-n // _GEN_CHUNK)
    for ci in range(n_chunks):
        Xc = chunk(ci)
        erng = np.random.default_rng((seed, 20011, ci))
        start = ci * _GEN_CHUNK
        s[start:start + len(Xc)] = (
            Xc @ w + 0.5 * (Xc[:, 0] * Xc[:, min(1, d - 1)])
            + 0.3 * erng.normal(0, 1, len(Xc)))
    if task == "binary":
        y = (s > np.median(s)).astype(np.float64)
    else:
        qs = np.quantile(s, np.linspace(0, 1, n_classes + 1)[1:-1])
        y = np.digitize(s, qs).astype(np.float64)
    return blocks, y
