from .pipeline import PrefetchLoader, SyntheticTokens, synthetic_tabular  # noqa: F401
