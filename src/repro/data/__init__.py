from .pipeline import (PrefetchLoader, RowBlocks, SyntheticTokens,  # noqa: F401
                       synthetic_tabular, synthetic_tabular_stream)
