"""Sharding rule tables: parameter/batch/cache PartitionSpecs per mesh.

LM rules are keyed on the trailing path of each parameter leaf; stacked
layer axes (from scan-over-layers) get a leading None.  "data" expands to
("pod", "data") on the multi-pod mesh (DP across pods); "model" carries
TP/EP.  ZeRO-1: optimizer moments additionally shard their first replicated
axis over "data" when divisible.

GBDT rules (``GBDT_RULES`` / :func:`gbdt_specs`) are keyed by array name:
the SecureBoost+ frontier engine shards instances over "data" and the layer
histogram's node axis over "model" (DESIGN.md §5/§7).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# trailing-key -> spec for the *unstacked* param (layer axis prepended later)
_RULES = {
    ("embed",): ("model", None),
    ("lm_head",): (None, "model"),
    ("attn", "wq"): (None, "model"),
    ("attn", "wk"): (None, "model"),
    ("attn", "wv"): (None, "model"),
    ("attn", "wo"): ("model", None),
    ("xattn", "wq"): (None, "model"),
    ("xattn", "wk"): (None, "model"),
    ("xattn", "wv"): (None, "model"),
    ("xattn", "wo"): ("model", None),
    ("ffn", "wi"): (None, "model"),
    ("ffn", "wg"): (None, "model"),
    ("ffn", "wo"): ("model", None),
    ("shared", "wi"): (None, "model"),
    ("shared", "wg"): (None, "model"),
    ("shared", "wo"): ("model", None),
    ("moe", "router"): (None, None),
    ("moe", "wi"): ("model", None, None),      # EP: experts over model
    ("moe", "wg"): ("model", None, None),
    ("moe", "wo"): ("model", None, None),
    ("rec", "wx"): (None, "model"),
    ("rec", "wgate"): (None, "model"),
    ("rec", "wo"): ("model", None),
    ("rec", "wr"): (None, "model"),
    ("rec", "wi"): (None, "model"),
    ("rec", "lam"): ("model",),
    ("rec", "conv"): (None, "model"),
    ("ssd", "in_proj"): (None, None),          # small dims: replicate
    ("ssd", "out_proj"): (None, None),
}


def _data_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _match(path_keys):
    for k in range(2, 0, -1):
        rule = _RULES.get(tuple(path_keys[-k:]))
        if rule is not None:
            return rule
    return None


def _leaf_spec(path, leaf, mesh):
    keys = [getattr(p, "key", getattr(p, "name", None)) or str(p)
            for p in path]
    keys = [k for k in keys if isinstance(k, str)]
    rule = _match(keys)
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    if rule is None:
        return P(*([None] * ndim))
    rule = list(rule)
    # stacked layer axis (scan): param rank exceeds the rule rank
    while len(rule) < ndim:
        rule = [None] + rule
    rule = rule[:ndim]
    # drop axes that don't divide
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shape = leaf.shape
    fixed = []
    for dim, ax in zip(shape, rule):
        if ax is not None and dim % sizes.get(ax, 1) != 0:
            ax = None
        fixed.append(ax)
    return P(*fixed)


def param_specs(params, mesh):
    """PartitionSpec pytree for a parameter pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, mesh), params)


def param_shardings(params, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


def opt_specs(params, mesh, zero1: bool = True):
    """Moment specs: param spec + ZeRO-1 sharding of the first free axis
    over 'data' when divisible."""
    specs = param_specs(params, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dax = _data_axes(mesh)
    d_total = int(np.prod([sizes[a] for a in dax]))

    def widen(path, leaf):
        spec = _leaf_spec(path, leaf, mesh)
        if not zero1:
            return spec
        parts = list(spec)
        for i, (dim, ax) in enumerate(zip(leaf.shape, parts)):
            if ax is None and dim % d_total == 0:
                parts[i] = dax if len(dax) > 1 else dax[0]
                break
        return P(*parts)

    moments = jax.tree_util.tree_map_with_path(widen, params)
    return moments


def _fit(spec: P, shape, mesh) -> P:
    """Drop spec axes whose mesh extent doesn't divide the array dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([sizes.get(a, 1) for a in axes]))
        out.append(ax if dim % total == 0 else None)
    return P(*out)


def batch_specs(batch_shapes: dict, mesh) -> dict:
    """Tokens/labels/embeds: batch axis over data(+pod), rest replicated.
    Non-divisible batch dims (e.g. global_batch=1 long-context cells) fall
    back to replication."""
    dax = _data_axes(mesh)
    d = dax if len(dax) > 1 else dax[0]

    def spec(leaf):
        return _fit(P(*([d] + [None] * (len(leaf.shape) - 1))), leaf.shape,
                    mesh)
    return jax.tree.map(spec, batch_shapes)


# ---------------------------------------------------------------------------
# GBDT (SecureBoost+) rule table — DESIGN.md §5
# ---------------------------------------------------------------------------
# At-rest layouts for the frontier engine (core/frontier.py) and the launch
# cell (launch/gbdt_cell.py).  Instances shard over "data"; the *feature*
# axis of at-rest binned matrices carries the party boundary on "model"
# (cross-party cell), while the per-layer histogram batch shards its *node*
# axis over "model" — the node axis is the one that doubles with depth, so
# it is what the intra-party frontier dispatch block-shards.

GBDT_RULES = {
    "bins": ("data", "model"),        # (instance, feature) binned matrix
    "zero_mask": ("data", "model"),   # (instance, feature) sparse mask
    "gh_cts": ("data", None, None),   # (instance, slot, limb) GH ciphertexts
    "node_slot": ("data",),           # (instance,) frontier slot assignment
    "layer_hist": ("model", None, None, None, None),
    #                                  (node, feature, bin, slot, limb)
    "layer_counts": ("model", None, None),   # (node, feature, bin) plaintext
    # round-forest mode (forest_size=k): the slot assignment gains a member
    # (tree) axis — one column per bagged member tree — and the histogram
    # batch gains a leading member axis while its member-local node axis
    # keeps the "model" block-sharding of the layer variant.
    "forest_slot": ("data", None),    # (instance, member) frontier slots
    "forest_hist": (None, "model", None, None, None, None),
    #                          (member, node, feature, bin, slot, limb)
    # crypto endpoints (DESIGN.md §8): both are embarrassingly parallel over
    # rows, so the encrypt input's instance axis and the per-layer decrypt
    # stack's candidate axis shard over "data" with no collective.
    "enc_plain": ("data", None, None),      # (instance, slot, plain-limb)
    "split_infos": ("data", None, None),    # (candidate, slot, limb)
    # serving engine (DESIGN.md §9): decision bits travel transposed and
    # bit-packed — (node-column, instance-byte) — so the *byte* axis is the
    # instance axis and shards over "data"; the routing cursor is
    # (instance, tree).  Routing is embarrassingly parallel over rows: no
    # collective on either array.
    "serve_bits": (None, "data"),           # (node-column, packed inst byte)
    "serve_route": ("data", None),          # (instance, tree)
}


def data_pad(mesh, n: int) -> int:
    """Rows to append so an instance/candidate axis of extent ``n`` divides
    the mesh's data-axis extent (device_put of a sharded layout requires
    divisibility).  Pad rows are protocol-inert by construction: bins = -1,
    ciphertexts = 0, never assigned a frontier slot."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d = int(np.prod([sizes.get(a, 1) for a in _data_axes(mesh)]))
    return -n % d


def gbdt_specs(mesh) -> dict:
    """PartitionSpec per GBDT frontier-engine array (name -> P).

    "data" expands to ("pod", "data") on a multi-pod mesh, mirroring the LM
    rule table above."""
    dax = _data_axes(mesh)
    d = dax if len(dax) > 1 else dax[0]
    return {k: P(*[d if a == "data" else a for a in v])
            for k, v in GBDT_RULES.items()}


def gbdt_sharding(mesh, name: str, ndim: int | None = None,
                  replicate: tuple = ()):
    """NamedSharding for one GBDT array.

    ``ndim`` trims/pads the rule to the actual rank (e.g. a 2-D flattened
    ciphertext batch).  ``replicate`` drops named axes — the intra-party
    frontier dispatch replicates features over "model" (every node shard
    needs every local feature) while the at-rest cross-party layout keeps
    them sharded."""
    rule = list(GBDT_RULES[name])
    if ndim is not None:
        rule = (rule + [None] * ndim)[:ndim]
    dax = _data_axes(mesh)
    d = dax if len(dax) > 1 else dax[0]
    parts = [None if (a in replicate or a is None)
             else (d if a == "data" else a) for a in rule]
    return NamedSharding(mesh, P(*parts))


def cache_specs(cache, mesh):
    """KV caches: (L, B, S, kv, hd) -> batch over data, S over model
    (split-KV decode); recurrent states: batch over data only."""
    dax = _data_axes(mesh)
    d = dax if len(dax) > 1 else dax[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec(leaf):
        nd = leaf.ndim
        if nd == 5:          # stacked KV cache (L, B, S, kv, hd)
            s_ok = leaf.shape[2] % sizes.get("model", 1) == 0
            p = P(None, d, "model" if s_ok else None, None, None)
        elif nd >= 2:        # stacked recurrent state (L, B, ...)
            p = P(*([None, d] + [None] * (nd - 2)))
        else:
            p = P(*([None] * nd))
        return _fit(p, leaf.shape, mesh)
    return jax.tree.map(spec, cache)
