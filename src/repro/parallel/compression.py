"""Gradient compression for DP all-reduce: the paper's packing idea, ported.

SecureBoost+'s GH packing quantizes two small values and bit-packs them into
one machine word before the expensive transport (HE + network).  Here the
expensive transport is the data-parallel gradient all-reduce across pods;
we quantize gradients to int8 with a per-tensor scale and psum the int8
payload (4x fewer inter-pod bytes than f32, 2x fewer than bf16), carrying
quantization error forward with error feedback (Karimireddy et al. 2019) so
convergence is preserved.

Summing int8 across N replicas needs log2(N x 127) < 31 bits of headroom --
int32 accumulation is exact for any realistic replica count, the same
lazy-accumulate-then-renormalize trick as the ciphertext histograms.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_grads(grads, error_state=None):
    """Quantize a gradient pytree to (int8, scale); returns (payload,
    new_error_state).  Call INSIDE shard_map/pjit before the psum."""
    if error_state is None:
        error_state = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                   grads)

    def q(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-20) / 127.0
        qv = jnp.clip(jnp.round(g / scale), -127, 127)
        err = g - qv * scale
        return (qv.astype(jnp.int8), scale), err

    flat, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    pairs = [q(g, e) for g, e in zip(flat, flat_e)]
    payload = treedef.unflatten([p[0] for p in pairs])
    new_err = treedef.unflatten([p[1] for p in pairs])
    return payload, new_err


def allreduce_compressed(payload, axis_name: str):
    """psum int8 payloads in int32, psum scales, return mean f32 grads."""
    n = jax.lax.psum(1, axis_name)

    def one(pair):
        qv, scale = pair
        total = jax.lax.psum(qv.astype(jnp.int32), axis_name)
        # per-replica scales differ; use the psum-mean scale (unbiased for
        # near-equal magnitudes, bounded error otherwise -- error feedback
        # absorbs the residual)
        mean_scale = jax.lax.psum(scale, axis_name) / n
        return total.astype(jnp.float32) * mean_scale / n

    return jax.tree.map(one, payload,
                        is_leaf=lambda x: isinstance(x, tuple))


def decompress(payload):
    return jax.tree.map(lambda p: p[0].astype(jnp.float32) * p[1], payload,
                        is_leaf=lambda x: isinstance(x, tuple))
