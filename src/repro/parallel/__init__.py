from .sharding import (batch_specs, cache_specs, opt_specs, param_shardings,  # noqa: F401
                       param_specs)
