"""Secret-taint pass: declared sources → declared sinks, minus sanitizers.

Flow-insensitive intra-procedural label propagation with per-function
summaries iterated to a global fixpoint, so flows THROUGH helpers are
seen (``f(g)`` where ``f`` forwards its argument to ``Channel.send``
is a finding at the call site of ``f``).

Labels are ``"secret"`` plus positional markers ``("p", i)``; a
function's summary records which params reach its return value, whether
the return is secret outright, and which params reach a sink
(transitively).  Callee resolution is by last name segment against
every function in the tree — several same-named candidates union their
summaries, which over-approximates but never misses a registered flow.

Precision decisions (documented, deliberate):

* Secret sources are SCOPED: a parameter named ``h`` is a hessian in
  ``core/*`` and a host handle in ``runtime/*`` — only the modules
  declared in ``registry.TAINT_SOURCES`` seed those names.
* Attribute reads taint only via declared attr names (``self.g``,
  ``._lam``); object taint does not bleed through arbitrary attribute
  access (``ctx.channel`` is not secret because ``ctx`` holds ``g``).
* Calls to unknown functions propagate the union of their argument
  labels (``jnp.exp(g)`` stays secret); a tiny allowlist of
  size/predicate builtins (``len``, ``int``, …) returns clean so row
  counts in payload dicts don't flag.
"""

from __future__ import annotations

import ast

from . import astutil, registry
from .report import Finding

_CLEAN_BUILTINS = frozenset({
    "len", "int", "bool", "str", "range", "isinstance", "hasattr",
    "id", "repr", "type",
})

SECRET = "secret"


class _Summary:
    __slots__ = ("params", "returns_secret", "param_to_return",
                 "param_to_sink")

    def __init__(self, params):
        self.params = params                # ordered param names
        self.returns_secret = False
        self.param_to_return = set()        # indices
        self.param_to_sink = {}             # index -> sink callee name

    def snapshot(self):
        return (self.returns_secret, frozenset(self.param_to_return),
                frozenset(self.param_to_sink))


def _param_names(node) -> list:
    a = node.args
    names = [p.arg for p in (*a.posonlyargs, *a.args)]
    # *args / keyword-only / **kwargs are not position-addressable in our
    # summaries; taint through them falls back to the unknown-call rule.
    return names


def _source_scope(relpath: str):
    """(params, attrs) seeded secret in this module, or (∅, ∅)."""
    params, attrs = set(), set()
    for grp in registry.TAINT_SOURCES:
        if relpath in grp["modules"]:
            params |= set(grp["params"])
            attrs |= set(grp["attrs"])
    return params, attrs


class TaintPass:
    def __init__(self, modules):
        self.modules = modules
        self.funcs = []                     # astutil.Func for every def
        self.by_name = {}                   # last-name -> [Func]
        self.sanitizers = set(registry.SANITIZER_NAMES)
        self.sinks = {s["name"]: s for s in registry.TAINT_SINKS}
        self.summaries = {}                 # id(node) -> _Summary
        self.findings = []
        self._seen = set()

        for mod in modules:
            astutil.link_parents(mod.tree)
            for fn in astutil.index_funcs(mod):
                self.funcs.append(fn)
                self.by_name.setdefault(fn.node.name, []).append(fn)
                if "declassifies" in astutil.decorator_names(fn.node):
                    self.sanitizers.add(fn.node.name)
                self.summaries[id(fn.node)] = _Summary(_param_names(fn.node))

    # -- driving ------------------------------------------------------------

    def run(self) -> list:
        changed = True
        rounds = 0
        while changed and rounds < 32:      # summaries grow monotonically
            changed = False
            rounds += 1
            for fn in self.funcs:
                before = self.summaries[id(fn.node)].snapshot()
                self._analyze(fn, emit=False)
                if self.summaries[id(fn.node)].snapshot() != before:
                    changed = True
        for fn in self.funcs:               # final pass: emit findings
            self._analyze(fn, emit=True)
        return self.findings

    # -- per-function analysis ---------------------------------------------

    def _analyze(self, fn, emit: bool) -> None:
        mod = fn.module
        src_params, src_attrs = _source_scope(mod.relpath)
        summ = self.summaries[id(fn.node)]
        env = {}                            # var name -> set of labels
        for i, p in enumerate(summ.params):
            labels = {("p", i)}
            if p in src_params:
                labels.add(SECRET)
            env[p] = labels

        def expr_labels(node) -> set:
            if node is None:
                return set()
            if isinstance(node, ast.Name):
                return set(env.get(node.id, ()))
            if isinstance(node, ast.Attribute):
                if node.attr in registry.SECRET_KEY_ATTRS:
                    return {SECRET}
                if node.attr in src_attrs and isinstance(node.value, ast.Name):
                    # self.g / ctx.h style reads in a source-scoped module
                    return {SECRET}
                return set()
            if isinstance(node, ast.Call):
                return call_labels(node)
            if isinstance(node, ast.Constant):
                return set()
            # generic fallback: union over child expressions (covers
            # BinOp, Subscript, Dict, List, comprehensions, IfExp, ...)
            out = set()
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.expr):
                    out |= expr_labels(child)
                elif isinstance(child, (ast.comprehension, ast.keyword)):
                    for sub in ast.iter_child_nodes(child):
                        if isinstance(sub, ast.expr):
                            out |= expr_labels(sub)
            return out

        def arg_exprs(call: ast.Call):
            """Positional view of a call's args: (index, expr) plus
            keyword name map."""
            pos = list(enumerate(call.args))
            kw = {k.arg: k.value for k in call.keywords if k.arg}
            return pos, kw

        def call_labels(call: ast.Call) -> set:
            name = astutil.callee_name(call)
            if name is None:
                out = set()
                for a in call.args:
                    out |= expr_labels(a)
                return out
            check_sink(call, name)
            if name in self.sanitizers:
                return set()
            if name in _CLEAN_BUILTINS and isinstance(call.func, ast.Name):
                return set()
            cands = self.by_name.get(name)
            if not cands:
                out = set()                 # unknown: propagate arg taint
                for a in call.args:
                    out |= expr_labels(a)
                for k in call.keywords:
                    out |= expr_labels(k.value)
                return out
            pos, kw = arg_exprs(call)
            out = set()
            bound = isinstance(call.func, ast.Attribute)
            for cand in cands:
                cs = self.summaries[id(cand.node)]
                off = 1 if (bound and cand.cls is not None
                            and cs.params[:1] == ["self"]) else 0
                if cs.returns_secret:
                    out.add(SECRET)
                for i in cs.param_to_return:
                    lab = labels_for_param(cs, i - off, pos, kw)
                    out |= lab
                # transitive param→sink: emit at THIS call site
                for i, sink_name in cs.param_to_sink.items():
                    lab = labels_for_param(cs, i - off, pos, kw)
                    note_sink_hit(call, sink_name, lab,
                                  pos[i - off][1] if 0 <= i - off < len(pos)
                                  else call)
            return out

        def labels_for_param(cs, j, pos, kw) -> set:
            if 0 <= j < len(pos):
                return expr_labels(pos[j][1])
            if 0 <= j < len(cs.params) and cs.params[j] in kw:
                return expr_labels(kw[cs.params[j]])
            return set()

        def note_sink_hit(call, sink_name, labels, payload_expr):
            if SECRET in labels:
                report(call, sink_name, payload_expr)
            for lab in labels:
                if isinstance(lab, tuple):
                    summ.param_to_sink.setdefault(lab[1], sink_name)

        def check_sink(call: ast.Call, name: str) -> None:
            sink = self.sinks.get(name)
            if sink is None:
                return
            pos, kw = arg_exprs(call)
            payload = None
            if sink["kwarg"] in kw:
                payload = kw[sink["kwarg"]]
            elif sink["arg"] < len(pos):
                payload = pos[sink["arg"]][1]
            if payload is None:
                return
            note_sink_hit(call, name, expr_labels(payload), payload)

        def report(call, sink_name, payload_expr) -> None:
            if not emit:
                return
            try:
                desc = ast.unparse(payload_expr)[:60]
            except Exception:
                desc = "<payload>"
            f = Finding("taint", mod.relpath, fn.qualname,
                        "unsanitized-flow",
                        f"secret '{desc}' reaches sink {sink_name}()",
                        getattr(call, "lineno", 0))
            if f.fingerprint not in self._seen:
                self._seen.add(f.fingerprint)
                self.findings.append(f)

        def bind(target, labels) -> None:
            if isinstance(target, ast.Name):
                env[target.id] = env.get(target.id, set()) | labels
            elif isinstance(target, (ast.Tuple, ast.List)):
                for t in target.elts:
                    bind(t, labels)
            elif isinstance(target, ast.Starred):
                bind(target.value, labels)
            # attribute/subscript targets: not tracked (declared attrs
            # are seeded on READ; everything else is out of scope)

        # flow-insensitive: sweep statements until the env stops growing
        body = list(ast.walk(fn.node))
        # exclude nested defs — they are analyzed as their own functions
        nested = set()
        for n in body:
            if (isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and n is not fn.node):
                for sub in ast.walk(n):
                    nested.add(id(sub))
                nested.discard(id(n))
        stmts = [n for n in body if id(n) not in nested]

        for _ in range(8):
            size = sum(len(v) for v in env.values())
            for n in stmts:
                if isinstance(n, ast.Assign):
                    lab = expr_labels(n.value)
                    for t in n.targets:
                        bind(t, lab)
                elif isinstance(n, ast.AnnAssign) and n.value is not None:
                    bind(n.target, expr_labels(n.value))
                elif isinstance(n, ast.AugAssign):
                    bind(n.target, expr_labels(n.value))
                elif isinstance(n, (ast.For, ast.AsyncFor)):
                    bind(n.target, expr_labels(n.iter))
                elif isinstance(n, ast.withitem) and n.optional_vars:
                    bind(n.optional_vars, expr_labels(n.context_expr))
                elif isinstance(n, ast.NamedExpr):
                    bind(n.target, expr_labels(n.value))
            if sum(len(v) for v in env.values()) == size:
                break

        # one evaluation sweep over every expression statement/call so
        # sink checks fire even outside assignments
        for n in stmts:
            if isinstance(n, ast.Call):
                call_labels(n)

        # returns → summary
        for n in stmts:
            if isinstance(n, ast.Return) and n.value is not None:
                lab = expr_labels(n.value)
                if SECRET in lab:
                    summ.returns_secret = True
                for item in lab:
                    if isinstance(item, tuple):
                        summ.param_to_return.add(item[1])
        if fn.node.name in self.sanitizers:
            summ.returns_secret = False
            summ.param_to_return.clear()


def run(modules) -> list:
    return TaintPass(modules).run()
