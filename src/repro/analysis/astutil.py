"""Shared AST plumbing for the analysis passes.

Loads every module under a source root once, indexes functions/methods
by qualified name, and annotates each node with its parent (the stdlib
``ast`` has no parent links, and both the wire and lock passes need
"am I inside a ``with self._lock:`` body / which function am I in").
"""

from __future__ import annotations

import ast
import dataclasses
import os


@dataclasses.dataclass
class Module:
    relpath: str            # path relative to the source root, "/"-separated
    path: str               # absolute path
    tree: ast.Module
    source: str


@dataclasses.dataclass
class Func:
    module: Module
    qualname: str           # "Class.method" or "function"
    node: ast.AST           # FunctionDef | AsyncFunctionDef
    cls: str | None         # owning class name, if a method


def load_tree(root: str, skip_dirs: tuple = ("analysis",)) -> list[Module]:
    """Parse every ``*.py`` under ``root`` except ``skip_dirs`` (the
    analyzer does not analyze itself — its fixture-like registries would
    drown the report in false positives)."""
    mods = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d != "__pycache__"
                             and os.path.relpath(os.path.join(dirpath, d),
                                                 root).replace(os.sep, "/")
                             not in skip_dirs)
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, "r", encoding="utf-8") as f:
                src = f.read()
            mods.append(Module(rel, path, ast.parse(src, filename=path), src))
    return mods


def link_parents(tree: ast.Module) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._parent = node  # type: ignore[attr-defined]


def parents(node: ast.AST):
    """Yield ancestors, innermost first (requires :func:`link_parents`)."""
    cur = getattr(node, "_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_parent", None)


def enclosing_func(node: ast.AST) -> ast.AST | None:
    for p in parents(node):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return p
    return None


def enclosing_class(node: ast.AST) -> ast.ClassDef | None:
    for p in parents(node):
        if isinstance(p, ast.ClassDef):
            return p
    return None


def index_funcs(mod: Module) -> list[Func]:
    """Every function/method in the module, with Class.method qualnames.
    Nested functions get ``outer.<locals>.inner``-style names collapsed
    to ``outer.inner`` — precise enough for name-based resolution."""
    out = []

    def visit(node, prefix, cls):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                out.append(Func(mod, q, child, cls))
                visit(child, f"{q}.", cls)
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.", child.name)
            else:
                visit(child, prefix, cls)

    visit(mod.tree, "", None)
    return out


def callee_name(call: ast.Call) -> str | None:
    """Last name segment of the callee: ``a.b.send(...)`` → ``send``."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def decorator_names(node) -> set:
    """Bare decorator names, unwrapping one call level:
    ``@declassifies("...")`` → ``declassifies``."""
    names = set()
    for d in getattr(node, "decorator_list", ()):
        t = d.func if isinstance(d, ast.Call) else d
        if isinstance(t, ast.Attribute):
            names.add(t.attr)
        elif isinstance(t, ast.Name):
            names.add(t.id)
    return names


def const_str(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
