"""Declared privacy & concurrency contracts — the analyzer's registries.

This module is imported by PRODUCTION code (for :func:`declassifies` and
:data:`SECRET_FIELD_NAMES`) and therefore stays dependency-free: pure
data plus one decorator.  The passes in this package read these
declarations; changing a contract here is a reviewable privacy/
concurrency decision, not an analyzer implementation detail.
"""

from __future__ import annotations

# ---------------------------------------------------------------------------
# sanitizers: the @declassifies decorator
# ---------------------------------------------------------------------------

def declassifies(reason: str):
    """Declare a function a *sanitizer*: its result is no longer secret.

    The taint pass treats any call to a decorated function as cutting the
    source→sink flow.  ``reason`` documents WHY the output is safe to
    disclose (encryption, an aggregate the protocol reveals by design,
    one-bit packing) — it is the written form of the privacy argument
    SecureBoost+ makes in prose.
    """
    def deco(fn):
        fn.__declassifies__ = reason
        return fn
    return deco


# Name-based backstop for the decorator (the pass detects ``@declassifies``
# syntactically, but resolution is by callee name; keeping the declared
# sanitizer names here makes the contract greppable and covers call sites
# that resolve to several same-named methods across cipher classes).
SANITIZER_NAMES = frozenset({
    "encrypt_batch",        # kernels/modmul/ops.py — Pallas limb encrypt
    "encrypt_limbs",        # core/he/affine.py — device-batch encrypt
    "encrypt_ints",         # core/he/paillier.py, affine.py — oracle encrypt
    "find_best_split",      # core/split.py — the split decision the
                            # protocol reveals to every party by design
    "leaf_weight",          # core/split.py — aggregate leaf statistic;
                            # part of the disclosed model
    "_packed_bits",         # serving/engine.py — one comparison bit per
                            # (row, node); the serving protocol's unit of
                            # disclosure
    "packed_from_X",        # serving/engine.py — PartyBits wrapper over
                            # _packed_bits
})


# ---------------------------------------------------------------------------
# secret sources
# ---------------------------------------------------------------------------

# Private-key material: attribute reads of these names are secret sources
# ANYWHERE in the tree (they are exactly what _strip_private_key deletes
# from a host-side cipher).
SECRET_KEY_ATTRS = frozenset({
    "_lam", "_mu",                      # Paillier private key
    "T_dec", "T_enc", "a_int", "a_inv_int",   # affine (symmetric) key
})

# Plaintext gradient/label tensors: parameter and ``self.<attr>`` names
# seeded as secret, scoped to the modules that actually carry them (a
# loop variable named ``h`` in serving code is a host handle, not a
# hessian — scoping keeps the pass meaningful).
TAINT_SOURCES = (
    {
        "modules": (
            "core/tree.py", "core/boosting.py", "core/party.py",
            "core/histogram.py", "core/frontier.py", "core/goss.py",
            "core/loss.py", "core/encoding.py", "core/mo_encoding.py",
            "core/split.py",
        ),
        "params": ("g", "h", "g_sel", "h_sel", "g_all", "h_all",
                   "y", "y_true", "labels"),
        "attrs": ("g", "h"),
    },
)

# Sinks: callee name -> 0-based positional index of the payload argument
# at a method call site (``obj.name(...)``), plus the keyword that names
# it.  Anything tainted reaching one of these without passing a
# sanitizer is a finding.
TAINT_SINKS = (
    {"name": "send", "arg": 3, "kwarg": "payload"},        # Channel.send
    {"name": "control_send", "arg": 2, "kwarg": "payload"},
    {"name": "deliver", "arg": 1, "kwarg": "payload"},     # in-process ship
    {"name": "_reply", "arg": 1, "kwarg": "payload"},      # HostRuntime
    {"name": "encode_payload", "arg": 0, "kwarg": "obj"},  # frame codec
    {"name": "encode_frame", "arg": 5, "kwarg": "payload"},
    {"name": "_write_party", "arg": 2, "kwarg": "arrays"}, # serving export
)


# ---------------------------------------------------------------------------
# export audit (the at-rest half of the boundary, serving/export.py)
# ---------------------------------------------------------------------------

# Field names that must never appear as an array or manifest key in ANY
# per-party export: plaintext gradients/labels and private-key material.
SECRET_FIELD_NAMES = frozenset({
    "g", "h", "g_sel", "h_sel", "y", "labels", "gh", "grad", "hess",
}) | SECRET_KEY_ATTRS


# ---------------------------------------------------------------------------
# wire pass: where dynamic (non-literal) tags are legitimate
# ---------------------------------------------------------------------------

# Generic forwarding plumbing: these functions take the tag as a
# parameter and pass it through; every literal tag they forward was
# already checked at THEIR call sites.
GENERIC_TAG_SITES = frozenset({
    "TransportChannel.send",        # super().send(src, dst, tag, ...)
    "TransportChannel._ingest",     # ledger mirror: Channel.send(self, ...)
    "TransportChannel.recv",        # broker pop(tag=tag)
    "TransportChannel.control_recv",
    "HostRuntime._reply",           # channel.send(..., tag, ...)
    "RemoteHostHandle.collect",     # channel.recv(peer, tag)
    "PartyProcess._handle",         # hr.deliver(tag, payload)
})

# Variable names treated as "the tag" in comparisons / dispatch tables.
TAG_VAR_NAMES = frozenset({"tag", "ftag", "until_ctrl"})


# ---------------------------------------------------------------------------
# lock-discipline contracts (the seven threaded modules)
# ---------------------------------------------------------------------------

# kind="lock":    guarded attrs may be touched only inside
#                 ``with self.<lock>:`` or from a declared method.
# kind="methods": guarded attrs may be touched only from the declared
#                 methods (ownership/join-ordering is the discipline).
# ``__init__`` is always exempt (construction precedes sharing).
LOCK_CONTRACTS = (
    # broker inbox: reader thread parks frames, protocol thread pops
    dict(module="runtime/transport.py", cls="_BrokerInbox", kind="lock",
         lock="cond", guarded=("inbox", "order", "err"), methods=()),
    # tx/rx byte mirrors: touched by send, broker and supervisor threads
    dict(module="runtime/transport.py", cls="TransportChannel", kind="lock",
         lock="_mirror_lock", guarded=("tx_bytes", "rx_bytes"), methods=()),
    # party runtime: every protocol mutation runs under _handle_lock
    # (serve loop vs. loopback encrypt-pump deliveries).  The declared
    # methods are the ones handle()/_control() call with the lock held;
    # resume_info/status run at quiesced points of the serve loop.
    dict(module="runtime/transport.py", cls="PartyProcess", kind="lock",
         lock="_handle_lock",
         guarded=("hr", "tables", "server", "cipher", "X_serve",
                  "_current_tree", "_complete", "_staged", "_tree_snaps",
                  "_tree_span", "_serve_k"),
         methods=("_handle", "_control", "_begin_tree", "_activate_tree",
                  "_build_runtime", "_complete_tree", "_serve_setup",
                  "_predict", "_persist_state", "_load_state", "status",
                  "resume_info")),
    # heartbeat supervisor: _last_ack is written by the recv-loop skim
    # hook and the supervisor thread only (GIL-atomic dict item writes)
    dict(module="runtime/transport.py", cls="MultiHostRun", kind="methods",
         guarded=("_last_ack",),
         methods=("_start_supervisor", "_skim_ctrl", "_supervise")),
    # encrypt pump: _err/_done_t are written by the worker and read only
    # after join() — join-ordering, owned by these two methods
    dict(module="core/tree.py", cls="_EncryptPump", kind="methods",
         guarded=("_err", "_done_t"), methods=("_run", "join")),
    # prefetch loader: _step is worker-thread-private
    dict(module="data/pipeline.py", cls="PrefetchLoader", kind="methods",
         guarded=("_step",), methods=("_run",)),
    # tracer ring
    dict(module="obs/trace.py", cls="Tracer", kind="lock", lock="_lock",
         guarded=("_events", "_emitted"), methods=()),
    # metrics registry + the one compound instrument
    dict(module="obs/metrics.py", cls="MetricsRegistry", kind="lock",
         lock="_lock",
         guarded=("_counters", "_gauges", "_histograms", "_series"),
         methods=()),
    dict(module="obs/metrics.py", cls="Histogram", kind="lock",
         lock="_lock", guarded=("count", "total", "min", "max"),
         methods=()),
)


# ---------------------------------------------------------------------------
# dtype-preservation lint: restore/codec paths
# ---------------------------------------------------------------------------

# Module path prefixes where ``asarray`` without an explicit ``dtype=``
# risks the float64→float32 canonicalization bug class (jax x64 off):
# checkpoint restore, the wire codec, and serving export/import.
DTYPE_LINT_PATHS = (
    "checkpoint/",
    "runtime/transport.py",
    "serving/export.py",
)
