"""CLI: run all analysis passes and diff against the baseline.

    python -m repro.analysis --json
    python -m repro.analysis --json --root src/repro
    python -m repro.analysis --update-baseline

Exit status 1 iff there are findings not covered by the baseline.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from . import astutil, dtype, locks, report, taint, wire

_PKG_DIR = os.path.dirname(os.path.abspath(__file__))
_DEFAULT_ROOT = os.path.dirname(_PKG_DIR)          # src/repro
_DEFAULT_BASELINE = os.path.join(_PKG_DIR, "baseline.json")

PASSES = (
    ("taint", taint.run),
    ("wire", wire.run),
    ("locks", locks.run),
    ("dtype", dtype.run),
)


def analyze(root: str) -> list:
    modules = astutil.load_tree(root)
    findings = []
    for _, run in PASSES:
        findings.extend(run(modules))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--root", default=_DEFAULT_ROOT,
                    help="source root to analyze (default: the repro "
                         "package)")
    ap.add_argument("--baseline", default=_DEFAULT_BASELINE,
                    help="baseline findings file")
    ap.add_argument("--json", action="store_true",
                    help="emit a machine-readable report on stdout")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to the current findings "
                         "and exit 0")
    args = ap.parse_args(argv)

    findings = analyze(args.root)

    if args.update_baseline:
        report.save_baseline(args.baseline, findings)
        print(f"baseline updated: {len(findings)} finding(s) -> "
              f"{args.baseline}", file=sys.stderr)
        return 0

    baseline = report.load_baseline(args.baseline)
    new, known, stale = report.diff_against_baseline(findings, baseline)

    if args.json:
        out = {
            "root": args.root,
            "summary": {
                "total": len(findings), "new": len(new),
                "baselined": len(known), "stale_baseline": len(stale),
            },
            "new": [f.to_dict() for f in new],
            "baselined": [f.to_dict() for f in known],
            "stale_baseline": stale,
        }
        json.dump(out, sys.stdout, indent=2)
        sys.stdout.write("\n")
    else:
        for f in new:
            print(f"NEW   {f}")
        for f in known:
            print(f"known {f}")
        for e in stale:
            print(f"stale [{e['pass']}/{e['rule']}] {e['module']} "
                  f"({e['qualname']}): {e['detail']}")

    if new:
        print(f"{len(new)} unbaselined finding(s); run with "
              f"--update-baseline only if each is an accepted, reviewed "
              f"exception.", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
