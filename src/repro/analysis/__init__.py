"""Privacy-boundary & protocol static analysis (DESIGN.md §15).

Three AST passes over ``src/repro`` plus one small lint, run by one CLI
(``python -m repro.analysis --json``):

* **secret-taint** (:mod:`.taint`): call-graph-aware flow analysis from
  declared secret sources (Paillier/affine private-key attributes,
  plaintext g/h tensors, raw labels) to declared sinks (``Channel.send``
  payloads, the frame codec, serving export writers).  Sanitizers —
  functions marked ``@declassifies`` (batch encryption, predict-bit
  packing, protocol-revealed aggregates) — cut the flow.
* **wire-schema** (:mod:`.wire`): every tag used at a send/recv/deliver
  site must resolve to the schema registry (:mod:`.schema`); dynamic tag
  forwarding is allowed only at declared generic plumbing sites.
* **lock-discipline** (:mod:`.locks`): declared guarded attributes of
  the threaded classes may only be touched under their owning lock (or
  from their declared owner methods).
* **dtype-preservation** (:mod:`.dtype`): ``asarray`` without an
  explicit ``dtype=`` on restore/codec paths (the float64→float32
  canonicalization bug class).

Findings diff against a checked-in baseline (``baseline.json`` next to
this package): CI fails only on *new* findings.

Only :mod:`.registry` (the contract declarations + ``declassifies``)
and :mod:`.schema` (the wire-tag registry + runtime conformance checks)
are imported by production code; the passes themselves are tooling.
"""

from .registry import declassifies  # noqa: F401  (re-export: the one
                                    # symbol production code decorates with)
