"""Dtype-preservation lint: ``asarray`` without ``dtype=`` on restore
and codec paths.

With jax's x64 mode off, ``jnp.asarray(x)`` silently canonicalizes
float64 → float32 — the PR 6 checkpoint-restore bug class.  On the
declared paths (``registry.DTYPE_LINT_PATHS``: checkpoint restore, the
wire codec, serving export) every ``asarray`` must pin its dtype, either
with an explicit ``dtype=`` keyword or a second positional argument.
Intentional canonicalization sites live in the baseline.
"""

from __future__ import annotations

import ast

from . import astutil, registry
from .report import Finding


def _on_lint_path(relpath: str) -> bool:
    for p in registry.DTYPE_LINT_PATHS:
        if relpath == p or (p.endswith("/") and relpath.startswith(p)):
            return True
    return False


def run(modules) -> list:
    findings, seen = [], set()
    for mod in modules:
        if not _on_lint_path(mod.relpath):
            continue
        astutil.link_parents(mod.tree)
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and astutil.callee_name(node) == "asarray"):
                continue
            if len(node.args) >= 2:        # positional dtype
                continue
            if any(k.arg == "dtype" for k in node.keywords):
                continue
            fn = astutil.enclosing_func(node)
            cls = astutil.enclosing_class(fn) if fn is not None else None
            qual = ("" if fn is None else
                    (f"{cls.name}.{fn.name}" if cls else fn.name))
            try:
                arg = ast.unparse(node.args[0])[:40] if node.args else "?"
            except Exception:
                arg = "?"
            f = Finding("dtype", mod.relpath, qual, "asarray-no-dtype",
                        f"asarray('{arg}') without explicit dtype on a "
                        f"restore/codec path", getattr(node, "lineno", 0))
            if f.fingerprint not in seen:
                seen.add(f.fingerprint)
                findings.append(f)
    return findings
