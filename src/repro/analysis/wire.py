"""Wire-schema pass: every tag at every send/recv site must resolve to
the registry in :mod:`.schema`.

Three site classes are checked:

* **call sites** — ``send``/``control_send``/``recv``/``deliver``/… with
  the tag at a known argument position (or keyword, for ``pop``/
  ``drain``).  A string literal must be registered; an UPPER-case
  constant must resolve to a registered tag through the schema module's
  namespace; anything else is a *dynamic* tag, legal only inside the
  declared generic-plumbing functions (``registry.GENERIC_TAG_SITES``).
* **comparisons** — ``tag == X`` / ``ftag in (X, Y)`` where the literal
  side must be registered (a typo'd tag in a dispatch condition is dead
  protocol code, which is exactly the bug class this catches).
* **dict dispatch** — ``{X: handler, ...}[tag]`` keys must be
  registered.
"""

from __future__ import annotations

import ast

from . import astutil, registry, schema
from .report import Finding

# callee name -> 0-based positional index of the tag argument
TAG_CALLS = {
    "send": 2, "control_send": 1, "recv": 1, "control_recv": 1,
    "deliver": 0, "collect": 0, "_reply": 0, "pending": 0,
}
# callee name -> keyword that names the tag
TAG_KWARGS = {"pop": "tag", "drain": "until_ctrl"}

# schema-module constant name -> tag string (only registered tags)
CONST_MAP = {
    name: val for name, val in vars(schema).items()
    if name.isupper() and isinstance(val, str) and val in schema.REGISTRY
}


def _const_ref(node: ast.AST) -> str | None:
    """UPPER-case constant reference name, if the node is one."""
    name = None
    if isinstance(node, ast.Attribute):
        name = node.attr
    elif isinstance(node, ast.Name):
        name = node.id
    if name and name.isupper():
        return name
    return None


class WirePass:
    def __init__(self, modules):
        self.modules = modules
        self.findings = []
        self._seen = set()

    def run(self) -> list:
        for mod in self.modules:
            astutil.link_parents(mod.tree)
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Call):
                    self._check_call(mod, node)
                elif isinstance(node, ast.Compare):
                    self._check_compare(mod, node)
                elif isinstance(node, ast.Subscript):
                    self._check_dispatch(mod, node)
        return self.findings

    # -- helpers ------------------------------------------------------------

    def _qual(self, node) -> str:
        fn = astutil.enclosing_func(node)
        if fn is None:
            return ""
        cls = astutil.enclosing_class(fn)
        return f"{cls.name}.{fn.name}" if cls else fn.name

    def _emit(self, mod, node, rule, detail) -> None:
        f = Finding("wire", mod.relpath, self._qual(node), rule, detail,
                    getattr(node, "lineno", 0))
        if f.fingerprint not in self._seen:
            self._seen.add(f.fingerprint)
            self.findings.append(f)

    def _check_tag_expr(self, mod, call, callee, tag_expr) -> None:
        lit = astutil.const_str(tag_expr)
        if lit is not None:
            if lit not in schema.REGISTRY:
                self._emit(mod, call, "unregistered-tag",
                           f"literal tag '{lit}' at {callee}() is not in "
                           f"the schema registry")
            return
        ref = _const_ref(tag_expr)
        if ref is not None:
            if ref not in CONST_MAP:
                self._emit(mod, call, "unknown-tag-constant",
                           f"constant {ref} at {callee}() does not resolve "
                           f"to a registered tag")
            return
        if self._qual(call) not in registry.GENERIC_TAG_SITES:
            self._emit(mod, call, "dynamic-tag",
                       f"non-literal tag at {callee}() outside declared "
                       f"generic plumbing")

    # -- site classes -------------------------------------------------------

    def _check_call(self, mod, call: ast.Call) -> None:
        name = astutil.callee_name(call)
        if name in TAG_CALLS:
            idx = TAG_CALLS[name]
            if idx < len(call.args):
                self._check_tag_expr(mod, call, name, call.args[idx])
        elif name in TAG_KWARGS:
            kw = TAG_KWARGS[name]
            for k in call.keywords:
                if k.arg != kw:
                    continue
                if isinstance(k.value, ast.Constant) and k.value.value is None:
                    break                   # tag=None means "any frame"
                self._check_tag_expr(mod, call, name, k.value)
                break

    def _is_tag_var(self, node) -> bool:
        return (isinstance(node, ast.Name)
                and node.id in registry.TAG_VAR_NAMES) or \
               (isinstance(node, ast.Attribute)
                and node.attr in registry.TAG_VAR_NAMES)

    def _check_literals(self, mod, node, side) -> None:
        exprs = side.elts if isinstance(side, (ast.Tuple, ast.List,
                                               ast.Set)) else [side]
        for e in exprs:
            lit = astutil.const_str(e)
            if lit is not None and lit not in schema.REGISTRY:
                self._emit(mod, node, "unregistered-tag",
                           f"tag compared against unregistered literal "
                           f"'{lit}'")
            else:
                ref = _const_ref(e)
                if ref is not None and ref not in CONST_MAP:
                    self._emit(mod, node, "unknown-tag-constant",
                               f"tag compared against unknown constant "
                               f"{ref}")

    def _check_compare(self, mod, node: ast.Compare) -> None:
        sides = [node.left, *node.comparators]
        if not any(self._is_tag_var(s) for s in sides):
            return
        for s in sides:
            if not self._is_tag_var(s):
                self._check_literals(mod, node, s)

    def _check_dispatch(self, mod, node: ast.Subscript) -> None:
        if not (isinstance(node.value, ast.Dict)
                and self._is_tag_var(node.slice)):
            return
        for key in node.value.keys:
            if key is None:
                continue
            lit = astutil.const_str(key)
            if lit is not None and lit not in schema.REGISTRY:
                self._emit(mod, node, "unregistered-tag",
                           f"dispatch table key '{lit}' is not a "
                           f"registered tag")
            else:
                ref = _const_ref(key)
                if ref is not None and ref not in CONST_MAP:
                    self._emit(mod, node, "unknown-tag-constant",
                               f"dispatch table key {ref} does not resolve "
                               f"to a registered tag")


def run(modules) -> list:
    return WirePass(modules).run()
