"""Findings, fingerprints, and the baseline diff.

A finding's *fingerprint* deliberately excludes line numbers: moving
code around must not churn the baseline.  It hashes
(pass, module relpath, enclosing qualname, rule, detail) — the same
leak reported twice on different lines of one function is one
fingerprint, which is the right granularity for "did a refactor
introduce a NEW leak".
"""

from __future__ import annotations

import dataclasses
import hashlib
import json


@dataclasses.dataclass
class Finding:
    pass_name: str          # "taint" | "wire" | "locks" | "dtype"
    module: str             # relpath under the source root
    qualname: str           # enclosing function/method ("" = module level)
    rule: str               # short machine id, e.g. "unsanitized-flow"
    detail: str             # stable human description (no line numbers!)
    line: int               # for navigation only; not fingerprinted

    @property
    def fingerprint(self) -> str:
        key = "\x1f".join((self.pass_name, self.module, self.qualname,
                           self.rule, self.detail))
        return hashlib.sha256(key.encode("utf-8")).hexdigest()[:16]

    def to_dict(self) -> dict:
        return {
            "pass": self.pass_name, "module": self.module,
            "qualname": self.qualname, "rule": self.rule,
            "detail": self.detail, "line": self.line,
            "fingerprint": self.fingerprint,
        }

    def __str__(self) -> str:
        where = f"{self.module}:{self.line}"
        if self.qualname:
            where += f" ({self.qualname})"
        return f"[{self.pass_name}/{self.rule}] {where}: {self.detail}"


def load_baseline(path: str) -> dict:
    try:
        with open(path, "r", encoding="utf-8") as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    return {e["fingerprint"]: e for e in data.get("findings", [])}


def save_baseline(path: str, findings: list) -> None:
    entries = sorted((f.to_dict() for f in findings),
                     key=lambda e: (e["pass"], e["module"], e["qualname"],
                                    e["rule"], e["detail"]))
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"findings": entries}, f, indent=2, sort_keys=True)
        f.write("\n")


def diff_against_baseline(findings: list, baseline: dict):
    """Return (new, known, stale): findings not in the baseline, findings
    covered by it, and baseline fingerprints no longer produced (fixed —
    candidates for ``--update-baseline``)."""
    produced = {f.fingerprint for f in findings}
    new = [f for f in findings if f.fingerprint not in baseline]
    known = [f for f in findings if f.fingerprint in baseline]
    stale = [e for fp, e in sorted(baseline.items()) if fp not in produced]
    return new, known, stale
