"""Wire-protocol schema registry: every tag, in one place.

Each frame the transport ships carries a tag; this module is the single
registry mapping tag → kind (protocol vs control), allowed direction,
and payload shape class.  Production protocol code
(``runtime/transport.py``, ``core/tree.py``, ``serving/engine.py``)
imports the tag CONSTANTS from here; the static wire pass
(:mod:`.wire`) verifies no call site uses an unregistered tag; and the
opt-in runtime conformance mode (:func:`validate`, enabled via
:func:`set_conformance` or ``REPRO_WIRE_CONFORMANCE=1``) validates
payloads at ship time.

This module must stay import-light (no numpy/jax): the transport
imports it on its hot path.
"""

from __future__ import annotations

import dataclasses
import os

# frame kinds — canonical here; runtime/transport.py re-exports them
KIND_PROTO = 0          # protocol message: enters the wire-byte ledger
KIND_CTRL = 1           # runtime control: real socket traffic, never
                        # ledger bytes


class WireSchemaError(ValueError):
    """A frame violates the registered schema (unregistered tag, wrong
    kind, wrong direction, or a payload of the wrong shape class)."""


# payload shape classes
P_NONE = "none"         # payload is None
P_STR = "str"           # a plain string (the error frame)
P_ARRAY = "array"       # a tensor (numpy/jax duck-typed)
P_DICT = "dict"         # a dict carrying at least the required keys
P_ANY = "any"           # unconstrained

# directions (src role -> dst role; every tag in this protocol is
# asymmetric — the guest orchestrates, hosts answer)
G2H = "g2h"
H2G = "h2g"


@dataclasses.dataclass(frozen=True)
class WireTag:
    tag: str
    kind: int
    direction: str
    payload: str
    requires: frozenset = frozenset()


# -- protocol tags (KIND_PROTO: ledger bytes) -------------------------------
ENC_GH = "enc_gh"               # encrypted g/h broadcast (tree boundary)
ASSIGN_SYNC = "assign_sync"     # one layer plan per host
SPLIT_INFOS = "split_infos"     # one candidate stack reply per host
CHOSEN_SID = "chosen_sid"       # the committed split id + instance space
ASSIGN_MASK = "assign_mask"     # host's go-left bitmask reply
PREDICT_REQ = "predict_req"     # serving: instance ids for one batch
PREDICT_BITS = "predict_bits"   # serving: packed decision bits reply

# -- control tags (KIND_CTRL: never ledger bytes) ---------------------------
HELLO = "hello"                 # host dial-in handshake
ERROR = "error"                 # a peer's dying words
SERVE_SETUP = "serve_setup"     # guest publishes bit-column key order
SERVE_READY = "serve_ready"     # host finished its export/reload
SERVE_DATA = "serve_data"       # out-of-band serving rows staging
RESET_STATS = "reset_stats"     # refit: fresh Stats + accounting
GET_STATS = "get_stats"         # stats request
STATS = "stats"                 # stats reply
STATUS = "status"               # live-introspection request
STATUS_REPLY = "status_reply"   # live-introspection reply
TRACE_SYNC = "trace_sync"       # trace collection round-trip (clock sync)
TRACE_DUMP = "trace_dump"       # trace ring reply
PING = "ping"                   # RTT probe
PONG = "pong"                   # RTT echo
HB = "hb"                       # supervisor heartbeat
HB_ACK = "hb_ack"               # heartbeat ack (skimmed, clock sample)
RESYNC = "resync"               # reconnect barrier request
RESYNC_ACK = "resync_ack"       # reconnect barrier ack
BYE = "bye"                     # orderly shutdown


def _t(tag: str, kind: int, direction: str, payload: str,
       requires: tuple = ()) -> WireTag:
    return WireTag(tag, kind, direction, payload, frozenset(requires))


REGISTRY: dict[str, WireTag] = {t.tag: t for t in (
    _t(ENC_GH, KIND_PROTO, G2H, P_DICT,
       ("tree", "seed", "forest", "codec", "cts")),
    _t(ASSIGN_SYNC, KIND_PROTO, G2H, P_DICT,
       ("tree", "node_of", "splittable", "modes")),
    _t(SPLIT_INFOS, KIND_PROTO, H2G, P_DICT,
       ("data", "sizes", "counts", "m")),
    _t(CHOSEN_SID, KIND_PROTO, G2H, P_DICT, ("nid", "sid", "rows")),
    _t(ASSIGN_MASK, KIND_PROTO, H2G, P_ARRAY),
    _t(PREDICT_REQ, KIND_PROTO, G2H, P_DICT, ("ids", "n_pad")),
    _t(PREDICT_BITS, KIND_PROTO, H2G, P_ARRAY),

    _t(HELLO, KIND_CTRL, H2G, P_DICT, ("hid", "run_id", "resume")),
    _t(ERROR, KIND_CTRL, H2G, P_STR),
    _t(SERVE_SETUP, KIND_CTRL, G2H, P_DICT, ("keys",)),
    _t(SERVE_READY, KIND_CTRL, H2G, P_DICT, ("k",)),
    _t(SERVE_DATA, KIND_CTRL, G2H, P_DICT, ("X",)),
    _t(RESET_STATS, KIND_CTRL, G2H, P_NONE),
    _t(GET_STATS, KIND_CTRL, G2H, P_NONE),
    _t(STATS, KIND_CTRL, H2G, P_DICT, ("stats", "ledger", "socket")),
    _t(STATUS, KIND_CTRL, G2H, P_NONE),
    _t(STATUS_REPLY, KIND_CTRL, H2G, P_DICT, ("hid", "stats")),
    _t(TRACE_SYNC, KIND_CTRL, G2H, P_DICT, ("clear",)),
    _t(TRACE_DUMP, KIND_CTRL, H2G, P_DICT,
       ("hid", "clock", "events", "dropped")),
    _t(PING, KIND_CTRL, G2H, P_DICT, ("t",)),
    _t(PONG, KIND_CTRL, H2G, P_DICT, ("t",)),       # echo of ping
    _t(HB, KIND_CTRL, G2H, P_DICT, ("t", "t_ns")),
    _t(HB_ACK, KIND_CTRL, H2G, P_DICT, ("clock",)),
    _t(RESYNC, KIND_CTRL, G2H, P_DICT, ("run",)),
    _t(RESYNC_ACK, KIND_CTRL, H2G, P_DICT, ("run",)),  # echo of resync
    _t(BYE, KIND_CTRL, G2H, P_NONE),
)}

PROTO_TAGS = frozenset(t for t, w in REGISTRY.items()
                       if w.kind == KIND_PROTO)
CTRL_TAGS = frozenset(t for t, w in REGISTRY.items()
                      if w.kind == KIND_CTRL)


# ---------------------------------------------------------------------------
# runtime conformance mode (opt-in; validated at ship time)
# ---------------------------------------------------------------------------

_conformance = bool(int(os.environ.get("REPRO_WIRE_CONFORMANCE", "0") or 0))


def set_conformance(on: bool) -> None:
    """Toggle ship-time payload validation (process-wide)."""
    global _conformance
    _conformance = bool(on)


def conformance_enabled() -> bool:
    return _conformance


def _role(party: str) -> str:
    if party == "guest":
        return "guest"
    if isinstance(party, str) and party.startswith("host"):
        return "host"
    return "?"


def validate(kind: int, src: str, dst: str, tag: str, payload) -> None:
    """Raise :class:`WireSchemaError` unless (kind, src→dst, payload)
    conforms to the registered schema for ``tag``.  Shape checks are
    shallow (type + required keys) by design: conformance mode must
    never perturb payload bytes or device placement."""
    spec = REGISTRY.get(tag)
    if spec is None:
        raise WireSchemaError(f"unregistered wire tag {tag!r}")
    if kind != spec.kind:
        raise WireSchemaError(
            f"{tag!r}: kind {kind} != registered "
            f"{'PROTO' if spec.kind == KIND_PROTO else 'CTRL'}")
    sr, dr = _role(src), _role(dst)
    want_src, want_dst = (("guest", "host") if spec.direction == G2H
                          else ("host", "guest"))
    # src may be unknown at control_send sites on simulation channels;
    # only flag a KNOWN role pointing the wrong way
    if (sr not in ("?", want_src)) or (dr not in ("?", want_dst)):
        raise WireSchemaError(
            f"{tag!r}: direction {src!r}->{dst!r} violates registered "
            f"{spec.direction}")
    p = spec.payload
    if p == P_NONE:
        if payload is not None:
            raise WireSchemaError(f"{tag!r}: payload must be None, got "
                                  f"{type(payload).__name__}")
    elif p == P_STR:
        if not isinstance(payload, str):
            raise WireSchemaError(f"{tag!r}: payload must be str, got "
                                  f"{type(payload).__name__}")
    elif p == P_ARRAY:
        if not (hasattr(payload, "__array__")
                or (hasattr(payload, "shape") and hasattr(payload, "dtype"))):
            raise WireSchemaError(f"{tag!r}: payload must be a tensor, "
                                  f"got {type(payload).__name__}")
    elif p == P_DICT:
        if not isinstance(payload, dict):
            raise WireSchemaError(f"{tag!r}: payload must be dict, got "
                                  f"{type(payload).__name__}")
        missing = spec.requires - payload.keys()
        if missing:
            raise WireSchemaError(
                f"{tag!r}: payload missing required keys "
                f"{sorted(missing)}")
