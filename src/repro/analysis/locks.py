"""Lock-discipline pass: guarded attributes only under their lock.

Contracts are declared in ``registry.LOCK_CONTRACTS``.  Two kinds:

* ``kind="lock"`` — every ``self.<attr>`` access (read or write) on a
  guarded attribute must sit inside a ``with self.<lock>:`` block or in
  one of the contract's declared methods (for classes whose public
  entry points take the lock once and fan out to private helpers).
* ``kind="methods"`` — the attribute is owned by the declared methods
  (thread-ownership / join-ordering discipline instead of a mutex).

``__init__`` is always exempt: construction precedes sharing.
"""

from __future__ import annotations

import ast

from . import astutil, registry
from .report import Finding


def _under_lock(node: ast.AST, lock: str) -> bool:
    for p in astutil.parents(node):
        if isinstance(p, (ast.With, ast.AsyncWith)):
            for item in p.items:
                ctx = item.context_expr
                if isinstance(ctx, ast.Attribute) and ctx.attr == lock \
                        and isinstance(ctx.value, ast.Name) \
                        and ctx.value.id == "self":
                    return True
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            break
    return False


def _find_class(mod, name: str):
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


class LockPass:
    def __init__(self, modules):
        self.by_rel = {m.relpath: m for m in modules}
        self.findings = []
        self._seen = set()

    def run(self) -> list:
        for c in registry.LOCK_CONTRACTS:
            mod = self.by_rel.get(c["module"])
            if mod is None:
                continue
            astutil.link_parents(mod.tree)
            cls = _find_class(mod, c["cls"])
            if cls is None:
                self._emit(mod, mod.tree, f'{c["cls"]}', "missing-class",
                           f"declared class {c['cls']} not found")
                continue
            self._check_class(mod, cls, c)
        return self.findings

    def _check_class(self, mod, cls: ast.ClassDef, c: dict) -> None:
        guarded = set(c["guarded"])
        methods = set(c.get("methods") or ())
        lock = c.get("lock")
        for node in ast.walk(cls):
            if not (isinstance(node, ast.Attribute)
                    and node.attr in guarded
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self"):
                continue
            fn = astutil.enclosing_func(node)
            if fn is None or fn.name == "__init__":
                continue
            owner = astutil.enclosing_class(fn)
            if owner is not cls:            # nested class: not ours
                continue
            qual = f"{cls.name}.{fn.name}"
            if fn.name in methods:
                continue
            if c["kind"] == "lock" and _under_lock(node, lock):
                continue
            mode = ("write" if isinstance(node.ctx, (ast.Store, ast.Del))
                    else "read")
            want = (f"'with self.{lock}'" if c["kind"] == "lock"
                    else "its declared owner methods")
            self._emit(mod, node, qual, "unlocked-access",
                       f"guarded attr '{node.attr}' {mode} outside {want}")

    def _emit(self, mod, node, qual, rule, detail) -> None:
        f = Finding("locks", mod.relpath, qual, rule, detail,
                    getattr(node, "lineno", 0))
        if f.fingerprint not in self._seen:
            self._seen.add(f.fingerprint)
            self.findings.append(f)


def run(modules) -> list:
    return LockPass(modules).run()
