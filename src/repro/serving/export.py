"""Per-party model export/import for the serving subsystem (DESIGN.md §9).

Layout mirrors the privacy boundary: one directory per party, each
self-contained (manifest.json + arrays.npz), written to a temp dir and
published with an atomic rename — the same crash-safety pattern as
``checkpoint/checkpoint.py``:

    <out_dir>/
      guest/   manifest.json  arrays.npz   (structure, leaf weights,
                                            guest splits, guest binning)
      host0/   manifest.json  arrays.npz   (host0 splits + binning ONLY)
      host1/   ...

A serving process loads only its own directory: ``load_guest`` /
``load_host`` rebuild the exact ``GuestHalf`` / ``HostHalf`` the packer
produced, and ``FederatedPredictor`` serves from them with no training
objects.  Manifests carry array shapes/dtypes so corruption fails loudly
(``ValueError``) instead of mis-serving.  No array in any manifest is
row-level: exported models carry zero training-set residue.
"""

from __future__ import annotations

import json
import os
import shutil

import numpy as np

from ..analysis.registry import SECRET_FIELD_NAMES
from .packed import GuestHalf, HostHalf, PackedEnsemble, PartySlice

FORMAT = "sbt-packed-serving"
VERSION = 1

_GUEST_ARRAYS = ("step", "roots", "tree_class", "leaf_w", "k_parties",
                 "fid", "bid", "thresholds")
_HOST_ARRAYS = ("fid", "bid", "thresholds")


def _manifest_keys(obj) -> set:
    keys = set()
    if isinstance(obj, dict):
        for k, v in obj.items():
            keys.add(k)
            keys |= _manifest_keys(v)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            keys |= _manifest_keys(v)
    return keys


def _audit_party(manifest: dict, arrays: dict) -> None:
    """At-rest half of the privacy boundary, checked at export time.

    A per-party export may carry ONLY its role's declared arrays — a
    guest half never ships host split content beyond its own slice, a
    host half never ships guest structure/leaf weights — and no field
    name anywhere (arrays or nested manifest keys) may collide with the
    declared secret registry (plaintext g/h, labels, private-key
    attributes).  This is the runtime twin of the static taint pass's
    ``_write_party`` sink."""
    role = manifest.get("role")
    allowed = {"guest": _GUEST_ARRAYS, "host": _HOST_ARRAYS}.get(role)
    if allowed is None:
        raise ValueError(f"export audit: unknown party role {role!r}")
    extra = set(arrays) - set(allowed)
    if extra:
        raise ValueError(f"export audit: {role} half carries undeclared "
                         f"arrays {sorted(extra)}")
    leaked = (set(arrays) | _manifest_keys(manifest)) & SECRET_FIELD_NAMES
    if leaked:
        raise ValueError(f"export audit: {role} half carries secret field "
                         f"name(s) {sorted(leaked)}")


def _write_party(party_dir: str, manifest: dict, arrays: dict) -> None:
    os.makedirs(party_dir, exist_ok=True)
    manifest = dict(manifest, format=FORMAT, version=VERSION,
                    arrays={k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                            for k, v in arrays.items()})
    _audit_party(manifest, arrays)
    np.savez_compressed(os.path.join(party_dir, "arrays.npz"), **arrays)
    with open(os.path.join(party_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def _guest_payload(g: GuestHalf) -> tuple:
    init = (g.init_score if np.isscalar(g.init_score)
            else np.asarray(g.init_score, np.float64).tolist())
    return ({"role": "guest", "objective": g.objective,
             "n_classes": g.n_classes, "n_bins": g.n_bins, "depth": g.depth,
             "n_trees": g.n_trees, "n_nodes": g.n_nodes,
             "n_hosts": g.n_hosts, "init_score": init},
            {"step": g.step, "roots": g.roots, "tree_class": g.tree_class,
             "leaf_w": g.leaf_w, "k_parties": g.k_parties,
             "fid": g.guest.fid, "bid": g.guest.bid,
             "thresholds": g.thresholds})


def _host_payload(h: HostHalf) -> tuple:
    return ({"role": "host", "hid": h.hid, "n_bins": h.n_bins,
             "k": h.table.k},
            {"fid": h.table.fid, "bid": h.table.bid,
             "thresholds": h.thresholds})


def _publish(tmp: str, out_dir: str) -> str:
    # publish by rename: the previous export (if any) is moved aside
    # BEFORE the new one lands and deleted only after — a crash at any
    # point leaves either the old or the new export recoverable on disk,
    # never neither
    stale = out_dir + ".stale-export"
    if os.path.exists(stale):
        shutil.rmtree(stale)
    if os.path.exists(out_dir):
        os.replace(out_dir, stale)
    os.replace(tmp, out_dir)                 # atomic publish
    if os.path.exists(stale):
        shutil.rmtree(stale)
    return out_dir


def export_guest(guest: GuestHalf, out_dir: str) -> str:
    """Atomically write ONE party directory: the guest half.  This is what
    the guest process publishes under the multi-host runtime — host halves
    are exported by their own processes (:func:`export_host`)."""
    out_dir = out_dir.rstrip("/")
    tmp = out_dir + ".tmp-export"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    _write_party(tmp, *_guest_payload(guest))
    return _publish(tmp, out_dir)


def export_host(host: HostHalf, out_dir: str) -> str:
    """Atomically write ONE party directory: a host half (its split table
    + binning thresholds only), from inside that host's process."""
    out_dir = out_dir.rstrip("/")
    tmp = out_dir + ".tmp-export"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    _write_party(tmp, *_host_payload(host))
    return _publish(tmp, out_dir)


def export_model(model_or_ensemble, out_dir: str) -> str:
    """Write per-party serving halves; returns ``out_dir``.

    Accepts a fitted ``VerticalBoosting`` (packed on the fly) or a
    ``PackedEnsemble``.  The whole export lands atomically: a partial
    write can never shadow a previous good export.
    """
    ens = (model_or_ensemble
           if isinstance(model_or_ensemble, PackedEnsemble)
           else PackedEnsemble.from_model(model_or_ensemble))
    out_dir = out_dir.rstrip("/")
    tmp = out_dir + ".tmp-export"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    _write_party(os.path.join(tmp, "guest"), *_guest_payload(ens.guest))
    for h in ens.hosts:
        _write_party(os.path.join(tmp, f"host{h.hid}"), *_host_payload(h))
    return _publish(tmp, out_dir)


def _read_party(party_dir: str, role: str, names: tuple) -> tuple:
    """Validated (manifest, arrays) for one party dir; ValueError on any
    corruption (bad JSON, wrong role/format, missing or mis-shaped
    arrays)."""
    mpath = os.path.join(party_dir, "manifest.json")
    try:
        with open(mpath) as f:
            man = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ValueError(f"corrupt serving manifest {mpath}: {e}") from e
    if man.get("format") != FORMAT:
        raise ValueError(f"{mpath}: not a {FORMAT} manifest "
                         f"(format={man.get('format')!r})")
    if man.get("role") != role:
        raise ValueError(f"{mpath}: role {man.get('role')!r}, "
                         f"expected {role!r}")
    meta = man.get("arrays")
    if not isinstance(meta, dict):
        raise ValueError(f"{mpath}: missing arrays metadata")
    apath = os.path.join(party_dir, "arrays.npz")
    try:
        z = np.load(apath)
    except Exception as e:   # truncated/corrupt zip, missing file, ...
        raise ValueError(f"corrupt serving arrays {apath}: {e}") from e
    with z:
        arrays = {}
        for name in names:
            if name not in meta or name not in z:
                raise ValueError(f"{mpath}: missing array {name!r}")
            arr = z[name]
            if list(arr.shape) != list(meta[name]["shape"]):
                raise ValueError(
                    f"{mpath}: array {name!r} shape {list(arr.shape)} != "
                    f"manifest {meta[name]['shape']}")
            if str(arr.dtype) != meta[name]["dtype"]:
                raise ValueError(
                    f"{mpath}: array {name!r} dtype {arr.dtype} != "
                    f"manifest {meta[name]['dtype']}")
            arrays[name] = arr
    return man, arrays


def load_guest(party_dir: str) -> GuestHalf:
    man, a = _read_party(party_dir, "guest", _GUEST_ARRAYS)
    try:
        init = man["init_score"]
        guest = GuestHalf(
            step=a["step"], roots=a["roots"], tree_class=a["tree_class"],
            leaf_w=a["leaf_w"], depth=int(man["depth"]),
            k_parties=a["k_parties"],
            guest=PartySlice(fid=a["fid"], bid=a["bid"]),
            thresholds=a["thresholds"], n_bins=int(man["n_bins"]),
            objective=man["objective"], n_classes=int(man["n_classes"]),
            init_score=(float(init) if man["objective"] == "binary"
                        else np.asarray(init, np.float64)))
    except KeyError as e:
        raise ValueError(f"corrupt guest manifest: missing {e}") from e
    if guest.n_trees != int(man["n_trees"]) \
            or guest.n_nodes != int(man["n_nodes"]):
        raise ValueError("guest manifest tree/node counts disagree with "
                         "arrays")
    return guest


def load_host(party_dir: str) -> HostHalf:
    man, a = _read_party(party_dir, "host", _HOST_ARRAYS)
    try:
        return HostHalf(hid=int(man["hid"]),
                        table=PartySlice(fid=a["fid"], bid=a["bid"]),
                        thresholds=a["thresholds"],
                        n_bins=int(man["n_bins"]))
    except KeyError as e:
        raise ValueError(f"corrupt host manifest: missing {e}") from e


def load_ensemble(out_dir: str) -> PackedEnsemble:
    """Load every party half back into a ``PackedEnsemble`` (simulation
    convenience; real deployments load one half per process)."""
    guest = load_guest(os.path.join(out_dir, "guest"))
    hosts = [load_host(os.path.join(out_dir, f"host{h}"))
             for h in range(guest.n_hosts)]
    return PackedEnsemble(guest=guest, hosts=hosts)
