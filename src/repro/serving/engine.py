"""Round-batched federated serving engine (DESIGN.md §9).

The training-time ``predict_tree`` loop walks nodes one at a time in
python, per tree, reading host tables directly — no batching, no protocol
accounting.  This engine serves a whole batch through ALL trees at once
with ONE wire round-trip per host per batch:

1. **Bits.**  Each party evaluates every internal node it owns for every
   instance in one vectorized binning+compare pass, *transposed and
   bit-packed*: ``bits[j, i//8]`` bit ``i%8`` says instance ``i`` goes left
   at node-column ``j``.  The packed uint8 tensor is simultaneously the
   wire payload (1 bit per node per instance — what the byte ledger
   counts) and the routing operand (the fused compare→packbits pass writes
   8x fewer bytes than a bool tensor, which is what makes the engine
   memory-bound-fast on CPU and TPU alike).
2. **Combine.**  The guest concatenates the per-party row blocks — packed
   node ids ARE bit-tensor rows (``serving/packed.py``), so no scatter.
3. **Route.**  A jitted layer-synchronous loop advances an (instance,
   tree) cursor ``depth`` times through the fused ``step[node, bit]``
   table; leaves self-loop.  Embarrassingly parallel over rows — with a
   mesh, the packed byte axis and the cursor row axis shard over "data"
   (rule-table entries ``serve_bits`` / ``serve_route``) with no
   collective.
4. **Accumulate.**  Leaf weights are gathered host-side in float64 and
   summed per tree in training order — bit-identical to the legacy
   ``predict_tree`` path by construction (routing is exact integer work;
   the float adds replay the same sequence).

Wire accounting uses the existing :class:`Channel`/:class:`Stats`
plumbing: ``predict_req`` (guest -> host, instance ids) and
``predict_bits`` (host -> guest, the packed bit block) per host per batch,
``Stats.n_predict_roundtrips`` counting the latter.
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis import schema as wire
from ..analysis.registry import declassifies
from ..core.binning import BinnedData, apply_binning
from ..core.party import Channel, PartyUnavailable, Stats


@declassifies("one comparison bit per (row, owned node): the serving "
              "protocol's unit of disclosure — never raw feature values")
@jax.jit
def _packed_bits(bins_T, fid, bid):
    """All of one party's decision bits in one fused pass.

    ``bins_T`` (n_f, n_pad) int32 — transposed *on the host* so the
    device sees a contiguous layout whose gathered rows are sequential
    sweeps (transposing inside the jit measured ~2x slower on CPU: XLA
    materializes the transposed gather poorly, while the numpy
    transpose+pad of a cache-resident (n, n_f) block is near-memcpy).
    ``fid``/``bid`` (k,) — the party's split table in bit-column order.
    Returns (k, n_pad // 8) uint8, bitorder little.
    """
    ge = jnp.take(bins_T, fid, axis=0) <= bid[:, None]
    return jnp.packbits(ge, axis=1, bitorder="little")


@partial(jax.jit, static_argnames="depth")
def _route(bits, step, node, depth: int):
    """Layer-synchronous traversal: advance the (instance, tree) cursor
    ``depth`` times.  ``bits`` (k, n_pad/8) uint8; ``step`` (n_nodes, 2)
    int32 with leaves self-looping, so no leaf test is needed and leaf
    cursor entries read a clamped, ignored bit."""
    rows = jnp.arange(node.shape[0], dtype=jnp.int32)[:, None]
    byte_ix = rows >> 3
    shift = (rows & 7).astype(jnp.uint8)
    kmax = max(bits.shape[0] - 1, 0)

    def body(_, node):
        b = (bits[jnp.minimum(node, kmax), byte_ix] >> shift) & 1
        return step[node, b.astype(jnp.int32)]

    return jax.lax.fori_loop(0, depth, body, node)


class PartyBits:
    """One party's serving evaluator: bins its OWN features and computes
    its decision-bit block (the fused compare→packbits pass).

    This is exactly the computation a host's ``PartyProcess`` runs on its
    side of the socket under ``runtime/transport.py`` — in-process serving
    calls the same object directly, so the two modes are bit-identical by
    construction."""

    def __init__(self, table, thresholds, n_bins: int, use_pallas: bool):
        self.table = table
        self.use_pallas = use_pallas
        # binner view: reuses the BinnedData device-threshold cache
        self.binner = BinnedData(
            bins=np.zeros((0, thresholds.shape[0]), np.int32),
            thresholds=thresholds, n_bins=n_bins)
        self._fid = jnp.asarray(table.fid)
        self._bid = jnp.asarray(table.bid)

    def bin(self, X) -> np.ndarray:
        return apply_binning(X, self.binner, self.use_pallas)

    def packed(self, bins: np.ndarray, n_pad: int):
        """(k, n_pad // 8) uint8 decision bits for one binned batch."""
        bins_T = np.zeros((bins.shape[1], n_pad), np.int32)
        bins_T[:, : bins.shape[0]] = bins.T
        return _packed_bits(jnp.asarray(bins_T), self._fid, self._bid)

    @declassifies("wrapper over _packed_bits: bins then packs to the "
                  "one-bit-per-node disclosure unit")
    def packed_from_X(self, X, n_pad: int):
        return self.packed(self.bin(X), n_pad)


class FederatedPredictor:
    """Serves batched predictions from packed per-party halves.

    Works from a live ``VerticalBoosting`` (see
    ``VerticalBoosting.predict_score``), from halves reloaded by
    ``serving/export.py`` — a serving process never needs the training
    objects — or from ``RemoteServingHost`` handles whose half lives in
    another OS process (``runtime/transport.py``).  All cross-party
    transfers go through ``channel`` with protocol-fidelity byte counts
    under the ``predict_*`` tags.
    """

    def __init__(self, guest, hosts, *, channel: Channel | None = None,
                 stats: Stats | None = None, mesh=None,
                 use_pallas: bool = True):
        hosts = sorted(hosts, key=lambda h: h.hid)
        if len(hosts) != guest.n_hosts or any(
                h.hid != i for i, h in enumerate(hosts)):
            raise ValueError(
                f"guest half expects hosts 0..{guest.n_hosts - 1}, got "
                f"{[h.hid for h in hosts]}")
        if guest.guest.k != int(guest.k_parties[0]):
            raise ValueError(
                f"guest split table has {guest.guest.k} nodes, k_parties "
                f"records {int(guest.k_parties[0])}")
        for h in hosts:
            k = h.table.k if hasattr(h, "table") else h.k
            if k != int(guest.k_parties[1 + h.hid]):
                raise ValueError(
                    f"host{h.hid} table has {k} nodes, guest half "
                    f"expects {int(guest.k_parties[1 + h.hid])}")
        self.guest = guest
        self.hosts = hosts
        self.channel = channel if channel is not None else Channel()
        self.stats = stats if stats is not None else Stats()
        self.mesh = mesh if (mesh is not None
                             and mesh.devices.size > 1) else None
        # serving is latency-sensitive: take the Pallas bucketize only
        # where it compiles natively (TPU).  Off-TPU it would run in
        # interpret mode — python per grid tile — while the pure-jnp ref
        # is bit-identical (tested) and XLA-compiled everywhere.
        from ..kernels.common import default_interpret
        self.use_pallas = use_pallas and not default_interpret()

        self._step = jnp.asarray(guest.step)
        # per party: a PartyBits evaluator (in-process halves), or None
        # for parties owning no internal nodes, or a remote handle whose
        # process evaluates its own bits (``RemoteServingHost``)
        self._bits = [PartyBits(guest.guest, guest.thresholds, guest.n_bins,
                                self.use_pallas)
                      if guest.guest.k else None]
        for h in hosts:
            if hasattr(h, "table"):     # in-process HostHalf
                self._bits.append(
                    PartyBits(h.table, h.thresholds, h.n_bins,
                              self.use_pallas) if h.table.k else None)
            else:                       # remote: its PartyProcess computes
                self._bits.append(h if h.k else None)

    # ------------------------------------------------------------------
    def predict_score(self, X_guest, X_hosts) -> np.ndarray:
        """Raw ensemble scores for one batch (one round-trip per host).

        With remote hosts the corresponding ``X_hosts`` entries are
        ignored (pass None): each host process bins its OWN feature
        matrix and answers the ``predict_req`` with its bit block."""
        if len(X_hosts) != len(self.hosts):
            raise ValueError(f"expected {len(self.hosts)} host matrices, "
                             f"got {len(X_hosts)}")
        # a guest owning no internal nodes (e.g. layered mode) never needs
        # its bins — only the batch row count
        guest_bins = (self._bits[0].bin(X_guest)
                      if self._bits[0] is not None
                      else np.zeros((len(X_guest), 0), np.int32))
        return self._predict_core(guest_bins, list(X_hosts), binned=False)

    def predict_proba(self, X_guest, X_hosts) -> np.ndarray:
        from ..core.loss import sigmoid, softmax
        s = self.predict_score(X_guest, X_hosts)
        return sigmoid(s) if self.guest.objective == "binary" else softmax(s)

    def predict_score_binned(self, guest_bins: np.ndarray,
                             host_bins: list) -> np.ndarray:
        """Serve one already-binned batch: the engine entry point shared by
        ``predict_score`` and the from-bins benchmark.  In-process halves
        only: a remote host bins its OWN staged rows, so caller-supplied
        bins for it would be silently ignored — refuse instead."""
        if any(b is not None and not isinstance(b, PartyBits)
               for b in self._bits[1:]):
            raise ValueError(
                "predict_score_binned serves in-process halves only; "
                "remote hosts bin their own staged rows — use "
                "predict_score / MultiHostRun.predict_score")
        return self._predict_core(guest_bins, list(host_bins), binned=True)

    def _predict_core(self, guest_bins: np.ndarray, host_parts: list,
                      binned: bool) -> np.ndarray:
        g = self.guest
        t0 = time.perf_counter()
        if len(host_parts) != len(self.hosts):
            raise ValueError(f"expected {len(self.hosts)} host matrices, "
                             f"got {len(host_parts)}")
        n = guest_bins.shape[0]
        self.stats.n_predict_batches += 1
        tracer = self.channel.tracer

        # pad instances to the next power of two, then to the packed-byte
        # granule (x mesh data extent when sharded).  The pow2 bucketing
        # caps distinct jit compilations of the bits/route kernels at
        # O(log max_batch) across varying batch sizes — the same retrace
        # bound the training path uses for candidate stacks (DESIGN.md
        # §8).  Pad rows route garbage and are sliced off before the
        # weight gather.
        dext = 1
        if self.mesh is not None:
            sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
            dext = int(np.prod([sizes.get(a, 1)
                                for a in ("pod", "data") if a in sizes]))
        n_pad = 1 << max(n - 1, 1).bit_length()
        n_pad += (-n_pad) % (8 * dext)

        blocks = []
        if self._bits[0] is not None:
            with tracer.span("serve_bins", cat="serve", rows=int(n)):
                blocks.append(self._bits[0].packed(guest_bins, n_pad))
        # one round-trip per host per batch: the request carries the
        # instance ids (+ the pad extent so both sides bucket alike), the
        # reply the packed bit block.  ALL requests go out before any
        # reply is collected, so remote hosts compute their bit blocks
        # concurrently (latency = max over hosts, not the sum) — the same
        # dispatch-then-collect shape as the training layer batch.
        t_rt = time.perf_counter()
        pending = []                        # (block slot, party, i)
        down: list = []                     # typed per-party failures
        # ONE request object for all hosts: the transport's broadcast
        # memo then encodes the id vector once, not once per host
        req = {"ids": np.arange(n, dtype=np.int32), "n_pad": int(n_pad)}
        for i, h in enumerate(self.hosts):
            party = self._bits[1 + i]
            if party is None:
                continue                    # party owns no internal nodes
            try:
                self.channel.send("guest", f"host{h.hid}", wire.PREDICT_REQ,
                                  req, n * 4)
            except PartyUnavailable as e:
                # keep dispatching: every HEALTHY host must still get its
                # request so the collect pass below consumes its reply —
                # otherwise a stale bit block would sit in the stream and
                # poison the NEXT batch's collect
                down.append(e)
                continue
            if isinstance(party, PartyBits):
                # in-process half: compute (async jax dispatch) and record
                # the reply send here, exactly the oracle accounting
                pb = (party.packed(host_parts[i], n_pad) if binned
                      else party.packed_from_X(host_parts[i], n_pad))
                k = pb.shape[0]
                pb = self.channel.send(f"host{h.hid}", "guest",
                                       wire.PREDICT_BITS, pb,
                                       k * ((n + 7) // 8))
                pending.append(pb)
            else:
                pending.append(party)       # remote: collect below
        for item in pending:
            try:
                pb = item.predict_bits() if hasattr(item, "predict_bits") \
                    else item
            except PartyUnavailable as e:
                down.append(e)
                continue
            self.stats.n_predict_roundtrips += 1
            blocks.append(pb)
        if down:
            # the whole batch fails, typed, after every live host's reply
            # was consumed: never a hang, never an answer scored from a
            # subset of the parties' bits
            raise down[0]
        tracer.complete("serve_roundtrip", int(t_rt * 1e9),
                        int((time.perf_counter() - t_rt) * 1e9),
                        cat="serve", hosts=len(self.hosts))

        if blocks and g.depth > 0:
            with tracer.span("serve_route", cat="serve", rows=int(n),
                             trees=int(g.n_trees)):
                bits = (blocks[0] if len(blocks) == 1
                        else jnp.concatenate(blocks, axis=0))
                node0 = jnp.broadcast_to(jnp.asarray(g.roots),
                                         (n_pad, g.n_trees))
                if self.mesh is not None:
                    from ..parallel.sharding import gbdt_sharding
                    bits = jax.device_put(
                        bits, gbdt_sharding(self.mesh, "serve_bits"))
                    node0 = jax.device_put(
                        node0, gbdt_sharding(self.mesh, "serve_route"))
                node = np.asarray(_route(bits, self._step, node0,
                                         g.depth))[:n]
        else:                               # every tree is a lone leaf
            node = np.broadcast_to(g.roots, (n, g.n_trees))

        # float accumulation replays the legacy per-tree order exactly
        w = g.leaf_w[node]                  # (n, n_trees, w_dim)
        if g.objective == "binary":
            score = np.full(n, g.init_score)
            for t in range(g.n_trees):
                score += w[:, t, 0]
        elif g.objective == "multiclass":
            score = np.tile(g.init_score, (n, 1))
            for t in range(g.n_trees):
                score[:, g.tree_class[t]] += w[:, t, 0]
        else:                               # mo: vector leaves
            score = np.tile(g.init_score, (n, 1))
            for t in range(g.n_trees):
                score += w[:, t]
        dt = time.perf_counter() - t0
        self.stats.predict_seconds += dt
        tracer.complete("serve_batch", int(t0 * 1e9), int(dt * 1e9),
                        cat="serve", rows=int(n))
        return score
