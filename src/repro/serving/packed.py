"""Packed ensembles for federated serving (DESIGN.md §9).

Training produces a list of :class:`FederatedTree` objects whose split
tables are scattered across parties.  Serving flattens them ONCE into flat
arrays shaped for the layer-synchronous traversal engine
(``serving/engine.py``):

* Internal nodes of ALL trees are numbered by **bit column**: guest-owned
  nodes first, then each host's block in hid order, each block ordered by
  (tree, nid).  A party's decision-bit tensor for a batch is therefore one
  contiguous row block, and the concatenated tensor needs no scatter.
* Leaves continue the numbering above the internal block and self-loop in
  the fused ``step`` table (``step[j] = [right, left]``, leaves
  ``[j, j]``), so routing needs no leaf test.
* The split *content* stays with its owner: the guest half carries tree
  structure, leaf weights, and only the guest's own (fid, bid) pairs; each
  host half carries only that host's (fid, bid) table and binning
  thresholds — the same privacy boundary as training.

Nothing here is row-level: a packed model is a pure function of the trees,
shippable to a serving process with no training-set residue (asserted).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.tree import GUEST


@dataclasses.dataclass
class PartySlice:
    """One party's private routing table: (fid, bid) per owned internal
    node, ordered by that party's bit-column ids.  ``fid`` is local to the
    party's own feature space."""
    fid: np.ndarray            # (k,) int32
    bid: np.ndarray            # (k,) int32

    @property
    def k(self) -> int:
        return len(self.fid)


@dataclasses.dataclass
class GuestHalf:
    """Everything the guest needs to serve: tree structure, leaf weights,
    its own splits, and its binning thresholds.  Contains NO host split
    content — only the per-party internal-node counts (``k_parties``),
    which fix each host's row block in the combined bit tensor."""
    step: np.ndarray           # (n_nodes, 2) int32: [right, left]; leaves
                               # self-loop
    roots: np.ndarray          # (n_trees,) int32 packed id of each root
    tree_class: np.ndarray     # (n_trees,) int32; -1 for binary / MO
    leaf_w: np.ndarray         # (n_nodes, w_dim) float64, 0 at internal ids
    depth: int                 # max node depth over all trees
    k_parties: np.ndarray      # (1 + n_hosts,) int32 internal nodes per
                               # party, guest first
    guest: PartySlice
    thresholds: np.ndarray     # guest binning table (n_f, n_b-1) fp32
    n_bins: int
    objective: str             # binary | multiclass | mo
    n_classes: int
    init_score: object         # float (binary) or (n_classes,) float64

    @property
    def n_nodes(self) -> int:
        return self.step.shape[0]

    @property
    def n_trees(self) -> int:
        return len(self.roots)

    @property
    def n_hosts(self) -> int:
        return len(self.k_parties) - 1

    @property
    def k_total(self) -> int:
        return int(np.sum(self.k_parties))


@dataclasses.dataclass
class HostHalf:
    """Everything one host needs to serve: its split table (in bit-column
    order) and its binning thresholds.  No tree structure, no leaf
    weights, no other party's splits."""
    hid: int
    table: PartySlice
    thresholds: np.ndarray
    n_bins: int


@dataclasses.dataclass
class PackedEnsemble:
    guest: GuestHalf
    hosts: list

    @classmethod
    def from_model(cls, model) -> "PackedEnsemble":
        """Flatten a trained ``VerticalBoosting`` into serving halves.

        Requires in-process host tables (simulation).  Under the
        process-per-party runtime use :func:`pack_guest` — each host's
        ``PartyProcess`` builds its own :class:`HostHalf` from the key
        order ``pack_guest`` returns, and the private (fid, bid) content
        never enters the guest process."""
        if getattr(model, "remote_hosts", None) is not None:
            raise ValueError(
                "host tables live in remote processes: use pack_guest() "
                "here and host_half_from_keys()/export_host() in each "
                "host's PartyProcess (MultiHostRun.serve does this)")
        guest, host_keys = pack_guest(model)
        trees = model.trees

        def _slice(keys, lookup):
            fid = np.empty(len(keys), np.int32)
            bid = np.empty(len(keys), np.int32)
            for i, (ti, nid) in enumerate(keys):
                fid[i], bid[i] = lookup(ti, nid)
            return PartySlice(fid=fid, bid=bid)

        hosts = [
            HostHalf(hid=h,
                     table=_slice(host_keys[h], lambda ti, nid:
                                  trees[ti].host_tables[h][nid]),
                     thresholds=np.asarray(model.host_data[h].thresholds,
                                           np.float32),
                     n_bins=model.params.n_bins)
            for h in range(len(host_keys))]
        return cls(guest=guest, hosts=hosts)


def pack_guest(model) -> tuple:
    """Pack the guest's serving half from a trained model, WITHOUT touching
    host split content.

    Returns ``(guest_half, host_keys)`` where ``host_keys[hid]`` is that
    host's internal nodes in bit-column order as ``(tree_idx, nid)`` pairs
    — the guest-visible structure a host needs (and all it needs) to build
    its own :class:`HostHalf` from its private tables in its own process.
    """
    trees = model.trees
    if not trees:
        raise ValueError("cannot pack an unfitted model (no trees)")
    n_hosts = (len(model.remote_hosts)
               if getattr(model, "remote_hosts", None) is not None
               else len(model.host_data))
    for t in trees:
        # the grower keeps row->leaf maps train-side; a tree that still
        # carries one must never reach an exportable ensemble
        if hasattr(t, "leaf_rows"):
            raise AssertionError(
                "FederatedTree retains row-level training state "
                "(leaf_rows); packed models must be training-set free")

    arrays = [t.node_arrays() for t in trees]

    # pass 1: bit-column ids — guest block, then host blocks (hid
    # order), each ordered by (tree, nid)
    owners = [GUEST] + list(range(n_hosts))
    internal = {p: [] for p in owners}
    n_leaves = 0
    for ti, a in enumerate(arrays):
        for nid in range(len(a["party"])):
            if a["left"][nid] != -1:
                internal[int(a["party"][nid])].append((ti, nid))
            else:
                n_leaves += 1
    k_parties = np.asarray([len(internal[p]) for p in owners], np.int32)
    k_total = int(k_parties.sum())
    n_nodes = k_total + n_leaves

    gid = {}
    col = 0
    for p in owners:
        for key in internal[p]:
            gid[key] = col
            col += 1
    for ti, a in enumerate(arrays):
        for nid in range(len(a["party"])):
            if a["left"][nid] == -1:
                gid[(ti, nid)] = col
                col += 1

    w_dim = arrays[0]["weight"].shape[1]
    step = np.empty((n_nodes, 2), np.int32)
    leaf_w = np.zeros((n_nodes, w_dim), np.float64)
    depth = 0
    roots = np.empty(len(trees), np.int32)
    for ti, a in enumerate(arrays):
        roots[ti] = gid[(ti, 0)]
        depth = max(depth, int(a["depth"].max()))
        for nid in range(len(a["party"])):
            g = gid[(ti, nid)]
            if a["left"][nid] != -1:
                step[g, 0] = gid[(ti, int(a["right"][nid]))]
                step[g, 1] = gid[(ti, int(a["left"][nid]))]
            else:
                step[g] = g
                leaf_w[g] = a["weight"][nid]

    fid = np.empty(len(internal[GUEST]), np.int32)
    bid = np.empty(len(internal[GUEST]), np.int32)
    for i, (ti, nid) in enumerate(internal[GUEST]):
        fid[i] = int(arrays[ti]["fid"][nid])
        bid[i] = int(arrays[ti]["bid"][nid])
    p = model.params
    guest = GuestHalf(
        step=step, roots=roots,
        tree_class=np.asarray(model.tree_class, np.int32),
        leaf_w=leaf_w, depth=depth, k_parties=k_parties,
        guest=PartySlice(fid=fid, bid=bid),
        thresholds=np.asarray(model.guest_data.thresholds, np.float32),
        n_bins=p.n_bins, objective=p.objective, n_classes=p.n_classes,
        init_score=(np.asarray(model.init_score, np.float64)
                    if p.objective != "binary"
                    else float(model.init_score)))
    return guest, [internal[h] for h in range(n_hosts)]


def host_half_from_keys(hid: int, keys: list, tables: dict,
                        thresholds: np.ndarray, n_bins: int) -> HostHalf:
    """Build one host's serving half from the guest-published bit-column
    key order and the host's OWN per-tree (fid, bid) tables
    (``tables[tree_idx][nid]``).  This runs inside the host's process: the
    split content never leaves it."""
    fid = np.empty(len(keys), np.int32)
    bid = np.empty(len(keys), np.int32)
    for i, (ti, nid) in enumerate(keys):
        fid[i], bid[i] = tables[int(ti)][int(nid)]
    return HostHalf(hid=hid, table=PartySlice(fid=fid, bid=bid),
                    thresholds=np.asarray(thresholds, np.float32),
                    n_bins=n_bins)
