"""Federated serving subsystem (DESIGN.md §9): packed device-resident
ensembles, a round-batched bit-tensor protocol (one round-trip per host
per batch), and per-party model export."""

from .engine import FederatedPredictor  # noqa: F401
from .export import export_model, load_ensemble, load_guest, load_host  # noqa: F401
from .packed import GuestHalf, HostHalf, PackedEnsemble, PartySlice  # noqa: F401
