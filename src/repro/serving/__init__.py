"""Federated serving subsystem (DESIGN.md §9): packed device-resident
ensembles, a round-batched bit-tensor protocol (one round-trip per host
per batch), and per-party model export."""

from .engine import FederatedPredictor, PartyBits  # noqa: F401
from .export import (export_guest, export_host, export_model,  # noqa: F401
                     load_ensemble, load_guest, load_host)
from .packed import (GuestHalf, HostHalf, PackedEnsemble,  # noqa: F401
                     PartySlice, host_half_from_keys, pack_guest)
