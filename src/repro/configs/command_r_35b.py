"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified]."""

import dataclasses

from ..models.common import ModelConfig

# seq-parallel residual + dots-saveable remat: measured +61% roofline on
# command-r train (EXPERIMENTS.md Perf-3); safe for dense/VLM stacks.
_FULL = ModelConfig(
    seq_shard=True, remat_policy="dots",
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000,
)


def full_config() -> ModelConfig:
    return _FULL


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        _FULL, name="command-r-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_ff=192, vocab=256, remat=False)
