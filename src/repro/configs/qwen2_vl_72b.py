"""qwen2-vl-72b [vlm backbone]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

The vision frontend is a STUB per the brief: the backbone consumes text
tokens plus optional precomputed patch embeddings (``input_specs`` supplies
them); M-RoPE degenerates to 1-D rotary on the text stream."""

import dataclasses

from ..models.common import ModelConfig

# seq-parallel residual + dots-saveable remat: measured +61% roofline on
# command-r train (EXPERIMENTS.md Perf-3); safe for dense/VLM stacks.
_FULL = ModelConfig(
    seq_shard=True, remat_policy="dots",
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, mrope=True, frontend="patch_stub",
)


def full_config() -> ModelConfig:
    return _FULL


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        _FULL, name="qwen2-vl-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_ff=192, vocab=256, remat=False)
