"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000, pruned nemotron [arXiv:2407.14679; hf]."""

import dataclasses

from ..models.common import ModelConfig

# seq-parallel residual + dots-saveable remat: measured +61% roofline on
# command-r train (EXPERIMENTS.md Perf-3); safe for dense/VLM stacks.
_FULL = ModelConfig(
    seq_shard=True, remat_policy="dots",
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab=256000,
)


def full_config() -> ModelConfig:
    return _FULL


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        _FULL, name="minitron-smoke", n_layers=2, d_model=48, n_heads=4,
        n_kv_heads=2, d_ff=144, vocab=256, remat=False)
