"""mamba2-130m [ssm]: 24L d_model=768 attn-free, ssm_state=128, SSD
[arXiv:2405.21060; unverified].  Sub-quadratic: runs long_500k."""

import dataclasses

from ..models.common import ModelConfig

_FULL = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    ssm_chunk=64, conv_width=4,
)


def full_config() -> ModelConfig:
    return _FULL


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        _FULL, name="mamba2-smoke", n_layers=2, d_model=64, vocab=256,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8, remat=False)
