"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk_norm [hf:Qwen/Qwen3-*; hf]."""

import dataclasses

from ..models.common import ModelConfig

# seq-parallel residual + dots-saveable remat: measured +61% roofline on
# command-r train (EXPERIMENTS.md Perf-3); safe for dense/VLM stacks.
_FULL = ModelConfig(
    seq_shard=True, remat_policy="dots",
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab=151936, qk_norm=True,
)


def full_config() -> ModelConfig:
    return _FULL


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        _FULL, name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, remat=False)
