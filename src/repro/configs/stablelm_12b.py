"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 [hf:stabilityai/stablelm-2-12b; hf]."""

import dataclasses

from ..models.common import ModelConfig

# seq-parallel residual + dots-saveable remat: measured +61% roofline on
# command-r train (EXPERIMENTS.md Perf-3); safe for dense/VLM stacks.
_FULL = ModelConfig(
    seq_shard=True, remat_policy="dots",
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab=100352,
)


def full_config() -> ModelConfig:
    return _FULL


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        _FULL, name="stablelm-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=256, remat=False)
