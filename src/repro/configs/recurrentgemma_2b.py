"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 -- RG-LRU + local attention, pattern 2 recurrent : 1 attn,
window 2048 [arXiv:2402.19427; hf].  Sub-quadratic: runs long_500k."""

import dataclasses

from ..models.common import ModelConfig

_FULL = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, window=2048, lru_width=2560, conv_width=4,
    head_dim=256,
)


def full_config() -> ModelConfig:
    return _FULL


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        _FULL, name="recurrentgemma-smoke", n_layers=5, d_model=64,
        n_heads=4, n_kv_heads=1, d_ff=128, vocab=256, window=16,
        lru_width=64, head_dim=16, remat=False)
