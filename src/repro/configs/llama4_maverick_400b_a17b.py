"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, 128 routed experts top-1 + 1 shared, early fusion
[hf:meta-llama/Llama-4-*; unverified]."""

import dataclasses

from ..models.common import ModelConfig

_FULL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, d_ff_expert=8192, vocab=202048,
    n_experts=128, n_shared_experts=1, top_k=1,
)


def full_config() -> ModelConfig:
    return _FULL


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        _FULL, name="llama4-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, d_ff_expert=96, vocab=256, n_experts=8,
        n_shared_experts=1, top_k=1, remat=False)
