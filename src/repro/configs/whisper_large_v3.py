"""whisper-large-v3 [audio]: enc-dec, 32+32L d_model=1280 20H d_ff=5120
vocab=51866, conv frontend STUB (input_specs supplies precomputed frame
embeddings) [arXiv:2212.04356; unverified]."""

import dataclasses

from ..models.common import ModelConfig

_FULL = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, n_enc_layers=32, enc_dec=True,
    d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, frontend="audio_stub",
)


def full_config() -> ModelConfig:
    return _FULL


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        _FULL, name="whisper-smoke", n_layers=2, n_enc_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, remat=False)
