"""Assigned-architecture registry: ``get_config(name, smoke=False)``.

Each module exposes ``full_config()`` (the exact published shape) and
``smoke_config()`` (reduced same-family config for CPU smoke tests).
"""

from __future__ import annotations

import importlib

ARCHS = [
    "deepseek_moe_16b",
    "llama4_maverick_400b_a17b",
    "recurrentgemma_2b",
    "qwen3_1_7b",
    "stablelm_12b",
    "command_r_35b",
    "minitron_4b",
    "qwen2_vl_72b",
    "mamba2_130m",
    "whisper_large_v3",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str, smoke: bool = False):
    mod_name = _ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config() if smoke else mod.full_config()
