"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, 2 shared + 64 routed top-6 fine-grained experts
[arXiv:2401.06066; hf].  (The released model's layer 0 is a dense FFN; we
keep all layers MoE for uniformity -- noted deviation.)"""

import dataclasses

from ..models.common import ModelConfig

_FULL = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, d_ff_expert=1408, vocab=102400,
    n_experts=64, n_shared_experts=2, top_k=6,
)


def full_config() -> ModelConfig:
    return _FULL


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        _FULL, name="deepseek-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=48, d_ff_expert=48, vocab=256, n_experts=8,
        n_shared_experts=2, top_k=2, remat=False)
