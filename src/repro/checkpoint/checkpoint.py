"""Sharded, atomic, async checkpointing with elastic restore.

Layout: one .npz per pytree leaf (path-encoded filename) + manifest.json
(tree structure, shapes, dtypes, step, logical sharding specs).  Writes go
to a temp dir + atomic rename, so a crash mid-save never corrupts the last
good checkpoint.  ``save_async`` returns immediately (thread pool); the
training loop joins before the next save (single outstanding write).

Elastic restore: leaves are stored *unsharded* (gathered); ``restore``
reshards onto whatever mesh/sharding the new job passes -- a different pod
count or TP degree just works, which is the elastic-scaling story.
"""

from __future__ import annotations

import concurrent.futures as _fut
import json
import os
import shutil

import jax
import numpy as np

_POOL = _fut.ThreadPoolExecutor(max_workers=2)


def _leaf_name(path) -> str:
    keys = []
    for p in path:
        k = getattr(p, "key", getattr(p, "name", None))
        keys.append(str(k) if k is not None else str(getattr(p, "idx", p)))
    return "__".join(keys) or "leaf"


def _leaf_names(paths) -> list:
    """Filenames for a flattened pytree, deterministically de-collided.

    Joining path keys with ``__`` is not injective (a dict key containing
    ``__`` vs. genuinely nested keys): two distinct leaves could map to the
    same .npz and silently overwrite each other — ``restore`` then returned
    the wrong array for one of them.  Suffix repeats with ``#k``, feeding
    chosen names back into the seen-set so a suffixed name can never
    collide with a genuine leaf named ``...#k`` either; both ``save`` and
    ``restore`` flatten in the same (sorted-key) order, so the mapping
    stays stable without storing extra state."""
    seen: set = set()
    out = []
    for path in paths:
        name = _leaf_name(path)
        k = 0
        final = name
        while final in seen:
            k += 1
            final = f"{name}#{k}"
        seen.add(final)
        out.append(final)
    return out


def save(ckpt_dir: str, step: int, tree) -> str:
    tmp = ckpt_dir + f".tmp-{step}"
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": []}
    names = _leaf_names([p for p, _ in leaves])
    for name, (path, leaf) in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        # npz can't hold ml_dtypes (bf16 etc.); store raw bytes + dtype str
        raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        np.savez_compressed(os.path.join(tmp, name + ".npz"), data=raw)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.makedirs(ckpt_dir, exist_ok=True)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                     # atomic publish
    _gc(ckpt_dir, keep=3)
    return final


def save_async(ckpt_dir: str, step: int, tree):
    """Non-blocking save; returns a future.  Device->host copy happens here
    (cheap), compression + IO on the pool thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    return _POOL.submit(save, ckpt_dir, step, host_tree)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Load into the structure of ``like``; reshard onto ``shardings``
    (elastic: any mesh shape)."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(paths))
    names = _leaf_names([p for p, _ in paths])
    out = []
    for name, (path, leaf), sh in zip(names, paths, shard_leaves):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        meta = by_name[name]
        import ml_dtypes  # noqa: F401  (registers bfloat16 et al.)
        raw = np.load(os.path.join(src, name + ".npz"))["data"]
        arr = raw.view(np.dtype(meta["dtype"])).reshape(meta["shape"])
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {leaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_any(ckpt_dir: str, step: int) -> dict:
    """Structure-free restore: ``{leaf name: numpy array}`` with the
    exact on-disk bytes.  ``restore`` routes leaves through
    ``jnp.asarray``, which canonicalizes dtypes (float64 silently
    truncates to float32 while x64 is off) — callers that need
    bit-exact HOST-side state, like the resilient trainer's score
    vector, must read through this instead."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    import ml_dtypes  # noqa: F401  (registers bfloat16 et al.)
    out = {}
    for m in manifest["leaves"]:
        raw = np.load(os.path.join(src, m["name"] + ".npz"))["data"]
        out[m["name"]] = raw.view(np.dtype(m["dtype"])).reshape(m["shape"])
    return out


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted([d for d in os.listdir(ckpt_dir) if d.startswith("step_")])
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
