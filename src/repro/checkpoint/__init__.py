from .checkpoint import latest_step, restore, save, save_async  # noqa: F401
